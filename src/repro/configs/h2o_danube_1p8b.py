"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA W=4096.
[arXiv:2401.16818]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        source="arXiv:2401.16818",
        num_layers=24,
        d_model=2560,
        d_ff=6912,
        vocab_size=32_000,
        attention=AttentionConfig(
            kind="swa",
            num_heads=32,
            num_kv_heads=8,
            head_dim=80,
            window=4096,
            rope_theta=10_000.0,
        ),
    ),
    run=RunConfig(microbatches=1, remat="layer", max_cache_len=524_288),
)
