"""Shared benchmark utilities: timing, CSV emission, and a JSON
results registry so CI can record the perf trajectory as an artifact
(``benchmarks/run.py --json BENCH_cosim.json``).

Rows are backed by the telemetry :class:`~repro.telemetry.Telemetry`
registry: every ``emit`` lands as ``bench:{name}:{field}`` gauges
(numbers) / texts (strings) in ``TELEMETRY.metrics``, and
``write_json`` reconstructs the ``{name: {field: value}}`` payload
from a registry snapshot — the BENCH_* artifacts are a telemetry
export rather than a hand-rolled dict, and ``TELEMETRY.to_prometheus``
gives the same rows in Prometheus text format."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

from repro.telemetry import Telemetry

#: process-wide benchmark telemetry: every ``emit`` records here, and
#: ``write_json`` / ``to_prometheus`` export from it.
TELEMETRY = Telemetry()

#: legacy row view (append order) — kept for callers that iterate rows.
RESULTS: List[Dict[str, object]] = []


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
            **kwargs) -> float:
    for _ in range(warmup):
        fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kwargs)
    return (time.perf_counter() - t0) / repeats * 1e6


def _derived_fields(derived: str) -> Dict[str, object]:
    """Parse the ``k=v;k=v`` derived string, keeping numeric values as
    numbers (so the JSON artifact is machine-comparable across runs)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    row: Dict[str, object] = {"name": name,
                              "us_per_call": float(us_per_call)}
    row.update(_derived_fields(derived))
    RESULTS.append(row)
    m = TELEMETRY.metrics
    for field, value in row.items():
        if field == "name":
            continue
        key = f"bench:{name}:{field}"
        if isinstance(value, (int, float)):
            m.gauge(key).set(float(value))
        else:
            m.text(key).set(str(value))


def rows_from_registry(prefix: str = "") -> Dict[str, Dict[str, object]]:
    """Reconstruct ``{name: {field: value}}`` from the telemetry
    registry (``bench:{name}:{field}`` keys; benchmark names contain no
    colons, so ``rsplit(':', 1)`` recovers the field).  ``prefix``
    restricts the payload to benchmark names starting with it (so a
    section can export its own BENCH_*.json without dragging along every
    row emitted earlier in the process)."""
    snap = TELEMETRY.metrics.snapshot()
    payload: Dict[str, Dict[str, object]] = {}
    for kind in ("gauges", "texts"):
        for key, value in snap.get(kind, {}).items():
            if not key.startswith("bench:"):
                continue
            name, field = key[len("bench:"):].rsplit(":", 1)
            if prefix and not name.startswith(prefix):
                continue
            payload.setdefault(name, {})[field] = value
    return payload


def write_json(path: str, prefix: str = "") -> None:
    """Snapshot every emitted benchmark row to ``path`` as
    ``{name: {us_per_call, ...derived fields...}}`` — the perf record
    CI uploads (``requests_per_s`` rows carry the event-engine
    throughput the soft floor in ``scripts/ci.sh`` checks).  The
    payload comes out of the telemetry registry, so it is exactly what
    ``TELEMETRY.to_prometheus()`` exposes under another format."""
    with open(path, "w") as f:
        json.dump(rows_from_registry(prefix), f, indent=2, sort_keys=True)
        f.write("\n")
