"""General-purpose-orchestrator (GPO) interface — paper §III.

The paper delegates infrastructure inventory to a GPO such as Kubernetes.
Here the GPO is an in-process inventory of nodes (devices, edge hosts,
cloud) exposing exactly the information the HFL-specific orchestrator
needs: node resource states, network costs, and inference workloads."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.hflop import HFLOPInstance


@dataclass
class DeviceNode:
    id: int
    lam: float                       # inference request rate (req/s)
    lan_edge: Optional[int] = None   # edge reachable at zero cost
    reliable: bool = True


@dataclass
class EdgeNode:
    id: int
    capacity_rps: float              # inference processing capacity r_j
    cloud_cost: float = 1.0          # c^e_j
    trusted_by_all: bool = True


@dataclass
class Inventory:
    devices: List[DeviceNode]
    edges: List[EdgeNode]
    unit_cost: float = 1.0           # device->non-LAN edge cost

    @classmethod
    def from_arrays(cls, lam: np.ndarray, r: np.ndarray,
                    lan_edge: Optional[np.ndarray] = None,
                    unit_cost: float = 1.0) -> "Inventory":
        """Build an inventory from the array form the benchmarks use
        (per-device rates, per-edge capacities, optional LAN edge;
        negative LAN entries — assign-style 'no edge' markers — map to
        None, not to a bogus zero-cost link)."""
        devices = [DeviceNode(i, lam=float(l),
                              lan_edge=(int(lan_edge[i])
                                        if lan_edge is not None
                                        and int(lan_edge[i]) >= 0
                                        else None))
                   for i, l in enumerate(np.asarray(lam, float))]
        edges = [EdgeNode(j, capacity_rps=float(c))
                 for j, c in enumerate(np.asarray(r, float))]
        return cls(devices, edges, unit_cost=unit_cost)

    def to_instance(self, l: int = 2,
                    T: Optional[int] = None) -> HFLOPInstance:
        n, m = len(self.devices), len(self.edges)
        c_d = np.full((n, m), self.unit_cost)
        rows = np.asarray([d.id for d in self.devices
                           if d.lan_edge is not None], int)
        cols = np.asarray([d.lan_edge for d in self.devices
                           if d.lan_edge is not None], int)
        c_d[rows, cols] = 0.0
        c_e = np.asarray([e.cloud_cost for e in self.edges])
        lam = np.asarray([d.lam for d in self.devices])
        r = np.asarray([e.capacity_rps for e in self.edges])
        return HFLOPInstance(c_d, c_e, lam, r, l=l, T=T)


def random_inventory(n: int, m: int, seed: int = 0,
                     capacity_slack: float = 1.5) -> Inventory:
    rng = np.random.default_rng(seed)
    devices = [DeviceNode(i, lam=float(rng.uniform(0.1, 1.0)),
                          lan_edge=int(rng.integers(0, m)))
               for i in range(n)]
    total = sum(d.lam for d in devices)
    raw = rng.uniform(0.5, 1.5, m)
    caps = raw / raw.sum() * total * capacity_slack
    edges = [EdgeNode(j, capacity_rps=float(caps[j])) for j in range(m)]
    return Inventory(devices, edges)
