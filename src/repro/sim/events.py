"""Discrete-event core of the training–inference co-simulation.

One heap-based clock, typed events, and handler dispatch.  Everything
that "happens" on the continuum — a request arriving, a local epoch
starting on a device, an aggregation upload occupying an edge, a node
dying, concept drift setting in — is an :class:`Event` on the same
timeline, so training and inference contend for the same per-node
compute instead of being simulated in isolation.

Determinism contract: events at equal timestamps are ordered by
``EventKind`` value (completions and state changes apply before the
requests that must observe them), then by insertion order.  Handlers
run in registration order.  Given the same seed and schedule, two runs
produce identical event traces — asserted in ``tests/test_cosim.py``.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Any, Callable, Dict, List, Optional, Tuple


class EventKind(IntEnum):
    """Typed simulation events.  The numeric value is the tie-break
    priority at equal timestamps: lower values are processed first, so
    a completion frees its slot, environment and training state changes
    apply, and only then do same-instant arrivals observe the world."""
    REQUEST_COMPLETION = 0   # a served request leaves its replica
    NODE_FAILURE = 1         # an edge host dies
    CAPACITY_CHANGE = 2      # an edge host's serving capacity shifts
    DEVICE_MOVE = 3          # a device hands over to another LAN edge
    STRAGGLER = 4            # a device's remaining epochs slow mid-round
    TENANT_LOAD = 5          # third-party edge demand changes (multi-tenant)
    DRIFT_ONSET = 6          # concept drift begins in the data stream
    RECONFIG_END = 7         # replica migration / re-deploy finishes
    ROUND_START = 8          # an HFL training round begins
    EPOCH_END = 9            # a device finishes one local epoch
    EPOCH_START = 10         # a device starts one local epoch
    AGG_START = 11           # aggregation upload window opens (edges busy)
    AGG_END = 12             # aggregation upload window closes
    ROUND_END = 13           # the training round is over
    TELEMETRY = 14           # periodic monitor tick (reactive loop)
    REQUEST_ARRIVAL = 15     # an inference request arrives


@dataclass(frozen=True)
class Event:
    t: float
    kind: EventKind
    node: int = -1           # device/edge id, -1 when not node-scoped
    payload: Any = None
    seq: int = 0             # insertion order (unique, the final tie-break)


class EventQueue:
    """Min-heap of events keyed by ``(t, kind, seq)``.  ``seq`` is unique,
    so heap entries never compare payloads."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, Event]] = []
        self._seq = 0

    def push(self, t: float, kind: EventKind, node: int = -1,
             payload: Any = None) -> Event:
        ev = Event(t=float(t), kind=kind, node=int(node), payload=payload,
                   seq=self._seq)
        heapq.heappush(self._heap, (ev.t, int(kind), ev.seq, ev))
        self._seq += 1
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[3]

    def peek_t(self) -> float:
        return self._heap[0][0] if self._heap else math.inf

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


Handler = Callable[["Simulation", Event], None]


@dataclass
class Simulation:
    """The clock + dispatcher.  Modules (request processor, training
    timeline, interference model, reactive loop) register handlers with
    :meth:`on` and schedule follow-up events from inside handlers."""
    record_trace: bool = False
    queue: EventQueue = field(default_factory=EventQueue)
    now: float = 0.0
    handlers: Dict[EventKind, List[Handler]] = field(default_factory=dict)
    trace: List[Tuple[float, str, int]] = field(default_factory=list)

    def on(self, kind: EventKind, handler: Handler) -> None:
        self.handlers.setdefault(kind, []).append(handler)

    def schedule(self, t: float, kind: EventKind, node: int = -1,
                 payload: Any = None) -> Event:
        return self.queue.push(t, kind, node=node, payload=payload)

    def run(self, until: float = math.inf) -> int:
        """Process events in order until the queue drains or the next
        event lies beyond ``until`` (which stays queued)."""
        processed = 0
        while self.queue and self.queue.peek_t() <= until:
            ev = self.queue.pop()
            self.now = ev.t
            if self.record_trace:
                self.trace.append((round(ev.t, 9), ev.kind.name, ev.node))
            for h in self.handlers.get(ev.kind, ()):
                h(self, ev)
            processed += 1
        return processed
