"""Determinism rules: RNG discipline and wall-clock isolation.

Every replayed experiment in this repo — co-sim scenario grids, solver
gap gates, routing fingerprints — depends on two conventions:

- all randomness flows through explicitly passed
  ``numpy.random.Generator`` objects drawn in heap order (DET001:
  global-state ``np.random.*`` and the stdlib ``random`` module are
  forbidden; constructing generators via ``default_rng(seed)`` is the
  sanctioned entry point);
- simulated time is the only time sim/control/solver code may read
  (DET002: ``time.time``/``perf_counter``/``monotonic`` and argless
  ``datetime.now`` are forbidden there; code that legitimately measures
  real elapsed time calls ``repro.telemetry.tracer.wall_clock`` — the
  single audited read);
- chaos and retry/failover code draws ONLY from the shared per-run
  generator the co-sim passes in (DET003: constructing a fresh
  Generator — even the DET001-sanctioned ``default_rng(seed)`` — inside
  ``repro.sim.faults`` or a retry/backoff/failover/fault helper would
  fork the draw stream and break heap-vs-batched retry-schedule
  parity).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Sequence, Set

from repro.analysis.core import (FileContext, Finding, Rule, dotted_name)

#: np.random constructors that are fine — they create explicit streams
RNG_CONSTRUCTORS = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64",
}

#: time-module attributes that read the wall clock
WALL_CLOCK_ATTRS = {
    "time", "time_ns", "perf_counter", "perf_counter_ns",
    "monotonic", "monotonic_ns", "process_time", "process_time_ns",
}

#: datetime methods that read the wall clock
DATETIME_NOW = {"now", "utcnow", "today"}


def module_aliases(tree: ast.Module, module: str) -> Set[str]:
    """Local names bound to ``module`` by top-level or nested imports
    (``import numpy as np`` -> {"np"})."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == module:
                    out.add(alias.asname or module.split(".")[0])
    return out


def from_imports(tree: ast.Module, module: str) -> Dict[str, str]:
    """``{local name: original name}`` for ``from <module> import ...``."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == module:
            for alias in node.names:
                if alias.name != "*":
                    out[alias.asname or alias.name] = alias.name
    return out


def _in_scope(module: str, include: Sequence[str],
              exclude: Sequence[str]) -> bool:
    def hit(namespaces: Sequence[str]) -> bool:
        return any(module == ns or module.startswith(ns + ".")
                   for ns in namespaces)
    return hit(include) and not hit(exclude)


class GlobalRngRule(Rule):
    """DET001: no global-state RNG anywhere in the package."""

    id = "DET001"
    name = "no-global-rng"
    description = ("randomness must flow through explicitly passed "
                   "np.random.Generator objects: global-state "
                   "np.random.* calls and the stdlib random module are "
                   "forbidden")
    include = ("repro",)
    exclude: Sequence[str] = ()

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if ctx.module is None or not _in_scope(ctx.module, self.include,
                                               self.exclude):
            return []
        findings: List[Finding] = []
        np_names = module_aliases(ctx.tree, "numpy") | {"numpy"}
        npr_names = (module_aliases(ctx.tree, "numpy.random")
                     | set(from_imports(ctx.tree, "numpy").get(k, "")
                           for k in ()))
        # `from numpy import random [as r]`
        for local, orig in from_imports(ctx.tree, "numpy").items():
            if orig == "random":
                npr_names.add(local)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        findings.append(Finding(
                            path=ctx.rel_path, line=node.lineno,
                            rule=self.id,
                            message="stdlib random module imported; use "
                                    "an explicit np.random.Generator"))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    findings.append(Finding(
                        path=ctx.rel_path, line=node.lineno,
                        rule=self.id,
                        message="stdlib random import; use an explicit "
                                "np.random.Generator"))
                elif node.module in ("numpy.random", "numpy"):
                    mod_attrs = (RNG_CONSTRUCTORS
                                 if node.module == "numpy.random"
                                 else set())
                    for alias in node.names:
                        if (node.module == "numpy.random"
                                and alias.name not in mod_attrs):
                            findings.append(Finding(
                                path=ctx.rel_path, line=node.lineno,
                                rule=self.id,
                                message=f"global-state numpy.random."
                                        f"{alias.name} imported; draw "
                                        f"from a passed Generator"))
            elif isinstance(node, ast.Attribute):
                name = dotted_name(node)
                if name is None:
                    continue
                parts = name.split(".")
                # np.random.X / numpy.random.X
                if (len(parts) >= 3 and parts[0] in np_names
                        and parts[1] == "random"
                        and parts[2] not in RNG_CONSTRUCTORS):
                    findings.append(Finding(
                        path=ctx.rel_path, line=node.lineno, rule=self.id,
                        message=f"global-state np.random.{parts[2]}; "
                                f"draw from a passed Generator"))
                # nprandom.X  (import numpy.random as nprandom)
                elif (len(parts) >= 2 and parts[0] in npr_names
                        and parts[0] != ""
                        and parts[1] not in RNG_CONSTRUCTORS):
                    findings.append(Finding(
                        path=ctx.rel_path, line=node.lineno, rule=self.id,
                        message=f"global-state numpy.random."
                                f"{parts[1]}; draw from a passed "
                                f"Generator"))
        return findings


class WallClockRule(Rule):
    """DET002: sim/control/solver paths never read the wall clock."""

    id = "DET002"
    name = "no-wall-clock"
    description = ("sim/control/solver code must not reference "
                   "time.time/perf_counter/monotonic or argless "
                   "datetime.now; real elapsed time goes through "
                   "repro.telemetry.tracer.wall_clock")
    include = ("repro.sim", "repro.routing", "repro.core",
               "repro.orchestration", "repro.fl", "repro.data",
               "repro.configs", "repro.checkpoint", "repro.analysis")
    # tracer.py hosts the one audited read; training/launch/benchmark
    # code measures real time legitimately
    exclude = ("repro.telemetry.tracer", "repro.launch", "repro.serving",
               "repro.models", "repro.kernels", "repro.training",
               "repro.fl.hierarchy_bench")

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if ctx.module is None or not _in_scope(ctx.module, self.include,
                                               self.exclude):
            return []
        findings: List[Finding] = []
        time_names = module_aliases(ctx.tree, "time") | {"time"}
        dt_locals = from_imports(ctx.tree, "datetime")
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in WALL_CLOCK_ATTRS:
                        findings.append(Finding(
                            path=ctx.rel_path, line=node.lineno,
                            rule=self.id,
                            message=f"time.{alias.name} imported in a "
                                    f"sim/control path; use "
                                    f"telemetry.tracer.wall_clock"))
            elif isinstance(node, ast.Attribute):
                name = dotted_name(node)
                if name is None:
                    continue
                parts = name.split(".")
                # time.perf_counter etc. — flag the *reference*, not just
                # calls: `default_factory=time.monotonic` never calls it
                # at this site but still injects wall time
                if (len(parts) >= 2 and parts[0] in time_names
                        and parts[1] in WALL_CLOCK_ATTRS):
                    findings.append(Finding(
                        path=ctx.rel_path, line=node.lineno, rule=self.id,
                        message=f"wall-clock read time.{parts[1]} in a "
                                f"sim/control path; use "
                                f"telemetry.tracer.wall_clock"))
                # datetime.datetime.now / dt.now / date.today
                elif parts[-1] in DATETIME_NOW and (
                        parts[0] in module_aliases(ctx.tree, "datetime")
                        or parts[0] in dt_locals):
                    findings.append(Finding(
                        path=ctx.rel_path, line=node.lineno, rule=self.id,
                        message=f"wall-clock read {name} in a "
                                f"sim/control path"))
        return findings


class FreshRngInFaultPathRule(Rule):
    """DET003: fault/retry code never constructs its own Generator.

    Retry schedules, failover decisions and fault timelines must be
    bit-identical between the heap and the batched request planes —
    which holds only when every draw comes from the ONE shared per-run
    generator, consumed in event order.  ``default_rng(seed)`` is fine
    elsewhere (DET001 sanctions it as the explicit-stream entry point),
    but inside the chaos module or a retry/backoff/failover helper it
    forks a private stream whose draws don't interleave with the run's,
    silently desynchronizing the two engines.
    """

    id = "DET003"
    name = "no-fresh-rng-in-fault-path"
    description = ("chaos plans and retry/backoff/failover helpers may "
                   "draw randomness only from the shared per-run "
                   "Generator passed in; constructing a fresh Generator "
                   "(np.random.default_rng & co.) there is forbidden")
    #: whole modules where any Generator construction is forbidden
    module_scope: Sequence[str] = ("repro.sim.faults",)
    #: modules where only fault-path functions are checked (they host
    #: sanctioned constructors elsewhere, e.g. bootstrap CIs)
    function_scope: Sequence[str] = ("repro.sim.request_plane",
                                     "repro.routing.simulator")
    _FAULT_FUNC = re.compile(r"retry|backoff|failover|fault",
                             re.IGNORECASE)

    def _constructor_calls(self, ctx: FileContext,
                           root: ast.AST) -> List[Finding]:
        np_names = module_aliases(ctx.tree, "numpy") | {"numpy"}
        npr_names = module_aliases(ctx.tree, "numpy.random")
        for local, orig in from_imports(ctx.tree, "numpy").items():
            if orig == "random":
                npr_names.add(local)
        bare = {local for local, orig
                in from_imports(ctx.tree, "numpy.random").items()
                if orig in RNG_CONSTRUCTORS}
        findings: List[Finding] = []
        for node in ast.walk(root):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            parts = name.split(".")
            hit = (
                # np.random.default_rng(...) / numpy.random.Generator(...)
                (len(parts) >= 3 and parts[0] in np_names
                 and parts[1] == "random"
                 and parts[2] in RNG_CONSTRUCTORS)
                # nprandom.default_rng(...)
                or (len(parts) >= 2 and parts[0] in npr_names
                    and parts[1] in RNG_CONSTRUCTORS)
                # default_rng(...) via `from numpy.random import ...`
                or (len(parts) == 1 and parts[0] in bare))
            if hit:
                findings.append(Finding(
                    path=ctx.rel_path, line=node.lineno, rule=self.id,
                    message=f"fresh Generator ({name}) constructed in a "
                            f"fault/retry path; draw from the shared "
                            f"per-run Generator instead"))
        return findings

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if ctx.module is None:
            return []
        if _in_scope(ctx.module, self.module_scope, ()):
            return self._constructor_calls(ctx, ctx.tree)
        if not _in_scope(ctx.module, self.function_scope, ()):
            return []
        findings: List[Finding] = []
        for node in ast.walk(ctx.tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and self._FAULT_FUNC.search(node.name)):
                findings.extend(self._constructor_calls(ctx, node))
        return findings
