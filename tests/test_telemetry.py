"""Continuum telemetry: registry/tracer/audit units, co-sim
instrumentation, and the non-perturbation contract (control
fingerprints bit-identical with telemetry on or off)."""
import json

import numpy as np
import pytest

from repro.core import random_instance, solve_decomposed
from repro.sim.scenarios import SCENARIOS, run_scenario
from repro.telemetry import (DecisionAudit, MetricsRegistry, SpanTracer,
                             Telemetry, maybe)


# -- registry ---------------------------------------------------------------

def test_registry_basics():
    m = MetricsRegistry()
    m.counter("a.b").inc()
    m.counter("a.b").inc(2.5)
    assert m.value("a.b") == 3.5
    m.gauge("g").set(7)
    assert m.value("g") == 7.0
    assert m.value("missing", default=-1.0) == -1.0
    h = m.histogram("lat", edges=(1.0, 10.0, 100.0))
    h.observe(0.5)
    h.observe_array(np.array([5.0, 50.0, 500.0]))
    assert h.count == 4
    assert h.counts.tolist() == [1, 1, 1, 1]
    assert h.min == 0.5 and h.max == 500.0
    snap = m.snapshot()
    assert snap["counters"]["a.b"] == 3.5
    assert snap["histograms"]["lat"]["count"] == 4
    with pytest.raises(TypeError):
        m.gauge("a.b")                    # name already a counter


def test_histogram_quantile_and_edges():
    m = MetricsRegistry()
    h = m.histogram("q", edges=(10.0, 20.0, 30.0))
    h.observe_array(np.linspace(0.0, 30.0, 300))
    q50 = h.quantile(50)
    assert 10.0 <= q50 <= 20.0
    assert h.quantile(0) <= h.quantile(50) <= h.quantile(100)
    with pytest.raises(ValueError):
        m.histogram("bad", edges=(5.0, 5.0))      # non-ascending


def test_prometheus_export():
    m = MetricsRegistry()
    m.counter("requests.total").inc(3)
    m.gauge("reconfig.budget_spent").set(12.5)
    m.histogram("lat", edges=(1.0, 2.0)).observe_array(
        np.array([0.5, 1.5, 9.0]))
    text = m.to_prometheus()
    assert "repro_requests_total 3" in text
    assert "repro_reconfig_budget_spent 12.5" in text
    assert 'repro_lat_bucket{le="+Inf"} 3' in text    # cumulative
    assert "repro_lat_count 3" in text


# -- tracer -----------------------------------------------------------------

def test_tracer_spans_and_exports(tmp_path):
    tr = SpanTracer()
    tr.open(("round", 0), "round 0", 10.0, cat="round", tid=1, sid=0)
    tr.open(("round", 1), "round 1", 12.0, cat="round", tid=2)
    tr.close(("round", 0), 30.0)
    tr.close(("round", 1), 35.0)
    tr.close(("round", 99), 40.0)                 # unknown key: ignored
    tr.complete("swap", 50.0, 10.0, cat="reconfig", trigger="drift")
    tr.instant("failure", 60.0, cat="fault")
    with tr.wall("solve_decomposed.polish", cat="solver") as sp:
        pass
    assert sp.dur >= 0.0
    assert len(tr.spans) == 4 and len(tr.instants) == 1
    d = tr.durations("solve_decomposed.")
    assert set(d) == {"polish"} and d["polish"] == sp.dur
    assert [s.name for s in tr.by_cat("round")] == ["round 0", "round 1"]

    path = tmp_path / "trace.json"
    tr.write_chrome(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert [e for e in evs if e["ph"] == "M"]     # process metadata
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"round 0", "swap"}
    sw = next(e for e in xs if e["name"] == "swap")
    assert sw["ts"] == 50.0 * 1e6 and sw["dur"] == 10.0 * 1e6
    assert sw["args"]["trigger"] == "drift"
    jsonl = tmp_path / "trace.jsonl"
    tr.write_jsonl(str(jsonl))
    lines = [json.loads(l) for l in jsonl.read_text().splitlines()]
    assert len(lines) == 5
    assert {l["kind"] for l in lines} == {"span", "instant"}


def test_audit_log():
    a = DecisionAudit()
    a.record(5.0, "deployment_swap", "drift alarm", "applied",
             evidence={"mse": 0.2}, cost=10.0, charged=True)
    a.record(9.0, "deployment_swap", "windowed_p95_breach", "deferred",
             cost=10.0)
    with pytest.raises(ValueError):
        a.record(1.0, "x", "y", "not-an-outcome")
    assert len(a) == 2
    assert a.counts()["applied"] == 1 and a.counts()["deferred"] == 1
    assert [r.trigger for r in a.by_action("deployment_swap")] == \
        ["drift alarm", "windowed_p95_breach"]


def test_maybe_resolution():
    assert maybe(None) is None
    tel = Telemetry()
    assert maybe(tel) is tel
    assert maybe(Telemetry(enabled=False)) is None


# -- co-sim instrumentation -------------------------------------------------

def test_cosim_spans_and_metrics():
    tel = Telemetry()
    res = run_scenario(SCENARIOS["churn"](), "budgeted", seed=0,
                       duration_s=60.0, telemetry=tel)
    cats = {sp.cat for sp in tel.tracer.spans}
    assert {"round", "epoch", "aggregation"} <= cats
    m = tel.metrics
    assert m.value("training.rounds_completed") == res.rounds_completed
    assert m.value("requests.total") == res.n_requests
    h = m.get("request.latency_ms")
    assert h.count == res.n_requests
    # bucket-approximated p95 bounds the exact percentile
    exact = res.log.percentile_latency(95)
    lo = max((e for e in h.edges if e <= exact), default=0.0)
    hi = min((e for e in h.edges if e >= exact), default=h.max)
    assert lo - 1e-9 <= h.quantile(95) <= hi + 1e-9


def test_audit_covers_every_swap_and_budget_metrics():
    tel = Telemetry()
    res = run_scenario(SCENARIOS["churn"](), "budgeted", seed=0,
                       duration_s=120.0, telemetry=tel)
    swaps = tel.audit.by_action("deployment_swap")
    done = [r for r in swaps if r.outcome in ("applied", "forced")]
    assert len(done) == res.reclusters > 0
    for rec in done:
        assert rec.trigger            # every swap names its trigger
        assert rec.cost > 0.0
    m = tel.metrics
    assert m.value("reconfig.applied") + m.value("reconfig.forced") == \
        res.reclusters
    assert m.value("reconfig.deferred") == res.budget_vetoes
    assert m.value("reconfig.budget_spent") == pytest.approx(
        res.budget_spent)
    assert m.value("reconfig.cost_spent") == pytest.approx(
        res.budget_spent)


@pytest.mark.parametrize("scenario,policy,engine", [
    ("straggler", "reactive", "batched"),
    ("mobility", "budgeted", "batched"),
    ("multi_tenant", "static", "batched"),
    ("churn", "budgeted", "batched"),
    ("churn", "reactive", "heap"),
])
def test_telemetry_does_not_perturb(scenario, policy, engine):
    kw = dict(policy=policy, seed=0, duration_s=60.0, engine=engine)
    base = run_scenario(SCENARIOS[scenario](), **kw)
    tel = Telemetry()
    inst = run_scenario(SCENARIOS[scenario](), telemetry=tel, **kw)
    assert inst.fingerprint() == base.fingerprint()
    assert inst.control_fingerprint() == base.control_fingerprint()
    assert np.array_equal(inst.log.latency_ms, base.log.latency_ms)
    assert np.array_equal(inst.log.t, base.log.t)
    assert np.array_equal(inst.log.tier, base.log.tier)
    assert inst.actions == base.actions
    if policy != "static":
        assert len(tel.tracer.spans) > 0   # it did record something


def test_disabled_telemetry_is_free():
    from repro.sim.cosim import CoSim, CoSimConfig
    from repro.sim.scenarios import hot_zone_topology
    topo, loc, lam, r = hot_zone_topology(seed=0)
    off = Telemetry(enabled=False)
    cosim = CoSim(topo, CoSimConfig(duration_s=10.0, telemetry=off))
    assert cosim.tel is None               # resolved once, never checked
    assert cosim.proc._tel is None
    cosim2 = CoSim(topo, CoSimConfig(duration_s=10.0))
    assert cosim2.tel is None
    assert len(off.tracer.spans) == 0 and len(off.audit) == 0


# -- solver phase spans -----------------------------------------------------

def test_solver_phase_view_matches_tracer():
    inst = random_instance(300, 12, seed=0)
    tel = Telemetry()
    sol = solve_decomposed(inst, telemetry=tel)
    d = tel.tracer.durations("solve_decomposed.")
    assert set(d) == {"partition", "subsolve", "stitch", "polish"}
    for k, v in d.items():
        assert sol.meta["phase_s"][f"{k}_s"] == pytest.approx(v)
    sub = next(sp for sp in tel.tracer.spans
               if sp.name == "solve_decomposed.subsolve")
    assert sub.args["regions"] == sol.meta["regions"]
    assert all(sp.domain == "wall" for sp in tel.tracer.by_cat("solver"))


# -- benchmark registry round-trip ------------------------------------------

def test_bench_emit_registry_roundtrip(tmp_path, capsys):
    from benchmarks import common
    common.emit("telemetry_test_row", 123.4,
                "requests_per_s=1000;engine=batched")
    capsys.readouterr()
    rows = common.rows_from_registry()
    row = rows["telemetry_test_row"]
    assert row["us_per_call"] == pytest.approx(123.4)
    assert row["requests_per_s"] == 1000.0
    assert row["engine"] == "batched"
    path = tmp_path / "bench.json"
    common.write_json(str(path))
    data = json.loads(path.read_text())
    assert data["telemetry_test_row"] == row
    assert "repro_bench:telemetry_test_row:us_per_call" not in \
        common.TELEMETRY.to_prometheus()       # colons sanitized
    assert "repro_bench_telemetry_test_row_us_per_call 123.4" in \
        common.TELEMETRY.to_prometheus()


def test_telemetry_snapshot_and_facade(tmp_path):
    tel = Telemetry()
    tel.metrics.counter("c").inc()
    tel.tracer.complete("s", 0.0, 1.0)
    tel.audit.record(0.0, "a", "trig", "noted")
    snap = tel.snapshot()
    assert snap["enabled"] and snap["spans"] == 1
    assert snap["audit"]["noted"] == 1
    p = tmp_path / "snap.json"
    tel.write_snapshot(str(p))
    assert json.loads(p.read_text())["metrics"]["counters"]["c"] == 1.0
    assert "repro_c 1" in tel.to_prometheus()
