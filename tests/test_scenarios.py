"""Scenario engine + reconfiguration budget: new event kinds
(STRAGGLER / DEVICE_MOVE / TENANT_LOAD), their co-sim mechanics and
reactive-loop reactions, deterministic same-seed traces per scenario,
and the ReconfigBudget accountant metering every deployment swap."""

import numpy as np
import pytest

from repro.core.topology import ClusterTopology
from repro.fl import round_schedule
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.sim import (CoSim, CoSimConfig, EventKind, InterferenceModel,
                       ReactiveLoop, ReactivePolicy, ReconfigBudget)
from repro.sim.scenarios import (SCENARIOS, continuum_topology,
                                 default_budget_total, hot_zone_topology,
                                 mobility_scenario, random_waypoint_moves,
                                 run_scenario)


def _topo(n=8, m=4, cap=20.0, lam=1.0):
    return ClusterTopology(assign=np.arange(n) % m, n_devices=n, n_edges=m,
                           lam=np.full(n, float(lam)),
                           r=np.full(m, float(cap)), l=2)


def _one_round(epoch_s=3.0, upload_s=2.0, local_epochs=5):
    return round_schedule(rounds=1, l=2, local_epochs=local_epochs,
                          epoch_s=epoch_s, upload_s=upload_s)


def _loop_for(topo, lam=None, r=None, loc=None, **policy):
    lam = lam if lam is not None else topo.lam
    r = r if r is not None else topo.r
    loc = loc if loc is not None else topo.assign
    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=topo.l)
    ctl.deployment = Deployment.from_topology(topo)
    return ctl, ReactiveLoop(ctl, policy=ReactivePolicy(**policy))


# ---------------------------------------------------------------------------
# event-kind ordering: scenario events are state changes, they apply
# before same-instant arrivals and epoch events
# ---------------------------------------------------------------------------

def test_scenario_event_kinds_order_before_arrivals():
    for kind in (EventKind.STRAGGLER, EventKind.DEVICE_MOVE,
                 EventKind.TENANT_LOAD):
        assert kind < EventKind.EPOCH_END
        assert kind < EventKind.REQUEST_ARRIVAL


# ---------------------------------------------------------------------------
# STRAGGLER mechanics
# ---------------------------------------------------------------------------

def test_straggler_stretches_remaining_epochs():
    """Without a drop policy the straggler's pending epochs run longer:
    its last EPOCH_END lands far beyond the nominal compute window."""
    topo = _topo()
    cfg = CoSimConfig(duration_s=120.0, seed=0, rate_scale=0.0)
    plain = CoSim(topo, cfg, schedule=_one_round())
    plain_res = plain.run()
    cosim = CoSim(topo, cfg, schedule=_one_round())
    cosim.schedule_straggler(4.0, device_id=0, factor=10.0)
    res = cosim.run()
    def last_epoch_end(trace, node):
        return max(t for t, kind, n in trace
                   if kind == "EPOCH_END" and n == node)
    assert last_epoch_end(plain_res.trace, 0) <= 15.0 + 1e-9
    assert last_epoch_end(res.trace, 0) > 30.0
    # other devices keep their nominal timing
    assert last_epoch_end(res.trace, 1) == pytest.approx(
        last_epoch_end(plain_res.trace, 1))


def test_straggler_reaction_drops_device_at_deadline():
    topo = _topo()
    ctl, loop = _loop_for(topo, p95_threshold_ms=1e9)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=_one_round(), reactive=loop)
    cosim.schedule_straggler(4.0, device_id=0, factor=10.0)
    # once the in-flight epoch drains, the dropped device is idle again
    # — its cancelled 10x epochs never claim compute (without the drop
    # it would still be mid-epoch here, at device_train_share demand)
    cosim.sim.run(until=30.0)
    assert cosim.interference.demand(("device", 0)) == pytest.approx(0.0)
    res = cosim.run()
    assert len(res.drop_log) == 1
    t, dev, ridx, dropped = res.drop_log[0]
    assert dev == 0 and dropped >= 1
    assert any("dropped" in a and "partial aggregation" in a
               for _, a in res.actions)
    # and the round still completes on time (partial aggregation)
    assert res.rounds_completed == 1


def test_persistent_straggler_marked_unreliable_and_reclustered():
    """unreliable_after_drops: once a device's deadline drops reach the
    threshold it is marked ``reliable=False`` and HFLOP re-solves over
    the reliable subset — the live topology excludes it."""
    topo = _topo()
    ctl, loop = _loop_for(topo, p95_threshold_ms=1e9,
                          unreliable_after_drops=1)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=_one_round(), reactive=loop)
    cosim.schedule_straggler(4.0, device_id=0, factor=10.0)
    res = cosim.run()
    assert not ctl.inventory.devices[0].reliable
    assert ctl.recluster_count >= 1
    assert cosim.proc.topo.assign[0] == -1
    # everyone else still participates
    assert int(np.sum(cosim.proc.topo.assign >= 0)) == topo.n_devices - 1
    assert any("unreliable" in a and "re-clustered" in a
               for _, a in res.actions)
    # the expanded solution records how many devices it was solved over
    assert ctl.solution.meta["reliable_devices"] == topo.n_devices - 1


def test_unreliable_mark_deferred_on_spent_budget():
    """A spent reconfig budget defers the re-deploy but still records
    the unreliable mark — the stale topology keeps serving, and any
    later recluster excludes the device."""
    topo = _topo()
    ctl, loop = _loop_for(topo, p95_threshold_ms=1e9,
                          unreliable_after_drops=1)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=_one_round(), reactive=loop,
                  budget=ReconfigBudget(total=0.0))
    cosim.schedule_straggler(4.0, device_id=0, factor=10.0)
    res = cosim.run()
    assert not ctl.inventory.devices[0].reliable
    assert cosim.proc.topo.assign[0] >= 0       # swap deferred
    assert any("unreliable" in a and "deferred" in a
               for _, a in res.actions)
    # a later (budget-permitting) recluster picks the mark up
    cosim.budget = None
    dep = ctl.deploy()
    assert dep.topology.assign[0] == -1


def test_unreliable_off_by_default():
    """The default policy never marks devices unreliable — drops alone
    must not change the inventory."""
    topo = _topo()
    ctl, loop = _loop_for(topo, p95_threshold_ms=1e9)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=_one_round(), reactive=loop)
    cosim.schedule_straggler(4.0, device_id=0, factor=10.0)
    res = cosim.run()
    assert len(res.drop_log) == 1
    assert all(d.reliable for d in ctl.inventory.devices)
    assert ctl.recluster_count == 0


def test_straggler_without_pending_epochs_is_noop():
    """A straggle landing in the upload window (all epochs finished)
    must not reschedule anything."""
    topo = _topo()
    cfg = CoSimConfig(duration_s=40.0, seed=0, rate_scale=0.0)
    plain = CoSim(topo, cfg, schedule=_one_round()).run()
    cosim = CoSim(topo, cfg, schedule=_one_round())
    cosim.schedule_straggler(16.0, device_id=0, factor=10.0)  # upload window
    res = cosim.run()
    assert [r for r in res.trace if r[1].startswith("EPOCH")] == \
        [r for r in plain.trace if r[1].startswith("EPOCH")]


# ---------------------------------------------------------------------------
# DEVICE_MOVE mechanics
# ---------------------------------------------------------------------------

def test_device_move_rehomes_requests_and_pays_handover():
    topo = _topo()
    cfg = CoSimConfig(duration_s=30.0, seed=0)
    cosim = CoSim(topo, cfg, schedule=_one_round())
    cosim.schedule_device_move(10.0, device_id=0, new_edge=2)
    res = cosim.run()
    assert int(cosim.proc.topo.assign[0]) == 2
    assert res.move_log == [(10.0, 0, 0, 2)]
    # the handover interference on the receiving edge was cleared at
    # the end of the handover window (via the TENANT_LOAD mechanism)
    assert (10.0 + cfg.handover_s, 2, "handover:0", 0.0) in cosim.tenant_log
    assert cosim.interference.demand(("edge", 2)) == pytest.approx(0.0)
    # and the run differs from the move-free one
    plain = CoSim(topo, cfg, schedule=_one_round())
    assert not np.array_equal(plain.run().log.latency_ms,
                              res.log.latency_ms)


def test_device_move_updates_inventory_and_reclusters():
    topo, loc, lam, r = hot_zone_topology(seed=0)
    ctl, loop = _loop_for(topo, lam=lam, r=r, loc=loc,
                          p95_threshold_ms=1e9, cooldown_s=0.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=30.0, seed=0),
                  reactive=loop)
    cosim.schedule_device_move(10.0, device_id=7, new_edge=0)
    res = cosim.run()
    assert ctl.inventory.devices[7].lan_edge == 0
    assert ctl.recluster_count == 1
    assert any("handed over" in a for _, a in res.actions)
    assert any("re-clustered around device 7" in a for _, a in res.actions)


def test_device_move_to_unknown_edge_raises():
    topo = _topo()
    cosim = CoSim(topo, CoSimConfig(duration_s=10.0, seed=0))
    cosim.schedule_device_move(1.0, device_id=0, new_edge=99)
    with pytest.raises(ValueError, match="unknown edge"):
        cosim.run()


def test_pending_move_survives_failure_renumbering():
    """A DEVICE_MOVE scheduled before a failure-driven recluster names
    its target by the old numbering; after the topology shrinks it must
    land on the same physical host (regression: it used to raise or
    silently re-home to the wrong edge)."""
    topo, loc, lam, r = hot_zone_topology(seed=0, slack=1.8)
    ctl, loop = _loop_for(topo, lam=lam, r=r, loc=loc,
                          p95_threshold_ms=1e9, cooldown_s=0.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=40.0, seed=0),
                  reactive=loop)
    cosim.schedule_failure(10.0, edge_id=0)      # edges renumber: 1..3->0..2
    cosim.schedule_device_move(20.0, device_id=2, new_edge=3)
    cosim.schedule_device_move(25.0, device_id=3, new_edge=0)  # dead host
    res = cosim.run()
    # old edge 3 is topology edge 2 after the recluster
    moved = [e for t, i, old, e in res.move_log if i == 2]
    assert moved == [2]
    assert int(cosim.proc.topo.assign[2]) == 2
    # the move to the dead host was abandoned, not crashed/misrouted
    assert not any(i == 3 for _, i, _, _ in res.move_log)


def test_deferred_highest_edge_failure_still_remaps_alias():
    """Dropping the HIGHEST-numbered edge under a deferred re-deploy
    leaves {0:0,1:1,2:2} in the edge mapping — which must not read as
    identity: once a later recluster applies the renumbered topology,
    events naming the dead edge must resolve to 'gone', not crash."""
    topo, loc, lam, r = hot_zone_topology(seed=0, slack=2.0)
    ctl, loop = _loop_for(topo, lam=lam, r=r, loc=loc,
                          p95_threshold_ms=1e9, cooldown_s=0.0,
                          budget_exempt_failures=False)
    cosim = CoSim(topo, CoSimConfig(duration_s=40.0, seed=0),
                  reactive=loop, budget=ReconfigBudget(total=0.0))
    cosim.schedule_failure(5.0, edge_id=3)       # deferred (budget 0)
    # this move's recluster applies the renumbered 3-edge topology...
    cosim.schedule_device_move(15.0, device_id=1, new_edge=2)
    # ...and this one then names the dead edge: abandon, don't crash
    cosim.schedule_device_move(25.0, device_id=0, new_edge=3)
    cosim.sim.run(until=10.0)
    assert len(ctl.inventory.edges) == 3         # renumbered, topo stale
    cosim.budget = None                          # budget frees up
    res = cosim.run()
    assert cosim.proc.topo.n_edges == 3
    assert cosim.edge_alias[3] is None
    assert any(i == 1 for _, i, _, _ in res.move_log)
    assert not any(i == 0 for _, i, _, _ in res.move_log)


def test_repeated_handover_keeps_newer_window():
    """A second handover before the first window closes supersedes it:
    the first clear must not strip the second's edge load early."""
    topo = _topo()
    cfg = CoSimConfig(duration_s=30.0, seed=0, rate_scale=0.0)
    cosim = CoSim(topo, cfg)
    cosim.schedule_device_move(10.0, device_id=0, new_edge=2)
    cosim.schedule_device_move(11.0, device_id=0, new_edge=3)
    share = cfg.interference.handover_share
    cosim.sim.run(until=10.5)
    assert cosim.interference.demand(("edge", 2)) == pytest.approx(share)
    cosim.sim.run(until=11.5)                    # superseded: load moved
    assert cosim.interference.demand(("edge", 2)) == pytest.approx(0.0)
    assert cosim.interference.demand(("edge", 3)) == pytest.approx(share)
    cosim.sim.run(until=13.5)                    # first clear is stale
    assert cosim.interference.demand(("edge", 3)) == pytest.approx(share)
    cosim.sim.run(until=14.5)                    # second window expires
    assert cosim.interference.demand(("edge", 3)) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# TENANT_LOAD mechanics
# ---------------------------------------------------------------------------

def test_tenant_load_stretches_edge_service_and_expires():
    topo = _topo(cap=6.0, lam=2.0)
    cfg = CoSimConfig(duration_s=40.0, seed=0)
    sched = _one_round(epoch_s=6.0)              # devices busy -> R1 offload
    plain = CoSim(topo, cfg, schedule=sched).run()
    cosim = CoSim(topo, cfg, schedule=sched)
    for j in range(topo.n_edges):
        cosim.schedule_tenant_load(2.0, j, share=0.9, duration_s=35.0,
                                   tenant=f"job{j}")
    res = cosim.run()
    assert res.log.mean_latency() > plain.log.mean_latency()
    # every job expired: last logged share per source is 0
    final = {}
    for t, j, src, share in cosim.tenant_log:
        final[(j, src)] = share
    assert all(v == 0.0 for v in final.values())


def test_tenant_demand_survives_redeploy():
    """apply_deployment rebuilds the edge tier but must not evict
    third-party tenant load — it is external to the training pipeline."""
    m = InterferenceModel()
    m.set_demand(("edge", 0), "tenant:ext", 0.4)
    m.set_demand(("edge", 0), "agg0:1", 0.6)
    m.clear_tier("edge", keep_prefixes=("tenant:", "handover:"))
    assert m.demand(("edge", 0)) == pytest.approx(0.4)
    m.remap_tier("edge", lambda j: j - 1 if j > 0 else None)
    assert m.demand(("edge", 0)) == pytest.approx(0.0)


def test_remap_tier_moves_demand_to_new_ids():
    m = InterferenceModel()
    m.set_demand(("edge", 2), "tenant:a", 0.3)
    m.set_demand(("edge", 0), "tenant:b", 0.2)
    m.remap_tier("edge", lambda j: None if j == 0 else j - 1)
    assert m.demand(("edge", 1)) == pytest.approx(0.3)
    assert m.demand(("edge", 0)) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# ReconfigBudget accountant
# ---------------------------------------------------------------------------

def test_budget_charges_and_vetoes():
    b = ReconfigBudget(total=15.0)
    assert b.charge(1.0, 10.0, "first")          # affordable
    assert not b.charge(2.0, 10.0, "second")     # vetoed: only 5 left
    assert b.spent == pytest.approx(10.0)
    assert b.remaining == pytest.approx(5.0)
    assert b.charge(3.0, 10.0, "forced", forced=True)  # overruns visibly
    assert b.spent == pytest.approx(20.0)
    assert b.remaining == 0.0
    assert b.reconfigs == 2 and b.vetoes == 1
    assert [e.applied for e in b.ledger] == [True, False, True]


def test_apply_deployment_vetoed_leaves_topology_untouched():
    topo, loc, lam, r = hot_zone_topology(seed=0)
    budget = ReconfigBudget(total=0.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=10.0, seed=0),
                  budget=budget)
    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=2)
    dep = ctl.deploy()
    before = cosim.proc.topo
    assert cosim.apply_deployment(dep) is False
    assert cosim.proc.topo is before
    assert cosim.reconfig_times == []
    assert budget.vetoes == 1 and budget.spent == 0.0


def test_budgeted_policy_spends_at_most_budget_and_defers():
    sc = SCENARIOS["mobility"]()
    unconstrained = run_scenario(sc, policy="reactive", seed=0,
                                 duration_s=60.0)
    capped = run_scenario(sc, policy="budgeted", seed=0, duration_s=60.0,
                          budget_total=10.0)   # one migration's worth
    assert capped.budget_spent <= capped.budget_total + 1e-9
    assert capped.budget_vetoes >= 1
    assert capped.reclusters < unconstrained.reclusters
    assert any("deferred" in a for _, a in capped.actions)


def test_budget_exempt_failure_forces_through_spent_budget():
    topo, loc, lam, r = hot_zone_topology(seed=0, slack=1.8)
    ctl, loop = _loop_for(topo, lam=lam, r=r, loc=loc,
                          p95_threshold_ms=1e9)
    budget = ReconfigBudget(total=0.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=40.0, seed=0),
                  reactive=loop, budget=budget)
    cosim.schedule_failure(15.0, edge_id=0)
    res = cosim.run()
    assert ctl.recluster_count == 1              # went through regardless
    assert len(res.reconfig_times) == 1
    assert budget.spent > budget.total           # overrun is visible
    assert [e.forced for e in budget.ledger if e.applied] == [True]


# ---------------------------------------------------------------------------
# solver-produced continuum feeding the scenario grid
# ---------------------------------------------------------------------------

def test_continuum_topology_is_solver_feasible():
    """The decomposed solver's deployment seeds the scenario grid: all
    devices participate, loads respect capacities, and the build is
    deterministic."""
    topo, loc, lam, r = continuum_topology(seed=3, n=120, m=6)
    assert topo.participant_count() == 120
    loads = np.bincount(topo.assign[topo.assign >= 0],
                        weights=lam[topo.assign >= 0], minlength=6)
    assert np.all(loads <= r + 1e-9)
    topo2, loc2, _, _ = continuum_topology(seed=3, n=120, m=6)
    assert np.array_equal(topo.assign, topo2.assign)
    assert np.array_equal(loc, loc2)


def test_run_scenario_accepts_prebuilt_topology():
    """run_scenario(topology=...) swaps the hot-zone continuum for a
    solver-produced one; same-seed runs stay reproducible."""
    cont = continuum_topology(seed=0, n=60, m=4)
    res = run_scenario(SCENARIOS["straggler"](), policy="reactive",
                       seed=0, duration_s=40.0, topology=cont)
    assert res.n_requests > 0 and res.rounds_completed >= 1
    rerun = run_scenario(SCENARIOS["straggler"](), policy="reactive",
                         seed=0, duration_s=40.0,
                         topology=continuum_topology(seed=0, n=60, m=4))
    assert res.fingerprint() == rerun.fingerprint()


# ---------------------------------------------------------------------------
# determinism: every scenario x policy cell reproduces its trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["straggler", "mobility", "multi_tenant",
                                  "churn"])
def test_scenario_traces_deterministic_per_seed(name):
    sc = SCENARIOS[name]()
    for policy in ("static", "budgeted"):
        a = run_scenario(sc, policy=policy, seed=3, duration_s=45.0)
        b = run_scenario(sc, policy=policy, seed=3, duration_s=45.0)
        assert a.fingerprint() == b.fingerprint()
        assert a.trace == b.trace
    # a different seed genuinely changes the run
    c = run_scenario(sc, policy="budgeted", seed=4, duration_s=45.0)
    assert c.fingerprint() != a.fingerprint()


def test_mixed_event_kinds_deterministic_trace():
    """One co-sim with every scenario event kind on the timeline still
    reproduces bit-for-bit."""
    def once():
        # slack high enough that the post-failure, post-derate instance
        # stays feasible for the surviving three edges
        topo, loc, lam, r = hot_zone_topology(seed=1, slack=2.2)
        ctl, loop = _loop_for(topo, lam=lam, r=r, loc=loc,
                              p95_threshold_ms=25.0)
        cosim = CoSim(topo, CoSimConfig(duration_s=50.0, seed=1),
                      schedule=round_schedule(rounds=2, l=2, local_epochs=5,
                                              epoch_s=3.5, upload_s=2.0,
                                              gap_s=2.0),
                      reactive=loop, budget=ReconfigBudget(total=30.0))
        cosim.schedule_straggler(5.0, 0, 6.0)
        cosim.schedule_device_move(12.0, 7, 0)
        cosim.schedule_tenant_load(8.0, 1, 0.5, duration_s=15.0)
        cosim.schedule_drift(20.0)
        cosim.schedule_failure(35.0, edge_id=2)
        res = cosim.run()
        return res, ctl
    a, ctl_a = once()
    b, ctl_b = once()
    assert a.trace == b.trace
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
    assert a.actions == b.actions
    assert ctl_a.recluster_count == ctl_b.recluster_count
    assert [(e.t, e.cost, e.applied) for e in a.budget.ledger] == \
        [(e.t, e.cost, e.applied) for e in b.budget.ledger]


def test_budget_capped_recovers_fraction_of_gain():
    """The acceptance claim: the budgeted policy spends <= its budget
    and still recovers a positive fraction of the unconstrained
    policy's p95 gain over static."""
    sc = SCENARIOS["mobility"]()
    st = run_scenario(sc, policy="static", seed=0, duration_s=120.0)
    rx = run_scenario(sc, policy="reactive", seed=0, duration_s=120.0)
    bd = run_scenario(sc, policy="budgeted", seed=0, duration_s=120.0,
                      budget_total=default_budget_total())
    gain = st.p95 - rx.p95
    assert gain > 0
    assert bd.budget_spent <= bd.budget_total + 1e-9
    assert (st.p95 - bd.p95) / gain > 0.5


def test_random_waypoint_moves_deterministic():
    """Same seed -> bit-identical trace; the generator draws only from
    its own default_rng stream (contract DET001)."""
    a = random_waypoint_moves(20, 4, 120.0, seed=11)
    b = random_waypoint_moves(20, 4, 120.0, seed=11)
    c = random_waypoint_moves(20, 4, 120.0, seed=12)
    assert a == b
    assert a != c
    assert a == sorted(a)
    assert all(0.0 <= t <= 120.0 and 0 <= dev < 20 and 0 <= edge < 4
               for t, dev, edge in a)
    # consecutive moves of one device always change its edge
    last = {}
    for _t, dev, edge in a:
        assert last.get(dev) != edge
        last[dev] = edge


def test_random_waypoint_moves_edge_cases():
    assert random_waypoint_moves(0, 4, 60.0) == []
    assert random_waypoint_moves(10, 0, 60.0) == []
    assert random_waypoint_moves(10, 4, 0.0) == []
    # single edge: association can never change
    assert random_waypoint_moves(10, 1, 60.0, seed=5) == []


def test_random_waypoint_trace_runs_in_cosim():
    """A generated trace drives the mobility scenario end to end and
    stays deterministic through the full co-sim."""
    moves = random_waypoint_moves(20, 4, 90.0, seed=2,
                                  speed=(0.01, 0.04), pause_s=2.0)
    assert moves, "trace should contain at least one handover"
    sc = mobility_scenario(moves=moves)
    a = run_scenario(sc, policy="reactive", seed=0, duration_s=90.0)
    b = run_scenario(sc, policy="reactive", seed=0, duration_s=90.0)
    assert a.moves == len(moves)
    assert a.fingerprint() == b.fingerprint()
