"""Hypothesis property-based tests on system invariants.

``hypothesis`` is an optional dev dependency (declared in
pyproject.toml's ``dev`` extra); skip cleanly where it isn't installed."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (HFLOPInstance, is_feasible, objective,
                        solve_bruteforce, solve_greedy, solve_heuristic)
from repro.fl.compression import dequantize_int8, quantize_int8
import jax.numpy as jnp


@st.composite
def instances(draw, max_n=7, max_m=3):
    n = draw(st.integers(2, max_n))
    m = draw(st.integers(1, max_m))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    c_d = rng.uniform(0, 1, (n, m))
    c_e = rng.uniform(0.1, 2, m)
    lam = rng.uniform(0.1, 1, n)
    slack = draw(st.floats(1.05, 3.0))
    raw = rng.uniform(0.5, 1.5, m)
    r = raw / raw.sum() * lam.sum() * slack
    T = draw(st.one_of(st.none(), st.integers(1, n)))
    return HFLOPInstance(c_d, c_e, lam, r, l=draw(st.integers(1, 4)), T=T)


@settings(max_examples=25, deadline=None)
@given(instances())
def test_heuristic_always_feasible_or_inf(inst):
    sol = solve_heuristic(inst)
    if np.isfinite(sol.cost):
        assert is_feasible(inst, sol.assign)
        assert sol.cost == objective(inst, sol.assign)


@settings(max_examples=15, deadline=None)
@given(instances(max_n=6, max_m=2))
def test_heuristic_never_beats_bruteforce(inst):
    bf = solve_bruteforce(inst)
    h = solve_heuristic(inst)
    if np.isfinite(bf.cost) and np.isfinite(h.cost):
        assert h.cost >= bf.cost - 1e-9


@settings(max_examples=25, deadline=None)
@given(instances())
def test_objective_scale_invariance(inst):
    """Scaling all costs by a>0 scales the optimum by a."""
    h = solve_greedy(inst)
    if not np.isfinite(h.cost):
        return
    scaled = HFLOPInstance(inst.c_d * 3.0, inst.c_e * 3.0, inst.lam,
                           inst.r, l=inst.l, T=inst.T)
    assert objective(scaled, h.assign) == (
        3.0 * objective(inst, h.assign)) or True
    np.testing.assert_allclose(objective(scaled, h.assign),
                               3.0 * objective(inst, h.assign), rtol=1e-9)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False), min_size=1,
                max_size=64))
def test_quantize_error_bound(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 20), st.integers(1, 4))
def test_costmodel_monotonic_in_rounds(seed, n, m):
    from repro.core import flat_fl_cost
    a = flat_fl_cost(n, 10)
    b = flat_fl_cost(n, 20)
    assert b.metered_bytes == 2 * a.metered_bytes


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 6),                    # slots
       st.floats(5.0, 80.0),                 # base service ms
       st.floats(0.75, 1.25),                # load vs the occupancy knee
       st.integers(0, 10_000),               # arrival seed
       st.integers(0, 12))                   # carried-over pending count
def test_occupancy_replay_boundary_property(slots, base_ms, load, seed,
                                            n_pend):
    """Property fuzz of the oversubscription boundary: with the offered
    load hovering at the occupancy knee (occupancy grazing ``slots``),
    the vectorized calibrated replay must stay bit-identical to the
    scalar per-request recursion — services AND carried pending state."""
    import heapq
    from repro.routing import CalibratedLatencyModel
    from repro.sim.request_plane import occupancy_replay

    lat = CalibratedLatencyModel(tier_service_ms={"edge": base_ms},
                                 tier_slots={"edge": slots})
    fn = lambda occ: lat.infer_ms("edge", occupancy=occ)  # noqa: E731
    rng = np.random.default_rng(seed)
    rate = slots / (base_ms / 1000.0) * load
    t = np.cumsum(rng.exponential(1.0 / rate, size=400))
    pend = np.sort(rng.uniform(0.0, float(t[min(20, t.size - 1)]),
                               size=n_pend))
    got_s, got_p = occupancy_replay(t, pend, base_ms, float(slots), fn)
    svc = np.empty(t.size)
    heap = pend.tolist()
    heapq.heapify(heap)
    for k, tk in enumerate(t):
        while heap and heap[0] <= tk:
            heapq.heappop(heap)
        s = fn(len(heap))
        svc[k] = s
        heapq.heappush(heap, tk + s / 1000.0)
    assert np.array_equal(got_s, svc)
    assert np.array_equal(got_p, np.sort(np.asarray(heap)))


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0.0, 1e4, allow_nan=False), min_size=0,
                max_size=200),
       st.integers(1, 5))                    # number of bulk chunks
def test_histogram_bulk_equals_scalar(vals, chunks):
    """Bulk columnar recording (``observe_array``) must be exactly
    equivalent to scalar ``observe`` per element: identical bucket
    counts / count / min / max (integer arithmetic and the same
    ``searchsorted`` semantics), and the float ``sum`` equal up to
    add-order rounding."""
    from repro.telemetry import MetricsRegistry

    bulk = MetricsRegistry().histogram("h")
    scalar = MetricsRegistry().histogram("h")
    arr = np.asarray(vals, np.float64)
    for part in np.array_split(arr, chunks):
        bulk.observe_array(part)
    for v in arr:
        scalar.observe(v)
    assert np.array_equal(bulk.counts, scalar.counts)
    assert bulk.count == scalar.count == arr.size
    if arr.size:
        assert bulk.min == scalar.min and bulk.max == scalar.max
        np.testing.assert_allclose(bulk.sum, scalar.sum, rtol=1e-12)
        assert bulk.quantile(95) == pytest.approx(scalar.quantile(95))
    assert bulk.snapshot()["buckets"] == scalar.snapshot()["buckets"]


# ---------------------------------------------------------------------------
# fault plane: heap/batched retry-schedule parity under chaos
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(0, 1000), st.floats(4.0, 30.0), st.floats(1.0, 8.0),
       st.floats(0.0, 0.5))
def test_fault_retry_schedule_engine_parity(seed, mttf, mttr, p_drop):
    """Under any composed fault plan the batched engine's retry
    schedule — every REQUEST_RETRY / FAULT_* instant in the control
    trace — and the resulting request log are bit-identical to the
    heap engine's."""
    from repro.sim.faults import DropBurstPlan, EdgeOutagePlan
    from repro.sim.scenarios import outage_scenario, run_scenario

    plan = (EdgeOutagePlan(mttf_s=mttf, mttr_s=mttr, edges=(0, 1))
            + DropBurstPlan(p_drop=p_drop, every_s=8.0, burst_s=3.0,
                            edges=(2,)))
    def run(engine):
        return run_scenario(outage_scenario(plan=plan), policy="static",
                            seed=seed, duration_s=12.0, engine=engine)

    a, b = run("batched"), run("heap")
    assert a.control_fingerprint() == b.control_fingerprint()
    assert np.array_equal(a.log.t, b.log.t)
    assert np.array_equal(a.log.tier, b.log.tier)
    assert np.array_equal(a.log.rule, b.log.rule)
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 200), st.integers(0, 40), st.integers(1, 37))
def test_retry_at_exact_admission_instant_parity(seed, i0, stride):
    """Boundary fuzz: open a partition at the EXACT instant of a real
    arrival and size the (jitter-free) backoff so retries land at the
    EXACT instant of a later arrival — same-instant
    retry-vs-admission ordering must resolve identically in both
    engines (retries are late control events: arrivals at t serve
    first)."""
    from hypothesis import assume
    from repro.sim.scenarios import Scenario, run_scenario
    from repro.sim.faults import PartitionPlan
    from repro.sim.request_plane import RetryPolicy

    base = run_scenario(Scenario("probe", "", lambda c: None),
                        policy="static", seed=seed, duration_s=8.0)
    ts = np.unique(base.log.t)
    assume(ts.size > 64)
    k0 = i0 % (ts.size - 50)
    t0 = float(ts[k0])
    t1 = float(ts[k0 + 40])            # window spans ~40 arrival instants
    gap = float(ts[(i0 + stride) % ts.size] - t0)
    assume(gap > 1e-6)
    plan = PartitionPlan(windows_s=((t0, t1),))   # every edge partitioned
    pol = RetryPolicy(timeout_s=64.0, base_backoff_s=gap,
                      backoff_cap_s=64.0, max_attempts=3, jitter=0.0)

    def inject(cosim):
        cosim.schedule_faults(plan, retry=pol, standby=False)

    def run(engine):
        return run_scenario(Scenario("edgecase", "", inject),
                            policy="static", seed=seed, duration_s=8.0,
                            engine=engine)

    a, b = run("batched"), run("heap")
    assert a.control_fingerprint() == b.control_fingerprint()
    assert np.array_equal(a.log.t, b.log.t)
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
