"""Fused GRU sequence Pallas kernel — the paper's own model (traffic GRU)
is the inference payload of the whole orchestration scheme, so its cell
is the per-request hot loop on device/edge replicas.

The input projection x@W_x+b is a single big matmul done OUTSIDE the
kernel (MXU-friendly); the kernel runs the sequential recurrence with the
hidden state resident in VMEM, fusing the three gate nonlinearities and
the h@W_h matmul per step.  Grid: (B/bb,) batch blocks."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gru_kernel(xw_ref, h0_ref, wh_ref, o_ref, h_ref, *, T: int, h: int):
    h_ref[...] = h0_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)

    def step(t, _):
        xt = xw_ref[:, t, :].astype(jnp.float32)      # (bb, 3h)
        hw = jnp.dot(h_ref[...], wh,
                     preferred_element_type=jnp.float32)
        xr, xz, xn = xt[:, :h], xt[:, h:2 * h], xt[:, 2 * h:]
        hr, hz, hn = hw[:, :h], hw[:, h:2 * h], hw[:, 2 * h:]
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        h2 = (1.0 - z) * n + z * h_ref[...]
        h_ref[...] = h2
        o_ref[:, t, :] = h2.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, T, step, 0)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def gru_seq(xw: jax.Array, h0: jax.Array, w_h: jax.Array, *, bb: int = 8,
            interpret: bool = True) -> jax.Array:
    """xw (B,T,3h) precomputed input projection; h0 (B,h); w_h (h,3h).
    Returns hidden states (B,T,h)."""
    B, T, h3 = xw.shape
    h = h3 // 3
    bb = min(bb, B)
    assert B % bb == 0
    kernel = functools.partial(_gru_kernel, T=T, h=h)
    return pl.pallas_call(
        kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, T, 3 * h), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, h), lambda i: (i, 0)),
            pl.BlockSpec((h, 3 * h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, T, h), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, h), xw.dtype),
        scratch_shapes=[pltpu.VMEM((bb, h), jnp.float32)],
        interpret=interpret,
    )(xw, h0, w_h)
