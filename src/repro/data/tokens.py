"""Synthetic token pipeline for the LM-scale architectures: deterministic
per-shard streams with a Zipfian unigram mixture + local n-gram structure
so losses actually decrease during smoke training."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, vocab + 1), a)
    return w / w.sum()


class TokenStream:
    """Infinite deterministic stream of (tokens, labels) batches."""

    def __init__(self, cfg: TokenStreamConfig, shard: int = 0,
                 num_shards: int = 1):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed * 9973 + shard)
        self.probs = _zipf_probs(min(cfg.vocab_size, 50_000), cfg.zipf_a)
        self.vocab_eff = self.probs.shape[0]

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        base = self.rng.choice(self.vocab_eff, (c.batch_size, c.seq_len + 1),
                               p=self.probs)
        # inject copy structure: second half repeats the first half shifted
        half = (c.seq_len + 1) // 2
        base[:, half:2 * half] = base[:, :half]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
