from repro.fl.aggregation import cluster_fedavg, fedavg, global_fedavg
from repro.fl.client import (ClientBatch, eval_clients, stack_clients,
                             train_clients_locally, unstack_client)
from repro.fl.collectives import (cluster_divergence, cluster_slice,
                                  flat_allreduce, global_sync,
                                  hierarchical_allreduce,
                                  stack_for_clusters)
from repro.fl.compression import (EFState, compressed_global_sync,
                                  dequantize_int8, init_ef_state,
                                  quantize_int8, sync_bytes)
from repro.fl.hierarchy import (ContinualHFL, HFLResult, HFLRunConfig,
                                RoundWindow, continuous_vs_static,
                                round_schedule)

__all__ = [
    "cluster_fedavg", "fedavg", "global_fedavg", "ClientBatch",
    "eval_clients", "stack_clients", "train_clients_locally",
    "unstack_client", "cluster_divergence", "cluster_slice",
    "flat_allreduce", "global_sync", "hierarchical_allreduce",
    "stack_for_clusters", "EFState", "compressed_global_sync",
    "dequantize_int8", "init_ef_state", "quantize_int8", "sync_bytes",
    "ContinualHFL", "HFLResult", "HFLRunConfig", "RoundWindow",
    "continuous_vs_static", "round_schedule",
]
