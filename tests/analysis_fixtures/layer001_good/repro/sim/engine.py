"""Fixture: protected sim module keeping jax out of import time."""
from typing import TYPE_CHECKING

if TYPE_CHECKING:
    from repro.trainer import train_step


def run(params, batch):
    from repro.trainer import train_step   # function-local: non-eager
    return train_step(params, batch)
