"""shard_map hierarchical aggregation on a real multi-device (host) mesh.
Runs in a subprocess so the 8-device XLA flag never leaks into the other
tests (dryrun.py owns the 512-device flag)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.fl.collectives import (flat_allreduce, global_sync,
                                      hierarchical_allreduce,
                                      stack_for_clusters)
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    x = jnp.arange(8.0)
    xs = jax.device_put(x, NamedSharding(mesh, P(("data",))))
    # local-only reduce: mean over data axis
    local = hierarchical_allreduce(xs, mesh, do_global=False)
    # full hierarchical reduce
    both = hierarchical_allreduce(xs, mesh, do_global=True)
    flat = flat_allreduce(jax.device_put(x, NamedSharding(mesh,
                                         P(("pod", "data")))), mesh)
    # x has 8 elements over data(2): shards [0..3],[4..7]; psum over data
    # sums shard-wise -> mean of the two shards
    expect_local = (x[:4] + x[4:]) / 2
    np.testing.assert_allclose(np.asarray(local), np.asarray(expect_local))
    # global: dim 0 co-sharded over (data, pod) -> mean of the 4 blocks
    expect_both = x.reshape(4, 2)
    np.testing.assert_allclose(np.asarray(both),
                               np.asarray(expect_both).mean(axis=0))
    # flat over pod+data: 4 shards of 2
    xf = x.reshape(4, 2)
    np.testing.assert_allclose(np.asarray(flat), xf.mean(axis=0))

    # cluster-replica global_sync on a pod-sharded leading dim
    params = {"w": jnp.ones((4, 4))}
    stacked = stack_for_clusters(params, 2)
    stacked = jax.tree.map(lambda t: t + jnp.arange(2.0)[:, None, None],
                           stacked)
    sh = NamedSharding(mesh, P("pod"))
    stacked = jax.tree.map(lambda t: jax.device_put(t, sh), stacked)
    synced = jax.jit(global_sync)(stacked)
    np.testing.assert_allclose(np.asarray(synced["w"][0]),
                               np.asarray(synced["w"][1]))
    np.testing.assert_allclose(np.asarray(synced["w"][0]),
                               np.ones((4, 4)) + 0.5)
    # the pod-axis collective actually appears in the lowered program
    txt = jax.jit(global_sync).lower(stacked).compile().as_text()
    assert ("all-reduce" in txt) or ("all-gather" in txt), "no collective!"
    print("MULTIDEVICE_OK")
""")


def test_hierarchical_allreduce_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIDEVICE_OK" in out.stdout


SCRIPT_SM = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.fl.collectives import (global_sync_shardmap,
                                      make_hfl_local_step_shardmap)
    from repro.fl.compression import (EFState,
                                      compressed_global_sync_shardmap,
                                      init_ef_state)
    mesh = jax.make_mesh((2, 2, 2), ("cluster", "data", "model"))
    sh = NamedSharding(mesh, P("cluster"))
    rng = np.random.default_rng(0)

    # shard_map local step: per-cluster SGD on different data
    def base(p, o, b):
        loss, g = jax.value_and_grad(
            lambda w: jnp.mean((b["x"] @ w - b["y"]) ** 2))(p["w"])
        return {"w": p["w"] - 0.1 * g}, o, loss

    stepped = make_hfl_local_step_shardmap(base, mesh)
    p = {"w": jax.device_put(jnp.ones((2, 4)), sh)}
    o = jax.device_put(jnp.zeros((2,)), sh)
    b = {"x": jax.device_put(jnp.asarray(rng.normal(size=(2, 8, 4)),
                                         jnp.float32), sh),
         "y": jax.device_put(jnp.asarray(rng.normal(size=(2, 8)),
                                         jnp.float32), sh)}
    p2, _, losses = jax.jit(stepped)(p, o, b)
    assert losses.shape == (2,)
    # clusters trained on different data -> diverged replicas
    assert not np.allclose(np.asarray(p2["w"][0]), np.asarray(p2["w"][1]))
    # no cross-cluster collective in the local step
    txt = jax.jit(stepped).lower(p, o, b).compile().as_text()
    from repro.launch.roofline import collective_stats
    st = collective_stats(txt, pod_size=4)   # 4 devices per cluster here
    assert st.cross_pod_bytes == 0, st.bytes_by_kind

    # global sync equalizes
    p3 = jax.jit(lambda q: global_sync_shardmap(q, mesh))(p2)
    np.testing.assert_allclose(np.asarray(p3["w"][0]),
                               np.asarray(p3["w"][1]), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(p3["w"][0]),
        np.asarray(p2["w"]).mean(axis=0), rtol=1e-5)

    # int8-on-the-wire sync: anchor = params at last sync (pre-divergence)
    ef = init_ef_state(p)
    p4, ef2 = jax.jit(lambda q, e: compressed_global_sync_shardmap(
        q, e, mesh))(p2, ef)
    np.testing.assert_allclose(np.asarray(p4["w"][0]),
                               np.asarray(p4["w"][1]), rtol=1e-6)
    err = np.abs(np.asarray(p4["w"][0]) - np.asarray(p2["w"]).mean(0))
    assert err.max() < 0.01

    # fully-manual variant (local shards on the wire) agrees too
    from repro.fl.compression import compressed_global_sync_manual
    specs = [P("cluster", "data")]
    p5, _ = jax.jit(lambda q, e: compressed_global_sync_manual(
        q, e, mesh, specs))(jax.device_put(
            p2, NamedSharding(mesh, P("cluster", "data"))),
        init_ef_state(p))
    np.testing.assert_allclose(np.asarray(p5["w"][0]),
                               np.asarray(p5["w"][1]), rtol=1e-6)
    err5 = np.abs(np.asarray(p5["w"][0]) - np.asarray(p2["w"]).mean(0))
    assert err5.max() < 0.02
    print("SHARDMAP_HFL_OK")
""")


def test_hfl_shardmap_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT_SM], env=env,
                         capture_output=True, text=True, timeout=400)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDMAP_HFL_OK" in out.stdout
