"""Paper Fig. 2: time to derive the optimal HFLOP solution vs instance
size.  The paper used CPLEX on an 8-core Ryzen; we report our own exact
branch-and-bound (dense-simplex LP relaxation) plus the heuristic path
used for large instances, with 95% CIs over seeds.

``run_decomposed`` extends the curve to continuum scale (10^5 - 10^6
devices) with the hierarchically decomposed solver: per-size wall time
and devices/sec, phase breakdown, cost vs the vectorized greedy
baseline at the same scale, and the optimality gap vs the exact B&B on
<= 80-device subsamples of the same instances — all recorded to
``BENCH_solver.json`` (the artifact CI uploads)."""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (paper_cost_lan, random_instance, solve_bnb,
                        solve_decomposed, solve_greedy, solve_heuristic,
                        sub_instance)
from repro.core.hflop import is_feasible
from repro.telemetry import Telemetry
from benchmarks.common import emit


def run(sizes=((10, 3), (20, 4), (40, 5), (80, 6)), seeds=3,
        time_limit=60.0, heur_sizes=((500, 20), (2000, 50), (10000, 100))):
    rows = []
    for (n, m) in sizes:
        ts, opt = [], 0
        for s in range(seeds):
            inst = random_instance(n, m, seed=s)
            t0 = time.perf_counter()
            sol = solve_bnb(inst, time_limit_s=time_limit)
            ts.append(time.perf_counter() - t0)
            opt += int(sol.optimal)
        mean = np.mean(ts)
        ci = 1.96 * np.std(ts) / max(np.sqrt(len(ts)), 1)
        emit(f"fig2_bnb_n{n}_m{m}", mean * 1e6,
             f"optimal={opt}/{seeds};ci95_s={ci:.3f}")
        rows.append((n, m, mean, ci, opt))
    for (n, m) in heur_sizes:
        ts = []
        for s in range(seeds):
            inst = random_instance(n, m, seed=s)
            t0 = time.perf_counter()
            solve_heuristic(inst)
            ts.append(time.perf_counter() - t0)
        emit(f"fig2_heuristic_n{n}_m{m}", np.mean(ts) * 1e6,
             f"ci95_s={1.96 * np.std(ts) / np.sqrt(len(ts)):.3f}")
        rows.append((n, m, np.mean(ts), 0.0, -1))
    return rows


def _subsample_gaps(inst, seeds, sub_devices=60, extra_edges=4):
    """Exact-gap validation: draw small device subsamples (with every
    sampled device's LAN edge kept), solve them exactly and with the
    decomposed solver, and report the relative gaps."""
    gaps = []
    for s in seeds:
        rng = np.random.default_rng(10_000 + s)
        dev = np.sort(rng.choice(inst.n, size=min(sub_devices, inst.n),
                                 replace=False))
        homes = np.unique(inst.free[dev])
        extra = rng.choice(inst.m, size=min(extra_edges, inst.m),
                           replace=False)
        edg = np.unique(np.concatenate([homes, extra]))
        sub = sub_instance(inst, dev, edg)
        dense = sub.to_dense() if hasattr(sub, "to_dense") else sub
        exact = solve_bnb(dense)
        dec = solve_decomposed(sub)
        gap = ((dec.cost - exact.cost) / max(exact.cost, 1e-9)
               if np.isfinite(exact.cost) else float("nan"))
        gaps.append({"sub_seed": int(s), "n": int(sub.n), "m": int(sub.m),
                     "exact_cost": float(exact.cost),
                     "decomposed_cost": float(dec.cost),
                     "gap": float(gap)})
    return gaps


def run_decomposed(sizes=((100_000, 200), (1_000_000, 1000)), seed=0,
                   sub_seeds=4, json_path="BENCH_solver.json"):
    """The continuum-scale curve.  One seed per size (generation alone
    dominates repeats at 10^6), greedy baseline at the same scale, and
    exact-gap subsamples drawn from the *largest* instance."""
    record = {"sizes": [], "subsample_gaps": [],
              "max_subsample_gap": None}
    largest = None
    for (n, m) in sizes:
        inst = paper_cost_lan(n, m, seed=seed)
        largest = inst if largest is None or inst.n > largest.n else largest

        tel = Telemetry()
        t0 = time.perf_counter()
        dec = solve_decomposed(inst, telemetry=tel)
        wall = time.perf_counter() - t0
        feas = bool(is_feasible(inst, dec.assign))
        # phase breakdown straight from the tracer spans (the
        # ``meta["phase_s"]`` entries are a view over the same spans)
        phase_s = {f"{k}_s": float(v) for k, v
                   in tel.tracer.durations("solve_decomposed.").items()}

        t0 = time.perf_counter()
        grd = solve_greedy(inst)
        greedy_wall = time.perf_counter() - t0
        vs_greedy = (dec.cost - grd.cost) / max(grd.cost, 1e-9)

        emit(f"fig2_decomposed_n{n}_m{m}", wall * 1e6,
             f"devices_per_s={n / wall:.0f};feasible={int(feas)};"
             f"cost={dec.cost:.1f};vs_greedy={vs_greedy:.4f};"
             f"regions={dec.meta['regions']}")
        record["sizes"].append({
            "n": int(n), "m": int(m), "wall_s": float(wall),
            "devices_per_s": float(n / wall), "feasible": feas,
            "cost": float(dec.cost), "greedy_cost": float(grd.cost),
            "greedy_wall_s": float(greedy_wall),
            "cost_vs_greedy": float(vs_greedy),
            "regions": int(dec.meta["regions"]),
            "phase_s": phase_s,
            "gap_vs_lb": float(dec.meta["gap_vs_lb"]),
        })

    if largest is not None and sub_seeds > 0:
        gaps = _subsample_gaps(largest, seeds=range(sub_seeds))
        record["subsample_gaps"] = gaps
        record["max_subsample_gap"] = max(g["gap"] for g in gaps)
        emit("fig2_decomposed_subsample_gap",
             record["max_subsample_gap"] * 1e6,
             f"max_gap={record['max_subsample_gap']:.4f};"
             f"subsamples={len(gaps)}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", action="store_true",
                    help="continuum-scale decomposed-solver curve "
                         "(10^5 - 10^6 devices) + BENCH_solver.json")
    ap.add_argument("--smoke", action="store_true",
                    help="fast decomposed-solver smoke (10^5 devices, "
                         "2 exact-gap subsamples) + BENCH_solver.json")
    args = ap.parse_args()
    if args.smoke:
        run_decomposed(sizes=((100_000, 200),), sub_seeds=2)
    elif args.scale:
        run_decomposed()
    else:
        run()
