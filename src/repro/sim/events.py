"""Discrete-event core of the training–inference co-simulation.

One heap-based clock, typed events, and handler dispatch.  Everything
that "happens" on the continuum — a request arriving, a local epoch
starting on a device, an aggregation upload occupying an edge, a node
dying, concept drift setting in — is an :class:`Event` on the same
timeline, so training and inference contend for the same per-node
compute instead of being simulated in isolation.

Determinism contract: events at equal timestamps are ordered by
``EventKind`` value (completions and state changes apply before the
requests that must observe them), then by insertion order.  Handlers
run in registration order.  Given the same seed and schedule, two runs
produce identical event traces — asserted in ``tests/test_cosim.py``.

Window iteration: the heap is the sparse *control plane*.  A dense
*request plane* (``repro.sim.request_plane``) can register a flush
hook via :meth:`Simulation.set_flush`; :meth:`Simulation.run` then
calls it for every half-open window ``[lo, hi)`` between consecutive
control-event timestamps *before* dispatching the event at ``hi`` —
so batched request processing observes exactly the state a per-request
heap run would have seen (same-instant control events still apply
before same-instant arrivals, which belong to the *next* window), and
monitors reading the request log at a control event see every earlier
arrival.  The final window up to ``until`` is flushed inclusively
after the loop drains.

Window fusion: not every control event warrants cutting a window.
Each :class:`EventKind` is classified by its effect on the request
plane (:data:`EVENT_EFFECTS`): *mutates-routing-inputs* (busy flags,
capacities, assignment, interference stretch, penalty windows, the
shared generator stream), *reads-request-log* (telemetry monitors), or
*neither*.  A window ending in an effect-free event is **fused** with
the next one — the flush is skipped and the pending arrivals ride
along until a flushing event (or the run tail) cuts them, which is
trace-equivalent by construction: the skipped event's handlers neither
change what routing would observe nor observe what routing produced.
A host (the co-sim) can refine the static table per event through
:attr:`Simulation.flush_gate` — e.g. an ``EPOCH_START`` on a device
that is *already* busy changes nothing the router can see — and
``fuse_windows=False`` restores a flush at every control event.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Any, Callable, Dict, List, Optional, Tuple


class EventKind(IntEnum):
    """Typed simulation events.  The numeric value is the tie-break
    priority at equal timestamps: lower values are processed first, so
    a completion frees its slot, environment and training state changes
    apply, and only then do same-instant arrivals observe the world."""
    REQUEST_COMPLETION = 0   # a served request leaves its replica
    NODE_FAILURE = 1         # an edge host dies
    CAPACITY_CHANGE = 2      # an edge host's serving capacity shifts
    DEVICE_MOVE = 3          # a device hands over to another LAN edge
    STRAGGLER = 4            # a device's remaining epochs slow mid-round
    TENANT_LOAD = 5          # third-party edge demand changes (multi-tenant)
    DRIFT_ONSET = 6          # concept drift begins in the data stream
    RECONFIG_END = 7         # replica migration / re-deploy finishes
    ROUND_START = 8          # an HFL training round begins
    EPOCH_END = 9            # a device finishes one local epoch
    EPOCH_START = 10         # a device starts one local epoch
    AGG_START = 11           # aggregation upload window opens (edges busy)
    AGG_END = 12             # aggregation upload window closes
    ROUND_END = 13           # the training round is over
    TELEMETRY = 14           # periodic monitor tick (reactive loop)
    REQUEST_ARRIVAL = 15     # an inference request arrives
    # Fault-plane kinds sort AFTER same-instant arrivals (values above
    # REQUEST_ARRIVAL): a fault window opening at t applies to arrivals
    # strictly after t, and a retry landing exactly on an arrival's
    # timestamp re-attempts after that arrival was served.  ``run``
    # flushes the request plane *inclusively* before dispatching these,
    # so the batched engine observes the identical ordering.
    FAULT_START = 16         # a chaos-plan fault window opens
    FAULT_END = 17           # the fault clears (crash recovers, etc.)
    REQUEST_RETRY = 18       # a failed request re-attempts (backoff)
    # The batched engine's fault-window pacing beat (request-plane
    # internal, never appears in a heap run): while a crash/partition/
    # drop fault is live, each pending arrival gets a tick at its exact
    # timestamp so the pre-dispatch inclusive flush serves it *at that
    # instant* — a failed attempt then schedules its backoff retry in
    # the future, exactly where the heap engine would, instead of the
    # whole window's failures being discovered (and their retries
    # scheduled into the past) at the next control event.
    ARRIVAL_TICK = 19        # batched-plane pacing beat during faults


@dataclass(frozen=True)
class Event:
    t: float
    kind: EventKind
    node: int = -1           # device/edge id, -1 when not node-scoped
    payload: Any = None
    seq: int = 0             # insertion order (unique, the final tie-break)


class EventQueue:
    """Min-heap of events keyed by ``(t, kind, seq)``.  ``seq`` is unique,
    so heap entries never compare payloads."""

    def __init__(self) -> None:
        self._heap: List[Tuple[float, int, int, Event]] = []
        self._seq = 0

    def push(self, t: float, kind: EventKind, node: int = -1,
             payload: Any = None) -> Event:
        ev = Event(t=float(t), kind=kind, node=int(node), payload=payload,
                   seq=self._seq)
        heapq.heappush(self._heap, (ev.t, int(kind), ev.seq, ev))
        self._seq += 1
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[3]

    def peek_t(self) -> float:
        return self._heap[0][0] if self._heap else math.inf

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class EventEffect(IntEnum):
    """What dispatching one control event can do to the request plane —
    the window-fusion classification (see module docstring)."""
    NONE = 0                 # neither mutates routing inputs nor reads log
    MUTATES_ROUTING = 1      # busy flags / capacity / assign / stretch / rng
    READS_LOG = 2            # handler observes the request log (telemetry)


#: Static per-kind classification of the *co-sim's* handler contract.
#: ``STRAGGLER`` re-times future epochs (and the reactive drop policy
#: cancels future ones), ``DRIFT_ONSET`` only moves the accuracy model,
#: ``ROUND_START`` only schedules epoch/aggregation events — none of
#: them changes anything an in-flight request window can observe.
#: Everything else defaults to mutating; a custom handler that mutates
#: routing inputs on a ``NONE`` kind must set ``fuse_windows=False`` or
#: install a stricter ``flush_gate``.
EVENT_EFFECTS: Dict[EventKind, EventEffect] = {
    EventKind.REQUEST_COMPLETION: EventEffect.MUTATES_ROUTING,
    EventKind.NODE_FAILURE: EventEffect.MUTATES_ROUTING,
    EventKind.CAPACITY_CHANGE: EventEffect.MUTATES_ROUTING,
    EventKind.DEVICE_MOVE: EventEffect.MUTATES_ROUTING,
    EventKind.STRAGGLER: EventEffect.NONE,
    EventKind.TENANT_LOAD: EventEffect.MUTATES_ROUTING,
    EventKind.DRIFT_ONSET: EventEffect.NONE,
    EventKind.RECONFIG_END: EventEffect.MUTATES_ROUTING,
    EventKind.ROUND_START: EventEffect.NONE,
    EventKind.EPOCH_END: EventEffect.MUTATES_ROUTING,
    EventKind.EPOCH_START: EventEffect.MUTATES_ROUTING,
    EventKind.AGG_START: EventEffect.MUTATES_ROUTING,
    EventKind.AGG_END: EventEffect.MUTATES_ROUTING,
    EventKind.ROUND_END: EventEffect.MUTATES_ROUTING,
    EventKind.TELEMETRY: EventEffect.READS_LOG,
    EventKind.REQUEST_ARRIVAL: EventEffect.MUTATES_ROUTING,
    EventKind.FAULT_START: EventEffect.MUTATES_ROUTING,
    EventKind.FAULT_END: EventEffect.MUTATES_ROUTING,
    EventKind.REQUEST_RETRY: EventEffect.MUTATES_ROUTING,
    EventKind.ARRIVAL_TICK: EventEffect.MUTATES_ROUTING,
}


Handler = Callable[["Simulation", Event], None]

#: optional per-event refinement of :data:`EVENT_EFFECTS` — returns
#: True (flush), False (fuse), or None (use the static table).  Must
#: be decided *before* the event's handlers run, from state they have
#: not yet touched.
FlushGate = Callable[[Event], Optional[bool]]

#: flush hook signature: ``flush(lo, hi, inclusive)`` processes every
#: pending dense-plane arrival with ``lo <= t < hi`` (``t <= hi`` when
#: ``inclusive`` — the tail window of a bounded run).
FlushFn = Callable[[float, float, bool], None]

#: event kinds belonging to the dense request plane — excluded from
#: control-plane trace fingerprints when comparing the heap ("parity")
#: engine against the batched engine, which never materializes them.
REQUEST_PLANE_KINDS = frozenset({EventKind.REQUEST_ARRIVAL.name,
                                 EventKind.REQUEST_COMPLETION.name,
                                 EventKind.ARRIVAL_TICK.name})


def control_trace(trace: List[Tuple[float, str, int]],
                  ) -> List[Tuple[float, str, int]]:
    """The control-plane view of a trace: request arrivals/completions
    stripped, everything else untouched.  A heap run and a batched run
    of the same seeded scenario must agree on this view bit-for-bit."""
    return [row for row in trace if row[1] not in REQUEST_PLANE_KINDS]


@dataclass
class Simulation:
    """The clock + dispatcher.  Modules (request processor, training
    timeline, interference model, reactive loop) register handlers with
    :meth:`on` and schedule follow-up events from inside handlers."""
    record_trace: bool = False
    queue: EventQueue = field(default_factory=EventQueue)
    now: float = 0.0
    handlers: Dict[EventKind, List[Handler]] = field(default_factory=dict)
    trace: List[Tuple[float, str, int]] = field(default_factory=list)
    flush_fn: Optional[FlushFn] = None
    flushed_to: float = 0.0
    fuse_windows: bool = True        # skip flushes at effect-free events
    flush_gate: Optional[FlushGate] = None
    fused_windows: int = 0           # observability: flushes skipped
    flushed_closed: bool = False     # arrivals at exactly ``flushed_to``
    #                                  already consumed (inclusive flush)

    def on(self, kind: EventKind, handler: Handler) -> None:
        self.handlers.setdefault(kind, []).append(handler)

    def set_flush(self, fn: Optional[FlushFn]) -> None:
        """Register the dense request plane's window flush (see module
        docstring); ``run`` becomes window iteration over the control
        events."""
        self.flush_fn = fn

    def schedule(self, t: float, kind: EventKind, node: int = -1,
                 payload: Any = None) -> Event:
        return self.queue.push(t, kind, node=node, payload=payload)

    def _needs_flush(self, ev: Event) -> bool:
        """Whether the window ending at ``ev`` must flush before the
        event's handlers run — the fusion decision (module docstring)."""
        if not self.fuse_windows:
            return True
        if self.flush_gate is not None:
            verdict = self.flush_gate(ev)
            if verdict is not None:
                return verdict
        return EVENT_EFFECTS.get(
            ev.kind, EventEffect.MUTATES_ROUTING) is not EventEffect.NONE

    def run(self, until: float = math.inf) -> int:
        """Process events in order until the queue drains or the next
        event lies beyond ``until`` (which stays queued).  With a flush
        hook registered, the dense plane is advanced through every
        inter-event window first — except windows ending in an
        effect-free event, which fuse into the next one — and through
        the tail window up to ``until`` (inclusive) once the control
        events drain."""
        processed = 0
        while self.queue and self.queue.peek_t() <= until:
            ev = self.queue.pop()
            # kinds above REQUEST_ARRIVAL dispatch after same-instant
            # arrivals in the heap ordering, so their pre-dispatch flush
            # must consume arrivals at exactly ev.t too
            late = int(ev.kind) > int(EventKind.REQUEST_ARRIVAL)
            if self.flush_fn is not None and (
                    ev.t > self.flushed_to
                    or (late and ev.t == self.flushed_to
                        and not self.flushed_closed)):
                if self._needs_flush(ev):
                    self.flush_fn(self.flushed_to, ev.t, late)
                    self.flushed_to = ev.t
                    self.flushed_closed = late
                else:
                    self.fused_windows += 1
            self.now = ev.t
            if self.record_trace:
                self.trace.append((round(ev.t, 9), ev.kind.name, ev.node))
            for h in self.handlers.get(ev.kind, ()):
                h(self, ev)
            processed += 1
        if self.flush_fn is not None and until >= self.flushed_to:
            self.flush_fn(self.flushed_to, until, True)
            self.flushed_to = until
            self.flushed_closed = True
        return processed
