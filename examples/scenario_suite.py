"""Scenario suite walkthrough: the co-simulation as a scenario engine.

Runs the perturbation scenarios (stragglers, device mobility,
multi-tenant edges, combined churn) under three policies — static,
unconstrained reactive, and budget-capped reactive — and narrates what
the reactive loop did in each: which devices got dropped at the round
deadline, which handovers triggered re-clusters, and where the
reconfiguration budget said no.

  PYTHONPATH=src python examples/scenario_suite.py
"""
import numpy as np

from repro.sim.scenarios import (SCENARIOS, default_budget_total,
                                 run_scenario)

DURATION = 120.0
SEED = 0


def show(res, budget=False):
    b = (f"  budget {res.budget_spent:.0f}/{res.budget_total:.0f} spent"
         f" ({res.budget_vetoes} vetoed)" if budget else "")
    print(f"    {res.policy:9s} p95 {res.p95:7.2f} ms   "
          f"rounds {res.rounds_completed}   reclusters {res.reclusters}{b}")
    return res


def main():
    budget_total = default_budget_total()        # two full migrations
    for name in ("straggler", "mobility", "multi_tenant", "churn"):
        scenario = SCENARIOS[name]()
        print(f"\n=== {name}: {scenario.description} ===")
        static = show(run_scenario(scenario, "static", seed=SEED,
                                   duration_s=DURATION))
        reactive = show(run_scenario(scenario, "reactive", seed=SEED,
                                     duration_s=DURATION))
        budgeted = show(run_scenario(scenario, "budgeted", seed=SEED,
                                     duration_s=DURATION,
                                     budget_total=budget_total),
                        budget=True)
        gain = static.p95 - reactive.p95
        if gain > 0:
            frac = (static.p95 - budgeted.p95) / gain
            print(f"    -> budgeted recovers {frac:.0%} of the "
                  f"unconstrained p95 gain ({gain:.1f} ms) for "
                  f"{budgeted.budget_spent:.0f} budget units")
        print("    reactive-loop decisions (budgeted run):")
        for t, action in budgeted.actions:
            print(f"      t={t:6.1f}s  {action}")

    print("\n=== p95 timeline under churn (20 s windows, budgeted) ===")
    res = run_scenario(SCENARIOS["churn"](), "budgeted", seed=SEED,
                       duration_s=DURATION, budget_total=budget_total)
    for lo, p95 in res.log.windowed_percentile(20.0, 95):
        bar = "" if np.isnan(p95) else "#" * int(min(p95, 120) / 2)
        marks = [a for ta, a in res.actions if lo <= ta < lo + 20.0]
        note = f"   <- {marks[0]}" if marks else ""
        print(f"  {lo:5.0f}s  {p95:7.2f} ms  {bar}{note}")


if __name__ == "__main__":
    main()
