"""Dry-run machinery smoke on a small host-device mesh (subprocess owns
its XLA device-count flag).  The full 512-device sweep lives in
repro.launch.dryrun; this proves the lowering path + roofline extraction
end-to-end in CI time."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch import shardings as sh
    from repro.launch.dryrun import build_programs
    from repro.launch.roofline import collective_stats, analyze, model_flops_for
    from repro.launch.analytic import analytic_roofline

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("xlstm-125m")
    rules = sh.rules_for(cfg, mesh)

    import dataclasses
    # shrink the shape for CI: 512 seq, batch 8
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=512,
                                global_batch=8)
    import repro.launch.dryrun as dr
    import repro.configs as C
    C.INPUT_SHAPES["ci_train"] = shape
    dr.INPUT_SHAPES["ci_train"] = shape

    fn, inputs = dr.build_programs("xlstm-125m", "ci_train", mesh, rules)
    lowered = fn.lower(*inputs)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    assert float(cost.get("flops", 0)) > 0
    st = collective_stats(compiled.as_text())
    assert st.total_bytes > 0, "expected collectives on a sharded mesh"
    roof = analyze(compiled, mesh, model_flops_for(cfg, shape))
    assert roof.dominant in ("compute", "memory", "collective")
    ana = analytic_roofline(cfg, shape, mesh)
    assert ana.compute_s > 0 and ana.memory_s > 0
    print("DRYRUN_CI_OK", roof.dominant, f"{st.total_bytes:.3g}")
""")


def test_dryrun_lowering_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DRYRUN_CI_OK" in out.stdout
