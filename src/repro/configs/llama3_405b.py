"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256, rope theta 500k.
[arXiv:2407.21783]

Memory note: optimizer states run in bf16 (opt_state_dtype) so that
params+grads+Adam states fit 16 GB/chip on the 256-chip pod; see
DESIGN.md §5.
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama3-405b",
        family="dense",
        source="arXiv:2407.21783",
        num_layers=126,
        d_model=16_384,
        d_ff=53_248,
        vocab_size=128_256,
        attention=AttentionConfig(
            kind="full",
            num_heads=128,
            num_kv_heads=8,
            head_dim=128,
            rope_theta=500_000.0,
        ),
    ),
    run=RunConfig(microbatches=16, remat="layer", opt_state_dtype="bfloat16"),
)
