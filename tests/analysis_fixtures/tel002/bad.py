"""TEL002 bad fixture: facade resolved per call / per iteration."""
from repro.telemetry import maybe


class Router:
    def __init__(self, telemetry):
        self.telemetry = telemetry

    def route(self, requests):
        tel = maybe(self.telemetry)             # per-call resolve
        for req in requests:
            t = maybe(self.telemetry)           # per-iteration resolve
            if t is not None:
                t.metrics.counter("routed").inc()
        return tel
