"""Fixture: a jax-backed training module."""
import jax
import jax.numpy as jnp


def train_step(params, batch):
    return jax.tree_util.tree_map(jnp.zeros_like, params), batch
