"""Fixture: EVENT_EFFECTS covering EventKind exactly."""
from enum import IntEnum
from typing import Dict


class EventKind(IntEnum):
    REQUEST_COMPLETION = 0
    DEVICE_MOVE = 1
    ROUND_START = 2


class EventEffect(IntEnum):
    NONE = 0
    MUTATES_ROUTING = 1


EVENT_EFFECTS: Dict[EventKind, EventEffect] = {
    EventKind.REQUEST_COMPLETION: EventEffect.MUTATES_ROUTING,
    EventKind.DEVICE_MOVE: EventEffect.MUTATES_ROUTING,
    EventKind.ROUND_START: EventEffect.NONE,
}
