"""TEL002 good fixture: facade bound once at construction."""
from repro.telemetry import maybe


def run_once(telemetry):
    tel = maybe(telemetry)                      # module-function scope
    return tel


class Router:
    def __init__(self, telemetry):
        self._tel = maybe(telemetry)            # bind once

    def bind(self, cosim):
        self._tel = maybe(cosim.telemetry)      # re-bind seam

    def route(self, requests):
        if self._tel is not None:
            self._tel.metrics.counter("routed").inc()
