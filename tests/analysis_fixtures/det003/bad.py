"""DET003 bad fixture: fault-path code forking its own RNG streams."""
import numpy as np
from numpy.random import default_rng


def windows(mttf_s, duration_s, seed=0):
    rng = np.random.default_rng(seed)        # fresh stream in a plan
    out, t = [], 0.0
    while t < duration_s:
        t += float(rng.exponential(mttf_s))
        out.append(t)
    return out


def backoff_delay(policy, attempt):
    jitter = default_rng(attempt).random()   # per-retry private stream
    return policy.base * (2 ** attempt) * (1.0 + jitter)


def pick_failover(edges, seed):
    g = np.random.Generator(np.random.PCG64(seed))   # explicit fork
    return edges[int(g.integers(len(edges)))]
