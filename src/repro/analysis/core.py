"""Rule framework for the contract checker (numpy/stdlib-only).

The checker is a small static-analysis engine over the repo's own
source tree: every rule states one invariant the reproduction's
correctness rests on (import layering, RNG discipline, telemetry
non-perturbation, event-effect completeness, hot-path binding — see
CONTRACTS.md), and CI runs ``python -m repro.analysis`` as a hard
gate so a violation fails before a test ever has to catch it.

Pieces:

- :class:`FileContext` — one parsed file: AST, source lines, module
  name, and the inline suppressions found in it.  Parsed once per
  (path, mtime, size) through the process-wide :class:`AstCache`, so
  rules share the work.
- :class:`Rule` — per-file rules implement :meth:`Rule.check_file`;
  whole-tree rules (the import graph, the EVENT_EFFECTS cross-check)
  implement :meth:`Rule.check_project` instead.
- :class:`Project` — the scanned tree (``<root>/src/repro`` or
  ``<root>/repro``) with path <-> module-name mapping.
- :func:`run_analysis` — run rules, drop suppressed findings, return
  them sorted plus the list of suppressions actually used (CONTRACTS.md
  enumerates the sanctioned sites; the self-check test pins them).

Suppressions: a ``# contract: ok RULE001`` comment on the offending
line (or alone on the line directly above) suppresses that rule there;
``# contract: ok`` with no id suppresses every rule on the line.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*contract:\s*ok(?:\s+(?P<ids>[A-Z][A-Z0-9]*(?:\s*,\s*[A-Z][A-Z0-9]*)*))?")

#: suppress-all marker used in FileContext.suppressions values
ALL_RULES = "*"


@dataclass(frozen=True, order=True)
class Finding:
    """One contract violation at a source location."""
    path: str                        # repo-root-relative, '/'-separated
    line: int
    rule: str                        # rule id, e.g. "DET001"
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "message": self.message}


@dataclass
class FileContext:
    """One parsed source file, shared by all rules."""
    path: str                        # absolute path on disk
    rel_path: str                    # repo-root-relative display path
    module: Optional[str]            # dotted module name, None outside pkg
    source: str
    lines: List[str]
    tree: ast.Module
    # line number -> suppressed rule ids ({ALL_RULES} = every rule)
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)

    def suppressed(self, line: int, rule_id: str) -> bool:
        ids = self.suppressions.get(line)
        return ids is not None and (ALL_RULES in ids or rule_id in ids)


def _parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """``# contract: ok [IDS]`` markers.  A marker sharing its line with
    code covers that line; a comment-only marker covers the next line
    (and itself, so marker placement never creates a hole)."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if m is None:
            continue
        ids_raw = m.group("ids")
        ids = ({ALL_RULES} if not ids_raw
               else {s.strip() for s in ids_raw.split(",")})
        covers = [i]
        if text.lstrip().startswith("#"):
            covers.append(i + 1)
        for ln in covers:
            out.setdefault(ln, set()).update(ids)
    return out


class AstCache:
    """Per-file parse cache keyed by (mtime_ns, size): re-running the
    checker (or several rules over one file) parses each file once."""

    def __init__(self) -> None:
        self._cache: Dict[str, Tuple[Tuple[int, int], FileContext]] = {}

    def get(self, path: str, rel_path: str,
            module: Optional[str]) -> FileContext:
        st = os.stat(path)
        key = (st.st_mtime_ns, st.st_size)
        hit = self._cache.get(path)
        if hit is not None and hit[0] == key:
            return hit[1]
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        lines = source.splitlines()
        tree = ast.parse(source, filename=path)
        ctx = FileContext(path=path, rel_path=rel_path, module=module,
                          source=source, lines=lines, tree=tree,
                          suppressions=_parse_suppressions(lines))
        self._cache[path] = (key, ctx)
        return ctx


_GLOBAL_CACHE = AstCache()


class Project:
    """The scanned package tree.  ``root`` is the repo root; the package
    lives at ``<root>/src/repro`` (this repo's layout) or ``<root>/repro``
    (the test fixtures' mini-trees)."""

    def __init__(self, root: str, cache: Optional[AstCache] = None):
        self.root = os.path.abspath(root)
        self.cache = cache if cache is not None else _GLOBAL_CACHE
        for candidate in (os.path.join(self.root, "src", "repro"),
                          os.path.join(self.root, "repro")):
            if os.path.isdir(candidate):
                self.pkg_dir = candidate
                break
        else:
            raise FileNotFoundError(
                f"no 'src/repro' or 'repro' package under {self.root}")
        self.pkg_root = os.path.dirname(self.pkg_dir)  # sys.path entry

    def iter_paths(self) -> Iterable[str]:
        for dirpath, dirnames, filenames in os.walk(self.pkg_dir):
            dirnames.sort()
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for name in sorted(filenames):
                if name.endswith(".py"):
                    yield os.path.join(dirpath, name)

    def module_name(self, path: str) -> str:
        rel = os.path.relpath(os.path.abspath(path), self.pkg_root)
        parts = rel[:-3].split(os.sep)          # strip ".py"
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def module_path(self, module: str) -> Optional[str]:
        """Filesystem path of a dotted internal module, if it exists."""
        base = os.path.join(self.pkg_root, *module.split("."))
        if os.path.isfile(base + ".py"):
            return base + ".py"
        init = os.path.join(base, "__init__.py")
        if os.path.isfile(init):
            return init
        return None

    def context(self, path: str) -> FileContext:
        rel = os.path.relpath(os.path.abspath(path), self.root)
        return self.cache.get(os.path.abspath(path),
                              rel.replace(os.sep, "/"),
                              self.module_name(path))

    def contexts(self) -> List[FileContext]:
        return [self.context(p) for p in self.iter_paths()]


class Rule:
    """One invariant.  Subclasses set ``id``/``name``/``description``
    and implement ``check_file`` (per-file) or ``check_project``
    (whole-tree); the runner calls both."""

    id: str = "RULE000"
    name: str = "unnamed"
    description: str = ""

    def check_file(self, ctx: FileContext) -> List[Finding]:
        return []

    def check_project(self, project: Project) -> List[Finding]:
        return []


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def in_module_scope(tree: ast.Module, node: ast.stmt) -> bool:
    """Whether ``node`` executes at import time: module body, or nested
    only under module-level ``if``/``try`` blocks (never inside a
    function or class body)."""
    return node in _eager_statements(tree)


def _eager_statements(tree: ast.Module) -> List[ast.stmt]:
    out: List[ast.stmt] = []
    stack: List[ast.stmt] = list(tree.body)
    while stack:
        stmt = stack.pop()
        out.append(stmt)
        if isinstance(stmt, ast.If):
            if _is_type_checking(stmt.test):
                stack.extend(stmt.orelse)
            else:
                stack.extend(stmt.body)
                stack.extend(stmt.orelse)
        elif isinstance(stmt, ast.Try):
            stack.extend(stmt.body)
            stack.extend(stmt.orelse)
            stack.extend(stmt.finalbody)
            for h in stmt.handlers:
                stack.extend(h.body)
        elif isinstance(stmt, (ast.With,)):
            stack.extend(stmt.body)
    return out


def _is_type_checking(test: ast.expr) -> bool:
    name = dotted_name(test)
    return name in ("TYPE_CHECKING", "typing.TYPE_CHECKING")


def eager_imports(tree: ast.Module) -> List[Tuple[str, int]]:
    """(imported module, line) pairs that execute at import time.
    ``from X import Y`` yields ``X`` and — so package-submodule imports
    resolve — ``X.Y``; relative imports are returned with leading dots
    for the caller to resolve."""
    out: List[Tuple[str, int]] = []
    for stmt in _eager_statements(tree):
        if isinstance(stmt, ast.Import):
            for alias in stmt.names:
                out.append((alias.name, stmt.lineno))
        elif isinstance(stmt, ast.ImportFrom):
            prefix = "." * stmt.level + (stmt.module or "")
            out.append((prefix, stmt.lineno))
            for alias in stmt.names:
                if alias.name != "*":
                    out.append((prefix + "." + alias.name, stmt.lineno))
    return out


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

@dataclass
class AnalysisResult:
    findings: List[Finding]
    files_checked: int
    # suppressions that actually absorbed a finding: (path, line, rule)
    suppressions_used: List[Tuple[str, int, str]]

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> Dict[str, object]:
        counts: Dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return {
            "ok": self.ok,
            "files_checked": self.files_checked,
            "findings": [f.to_dict() for f in self.findings],
            "counts": counts,
            "suppressions_used": [
                {"path": p, "line": ln, "rule": r}
                for p, ln, r in self.suppressions_used],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2) + "\n"

    def format(self) -> str:
        if self.ok:
            lines = [f"contract check OK: {self.files_checked} files, "
                     f"0 findings"]
        else:
            lines = [f.format() for f in self.findings]
            lines.append(f"contract check FAILED: {len(self.findings)} "
                         f"finding(s) across {self.files_checked} files")
        if self.suppressions_used:
            lines.append("suppressions in effect:")
            lines.extend(f"  {p}:{ln}  {r}"
                         for p, ln, r in self.suppressions_used)
        return "\n".join(lines)


def default_rules() -> List[Rule]:
    # local import: the rule modules import this one
    from repro.analysis.determinism import (FreshRngInFaultPathRule,
                                            GlobalRngRule, WallClockRule)
    from repro.analysis.events_rules import EventEffectsRule
    from repro.analysis.imports import JaxFreeImportRule, LazyFacadeRule
    from repro.analysis.telemetry_rules import (NonPerturbationRule,
                                                TelemetryBindOnceRule)
    return [JaxFreeImportRule(), LazyFacadeRule(), GlobalRngRule(),
            WallClockRule(), FreshRngInFaultPathRule(),
            NonPerturbationRule(), TelemetryBindOnceRule(),
            EventEffectsRule()]


def run_analysis(root: str, rules: Optional[Sequence[Rule]] = None,
                 ) -> AnalysisResult:
    project = Project(root)
    if rules is None:
        rules = default_rules()
    contexts = project.contexts()
    by_path = {ctx.rel_path: ctx for ctx in contexts}
    raw: List[Finding] = []
    for rule in rules:
        for ctx in contexts:
            raw.extend(rule.check_file(ctx))
        raw.extend(rule.check_project(project))
    findings: List[Finding] = []
    used: List[Tuple[str, int, str]] = []
    for f in sorted(set(raw)):
        ctx = by_path.get(f.path)
        if ctx is not None and ctx.suppressed(f.line, f.rule):
            used.append((f.path, f.line, f.rule))
        else:
            findings.append(f)
    return AnalysisResult(findings=findings, files_checked=len(contexts),
                          suppressions_used=sorted(set(used)))
