"""Traffic data generator, checkpoint roundtrip, orchestration controller,
serving engine."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import get_config
from repro.data.traffic import (continual_split, generate, select_fl_sensors,
                                windows_for_sensor)
from repro.models import make_model
from repro.orchestration import LearningController, random_inventory
from repro.serving import ServeEngine, batched_arrivals, poisson_requests


def test_traffic_dataset_statistics():
    ds = generate(num_days=7, n_sensors=50, seed=0)
    assert ds.speeds.shape == (7 * 288, 50)
    assert 3.0 <= ds.speeds.min() and ds.speeds.max() <= 75.0
    assert len(np.unique(ds.cluster_of)) == 4
    # rush hour slower than night, on average
    tod = np.arange(ds.num_steps) % 288
    rush = ds.speeds[(tod > 85) & (tod < 95)].mean()
    night = ds.speeds[tod < 40].mean()
    assert rush < night - 3.0


def test_windows_and_split():
    ds = generate(num_days=40, n_sensors=40, seed=1)
    tr, va = continual_split(ds, round_idx=3)
    X, y = windows_for_sensor(ds, 0, tr.start, tr.stop, history=12)
    assert X.shape[1:] == (12, 1) and y.shape[1:] == (1,)
    # next-step target: y equals the value following the window
    z = ds.normalized()[tr.start:tr.stop, 0]
    np.testing.assert_allclose(X[5, :, 0], z[5:17], rtol=1e-6)
    np.testing.assert_allclose(y[5, 0], z[17], rtol=1e-6)
    sensors = select_fl_sensors(ds, per_cluster=2, seed=0)
    assert len(sensors) == 8
    assert len(np.unique(ds.cluster_of[sensors])) == 4


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
            "c": jnp.asarray([1, 2, 3], jnp.int32)}
    p = str(tmp_path / "ckpt.npz")
    save_pytree(p, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    back = load_pytree(p, like)
    np.testing.assert_allclose(np.asarray(back["a"]["b"]),
                               np.asarray(tree["a"]["b"]))
    assert back["c"].dtype == jnp.int32


def test_controller_deploy_and_recluster():
    # generous capacity slack so losing one of three edges stays feasible
    inv = random_inventory(n=12, m=3, seed=0, capacity_slack=3.0)
    ctl = LearningController(inventory=inv, l=2)
    dep = ctl.deploy()
    topo = dep.topology
    assert topo.participant_count() == 12
    assert len(dep.aggregator_nodes) >= 1
    assert any(s.startswith("routing-agent/") for s in dep.inference_services)
    # edge failure triggers re-clustering onto remaining edges
    dep2 = ctl.on_node_failure(dep.aggregator_nodes[0])
    assert ctl.recluster_count == 1
    assert dep2.topology.participant_count() == 12
    assert ctl.on_accuracy_alarm(0.10) is True
    assert ctl.on_accuracy_alarm(0.01) is False


def test_serve_engine_generate():
    cfg = get_config("xlstm-125m").reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=2, max_len=32)
    prompt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out = eng.generate(prompt, steps=4)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all()


def test_workload_generator():
    lam = np.array([5.0, 0.0, 10.0])
    ev = poisson_requests(lam, duration_s=20, seed=0)
    devs = np.asarray([e.device for e in ev])
    assert (devs != 1).all()
    assert abs((devs == 2).sum() / max((devs == 0).sum(), 1) - 2.0) < 0.5
    batches = list(batched_arrivals(ev, batch_size=8))
    assert sum(len(b[1]) for b in batches) == len(ev)
