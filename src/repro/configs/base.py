"""Configuration system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig` plus a
:class:`RunConfig` describing how it is trained/served on the production
mesh.  Configs are frozen dataclasses so they can be hashed and used as
static arguments to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 => no q compression (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class AttentionConfig:
    kind: str = "full"              # full | swa | local_global | mla | none
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 64
    window: int = 0                 # sliding window size (swa / local layers)
    local_global_ratio: int = 0     # e.g. 5 => 5 local : 1 global (gemma3)
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0   # separate base for local layers (gemma3)
    rope_fraction: float = 1.0      # partial rotary (stablelm: 0.25)
    mla: Optional[MLAConfig] = None
    causal: bool = True
    qk_norm: bool = False           # gemma3 QK-norm
    logit_soft_cap: float = 0.0


# ---------------------------------------------------------------------------
# MoE / SSM / xLSTM
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared: int = 0             # always-on shared experts
    top_k: int = 2
    d_expert: int = 0               # per-expert FFN hidden size
    d_shared: int = 0               # shared-expert FFN hidden size (0 -> d_expert*num_shared)
    first_dense_layers: int = 0     # leading dense layers (deepseek: 1)
    aux_loss_coef: float = 0.001
    router_dtype: str = "float32"
    dense_d_ff: int = 0             # FFN size of the leading dense layers
    capacity_factor: float = 1.25   # dispatch buffer slack (tokens dropped beyond)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128                # SSD chunk length
    ngroups: int = 1


@dataclass(frozen=True)
class XLSTMConfig:
    num_heads: int = 4
    slstm_layers: Tuple[int, ...] = ()   # indices of sLSTM blocks; rest mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.333
    conv_width: int = 4


# ---------------------------------------------------------------------------
# Modality frontends (STUBS per the carve-out)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrontendConfig:
    """Audio/vision frontend stub: input_specs() provides embeddings."""
    kind: str = "none"              # none | audio_frames | vision_patches
    num_positions: int = 0          # e.g. 1500 audio frames / 256 image patches
    embed_dim: int = 0              # embedding dim delivered by the stub


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | hybrid | ssm | audio | vlm | rnn
    source: str = ""                # citation from the assignment table
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32_000
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"               # silu (SwiGLU) | gelu (plain MLP)
    tie_embeddings: bool = False
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # hybrid (zamba2): indices at which the shared attention block is applied
    shared_attn_every: int = 0      # every k-th layer gets the shared attn block
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # rnn (paper's GRU)
    rnn_hidden: int = 0
    rnn_layers: int = 0
    # dtype policy
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # numerics
    embed_scale: bool = False       # gemma multiplies embeddings by sqrt(d)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (O(1) or windowed per-token state)."""
        if self.family in ("ssm", "hybrid", "rnn"):
            return True
        return self.attention.kind in ("swa", "local_global")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS and the
        HFL communication-cost model)."""
        a = self.attention
        d = self.d_model
        n = 0
        # embeddings (+ untied head)
        n += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "rnn":
            h = self.rnn_hidden
            n = 0
            inp = 1
            for i in range(self.rnn_layers):
                din = inp if i == 0 else h
                n += 3 * (din * h + h * h + 2 * h)
            n += h * 1 + 1  # regression head
            return n
        # attention params
        if a.kind == "mla" and a.mla is not None:
            m = a.mla
            qdim = a.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            attn_p = d * qdim                                    # q proj
            attn_p += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down + rope
            attn_p += m.kv_lora_rank * a.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            attn_p += a.num_heads * m.v_head_dim * d             # o proj
        elif a.kind == "none":
            attn_p = 0
        else:
            attn_p = d * a.num_heads * a.head_dim                # q
            attn_p += 2 * d * a.num_kv_heads * a.head_dim        # k,v
            attn_p += a.num_heads * a.head_dim * d               # o
        # ffn params
        def ffn(dff: int) -> int:
            mult = 3 if self.act == "silu" else 2
            return mult * d * dff
        if self.family == "ssm" and self.xlstm is not None:
            x = self.xlstm
            per_layer = int(d * d * x.proj_factor_mlstm * 2.5) + int(d * d * x.proj_factor_slstm * 2)
            per_layer //= 2  # mix of mLSTM/sLSTM; coarse
            n += self.num_layers * per_layer
        elif self.family in ("ssm", "hybrid") and self.ssm is not None:
            s = self.ssm
            d_in = d * s.expand
            mamba_p = d * d_in * 2            # in proj (x, z)
            mamba_p += d_in * (2 * s.ngroups * s.state_dim)  # B, C proj
            mamba_p += d_in                    # dt
            mamba_p += s.conv_width * (d_in + 2 * s.ngroups * s.state_dim)
            mamba_p += d_in * d                # out proj
            n += self.num_layers * mamba_p
            if self.shared_attn_every:
                n += attn_p + ffn(self.d_ff)   # one shared block
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn_p + ffn(self.d_ff)
            layers = self.num_layers + self.encoder_layers
            n += layers * per_layer
            if self.is_encoder_decoder:
                n += self.num_layers * attn_p  # cross attention
        elif self.family == "moe" and self.moe is not None:
            mo = self.moe
            moe_layers = self.num_layers - mo.first_dense_layers
            shared = mo.d_shared if mo.d_shared else mo.num_shared * mo.d_expert
            per_moe = attn_p + mo.num_experts * ffn(mo.d_expert) // 1
            per_moe += ffn(shared) if shared else 0
            per_moe += d * mo.num_experts      # router
            dense_ff = mo.dense_d_ff or self.d_ff
            n += mo.first_dense_layers * (attn_p + ffn(dense_ff))
            n += moe_layers * per_moe
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k + shared only)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        mo = self.moe
        d = self.d_model
        full = self.param_count()
        def ffn(dff: int) -> int:
            mult = 3 if self.act == "silu" else 2
            return mult * d * dff
        inactive = (mo.num_experts - mo.top_k) * ffn(mo.d_expert) * (
            self.num_layers - mo.first_dense_layers)
        return full - inactive


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Run config (how the arch runs on the mesh)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    microbatches: int = 1           # grad-accumulation steps inside train_step
    remat: str = "layer"            # none | layer | dots
    scan_layers: bool = True
    opt_state_dtype: str = "float32"
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    # HFL schedule
    local_rounds_per_global: int = 2   # paper's l
    local_epochs: int = 5
    # serving
    max_cache_len: int = 32_768
    cache_dtype: str = ""            # "" -> model dtype; e.g. float8_e4m3fn
    # sharding overrides: logical axis -> mesh axis name tuple
    sharding_overrides: Tuple[Tuple[str, Tuple[str, ...]], ...] = ()


@dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    run: RunConfig = field(default_factory=RunConfig)

    @property
    def name(self) -> str:
        return self.model.name

    def reduced(self) -> "ArchConfig":
        """Reduced variant of the same family for CPU smoke tests:
        2 layers, d_model<=512, <=4 experts."""
        m = self.model
        a = m.attention
        heads = max(2, min(4, a.num_heads))
        kvh = 1 if a.num_kv_heads == 1 else max(1, min(2, a.num_kv_heads))
        hd = 32
        small_attn = dataclasses.replace(
            a, num_heads=heads, num_kv_heads=kvh, head_dim=hd,
            window=min(a.window, 64) if a.window else 0,
            mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                          qk_rope_head_dim=16, v_head_dim=16) if a.mla else None,
        )
        kw = dict(
            num_layers=2, d_model=min(m.d_model, 256),
            d_ff=min(m.d_ff, 512) if m.d_ff else 0,
            vocab_size=min(m.vocab_size, 1024),
            attention=small_attn,
            encoder_layers=2 if m.is_encoder_decoder else 0,
        )
        if m.moe is not None:
            kw["moe"] = dataclasses.replace(
                m.moe, num_experts=4, num_shared=min(m.moe.num_shared, 1),
                top_k=2, d_expert=64, d_shared=64 if m.moe.d_shared else 0,
                dense_d_ff=128 if m.moe.dense_d_ff else 0)
        if m.ssm is not None:
            kw["ssm"] = dataclasses.replace(m.ssm, state_dim=16, head_dim=16, chunk=32)
        if m.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(m.xlstm, num_heads=2, slstm_layers=(1,))
        if m.shared_attn_every:
            kw["shared_attn_every"] = 2
        if m.frontend.kind != "none":
            kw["frontend"] = dataclasses.replace(
                m.frontend, num_positions=16, embed_dim=min(m.d_model, 256))
        if m.family == "rnn":
            kw.update(rnn_hidden=32, rnn_layers=2, num_layers=0, d_ff=0)
        model = dataclasses.replace(m, **kw)
        run = dataclasses.replace(self.run, microbatches=1)
        return ArchConfig(model=model, run=run)
