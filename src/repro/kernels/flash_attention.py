"""Flash attention Pallas TPU kernel (causal / sliding-window), the hot
path of every attention-bearing assigned architecture.

TPU adaptation (vs the CUDA flash-attention algorithm): the grid
iterates (batch*kv_head, q_block, k_block) with the online-softmax
accumulator held in VMEM scratch across the innermost k_block dimension;
block shapes are MXU-aligned (multiples of 128 on the contracting dims).
Sliding-window blocks outside [q-W, q] are skipped via masking (the
index_map cannot skip them without ragged grids; the §Perf iteration
measures the win of halving the k-grid for causal blocks)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, window: int, causal: bool, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (bk, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d = q_pos - k_pos
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= d >= 0
    if window > 0:
        mask &= d < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jax.Array:
    """q (BH, T, D); k/v (BH, T, D).  GQA callers fold the group into BH.
    Returns (BH, T, Dv)."""
    BH, T, D = q.shape
    Dv = v.shape[-1]
    bq = min(bq, T)
    bk = min(bk, T)
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    scale = 1.0 / math.sqrt(D)
    grid = (BH, T // bq, T // bk)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, window=window,
                               causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom
        ],
        interpret=interpret,
    )(q, k, v)
