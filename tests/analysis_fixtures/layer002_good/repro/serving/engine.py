"""Fixture: the jax-backed engine behind the lazy facade."""
import jax


class Engine:
    def __init__(self):
        self.backend = jax
