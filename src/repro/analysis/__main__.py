"""CLI for the contract checker.

    python -m repro.analysis [--root PATH] [--json PATH] [--rules IDS]

Exit codes: 0 clean, 1 findings, 2 usage/internal error.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.core import default_rules, run_analysis


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Check the repo's determinism / layering / "
                    "telemetry contracts (see CONTRACTS.md).")
    parser.add_argument("--root", default=None,
                        help="repo root holding src/repro or repro "
                             "(default: auto-detect from this package)")
    parser.add_argument("--json", dest="json_path", default=None,
                        help="also write machine-readable results here")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--quiet", action="store_true",
                        help="suppress the OK summary line")
    args = parser.parse_args(argv)

    root = args.root
    if root is None:
        # .../src/repro/analysis -> repo root is 3 dirs up
        pkg = os.path.dirname(os.path.abspath(__file__))
        root = os.path.dirname(os.path.dirname(os.path.dirname(pkg)))
    rules = default_rules()
    if args.rules:
        wanted = {r.strip() for r in args.rules.split(",")}
        known = {r.id for r in rules}
        unknown = wanted - known
        if unknown:
            print(f"unknown rule id(s): {', '.join(sorted(unknown))}; "
                  f"known: {', '.join(sorted(known))}", file=sys.stderr)
            return 2
        rules = [r for r in rules if r.id in wanted]
    try:
        result = run_analysis(root, rules)
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    if args.json_path:
        os.makedirs(os.path.dirname(os.path.abspath(args.json_path)),
                    exist_ok=True)
        with open(args.json_path, "w") as f:
            f.write(result.to_json())
    if not (args.quiet and result.ok):
        print(result.format())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
