"""DET003 good fixture: every draw comes from the passed generator."""


def windows(rng, mttf_s, duration_s):
    out, t = [], 0.0
    while t < duration_s:
        t += float(rng.exponential(mttf_s))
        out.append(t)
    return out


def backoff_delay(policy, attempt, u):
    return policy.base * (2 ** attempt) * (1.0 + policy.jitter * u)


def pick_failover(rng, edges):
    return edges[int(rng.integers(len(edges)))]
