"""Production mesh definitions (TPU v5e target).

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run sets the 512-device XLA flag before
any jax initialization)."""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — roofline denominators
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s per link (intra-pod)
DCI_BW = 25e9                     # bytes/s effective cross-pod share
HBM_BYTES = 16 * 1024 ** 3        # 16 GB per chip


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_hfl_mesh(*, n_clusters: int = 4, multi_pod: bool = False):
    """HFL training mesh: a leading "cluster" axis carries divergent model
    replicas (DESIGN.md §3).  Multi-pod: cluster == pod (2 clusters).
    Single-pod: the 16-wide data axis is split into (cluster, data)."""
    if multi_pod:
        return jax.make_mesh((2, 16, 16), ("cluster", "data", "model"))
    if 16 % n_clusters != 0:
        raise ValueError("n_clusters must divide 16")
    return jax.make_mesh((n_clusters, 16 // n_clusters, 16),
                         ("cluster", "data", "model"))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small host-device mesh for unit tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return jax.make_mesh(shape, axes)
