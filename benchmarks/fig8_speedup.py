"""Paper Fig. 8: end-to-end latency across edge/cloud compute-capacity
asymmetry.  Cloud inference time = edge_time * (1 - speedup); (a) nominal
request rates — speedup barely matters because network dominates;
(b) rates x10 — edges saturate, and flat FL (direct-to-cloud) wins once
the cloud is fast enough (paper: crossover at speedup > 14.25%)."""
from __future__ import annotations


from repro.core import solve_heuristic
from repro.routing import LatencyModel, SimConfig, compare_methods
from benchmarks.fig7_inference_latency import build_scenario
from benchmarks.common import emit


def run(speedups=(0.0, 0.25, 0.5, 0.75, 0.95), duration_s=120.0, seed=0,
        base_infer_ms=8.0):
    inst, loc = build_scenario(seed)
    hflop = solve_heuristic(inst)
    assigns = {"flat": None, "hier_location": loc, "hflop": hflop.assign}
    results = {}
    for rate_scale, tag in ((1.0, "a"), (10.0, "b")):
        for sp in speedups:
            lat = LatencyModel(base_infer_ms=base_infer_ms,
                               cloud_speedup=sp)
            cfg = SimConfig(duration_s=duration_s, seed=seed,
                            rate_scale=rate_scale, latency=lat)
            logs = compare_methods(inst, assigns, cfg)
            means = {k: v.mean_latency() for k, v in logs.items()}
            results[(tag, sp)] = means
            emit(f"fig8{tag}_speedup{int(sp * 100)}", means["hflop"] * 1000,
                 ";".join(f"{k}={v:.2f}ms" for k, v in means.items()))
    # crossover detection for (b)
    cross = None
    for sp in speedups:
        m = results[("b", sp)]
        if m["flat"] < min(m["hier_location"], m["hflop"]):
            cross = sp
            break
    emit("fig8b_flat_wins_above", (cross if cross is not None else -1) * 100,
         f"crossover_speedup={cross}")
    return results


if __name__ == "__main__":
    run()
