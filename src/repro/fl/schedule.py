"""Round timeline of hierarchical FL — numpy/stdlib-only.

The wall-clock shape of a training schedule (``RoundWindow`` /
``round_schedule``) is consumed by the training–inference co-simulation
(`repro.sim`), which must import without jax (contract LAYER001 —
see CONTRACTS.md).  The jax-backed training runner in
``repro.fl.hierarchy`` builds on the same types; it re-exports them so
existing imports keep working.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List


@dataclass(frozen=True)
class RoundWindow:
    """Wall-clock footprint of one HFL round on the continuum:
    participating devices compute local epochs in [start, compute_end)
    (the slowest device defines compute_end), then edges aggregate the
    uploads in [compute_end, upload_end) — with the cloud joining every
    l-th round for the global aggregation."""
    index: int
    start: float
    compute_end: float
    upload_end: float
    is_global: bool
    local_epochs: int = 1

    @property
    def end(self) -> float:
        return self.upload_end


def round_schedule(rounds: int, l: int = 2, local_epochs: int = 5,
                   epoch_s: float = 6.0, upload_s: float = 2.0,
                   global_extra_s: float = 2.0, gap_s: float = 0.0,
                   start_s: float = 0.0) -> List[RoundWindow]:
    """Wall-clock timeline of ``rounds`` HFL rounds (paper §V-B2 shape:
    ``local_epochs`` per round, a cluster aggregation each round, a
    global aggregation every ``l``-th).  ``gap_s`` is idle time between
    rounds — 0 models a back-to-back retraining burst."""
    out: List[RoundWindow] = []
    t = float(start_s)
    for k in range(rounds):
        is_global = ((k + 1) % max(l, 1) == 0)
        compute_end = t + local_epochs * epoch_s
        upload_end = compute_end + upload_s \
            + (global_extra_s if is_global else 0.0)
        out.append(RoundWindow(index=k, start=t, compute_end=compute_end,
                               upload_end=upload_end, is_global=is_global,
                               local_epochs=local_epochs))
        t = upload_end + gap_s
    return out
