"""Reconfiguration-budget accountant for the reactive loop.

HFL reconfiguration is not free: every re-clustered deployment pays a
migration window (``CoSimConfig.reconfig_s`` seconds of
``migration_share`` demand on every open edge plus a per-request
penalty), so reacting to every alarm can cost more than it recovers —
Čilić et al. (arXiv:2412.03385) ration reconfiguration under an explicit
communication/cost budget for exactly this reason.

:class:`ReconfigBudget` meters every ``CoSim.apply_deployment``: each
attempted deployment swap is charged its modeled migration cost
(``CoSim.reconfig_cost``, in edge-compute-seconds), and once the budget
is spent further swaps are vetoed — the ``ReactivePolicy`` then defers
optional reclusterings (latency derates, idle restores, mobility
reclusters) while, by default, still forcing through correctness-
critical ones (node-failure reclusters).  The ledger records every
charge and veto, so a run reports exactly what its reactions cost and
what they were denied.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional


@dataclass(frozen=True)
class BudgetEntry:
    """One metered ``apply_deployment`` attempt."""
    t: float
    reason: str
    cost: float
    applied: bool
    forced: bool = False


@dataclass
class ReconfigBudget:
    """Fixed reconfiguration allowance for one co-simulation run.

    ``total`` is in the same units as ``CoSim.reconfig_cost`` —
    edge-compute-seconds of modeled migration load.  ``math.inf``
    reproduces the unconstrained reactive loop while still keeping the
    ledger."""
    total: float = math.inf
    spent: float = 0.0
    ledger: List[BudgetEntry] = field(default_factory=list)
    #: optional per-charge callback (e.g. CoSim mirrors the ledger into
    #: telemetry registry metrics); pure observation — called after the
    #: entry is recorded, must not mutate the budget.  Excluded from
    #: equality/repr so budgets stay comparable.
    observer: Optional[Callable[[BudgetEntry], None]] = field(
        default=None, repr=False, compare=False)

    @property
    def remaining(self) -> float:
        return max(self.total - self.spent, 0.0)

    def can_afford(self, cost: float) -> bool:
        return float(cost) <= self.remaining + 1e-9

    def charge(self, t: float, cost: float, reason: str,
               forced: bool = False) -> bool:
        """Attempt to spend ``cost``.  Returns True (and records the
        spend) when affordable or ``forced``; False records a veto.
        Forced charges may drive ``spent`` past ``total`` — the overrun
        stays visible in the ledger."""
        ok = forced or self.can_afford(cost)
        entry = BudgetEntry(t=float(t), reason=str(reason),
                            cost=float(cost), applied=ok,
                            forced=bool(forced))
        self.ledger.append(entry)
        if ok:
            self.spent += float(cost)
        if self.observer is not None:
            self.observer(entry)
        return ok

    @property
    def reconfigs(self) -> int:
        return sum(1 for e in self.ledger if e.applied)

    @property
    def vetoes(self) -> int:
        return sum(1 for e in self.ledger if not e.applied)

    def summary(self) -> str:
        return (f"spent {self.spent:.1f}/{self.total:.1f} "
                f"({self.reconfigs} reconfigs, {self.vetoes} vetoed)")
