"""Continuous-batching scheduler: admission queue, slot allocation and
per-request TTFT/TPOT accounting on top of :class:`ServeEngine`.

The scheduler drives real engine compute under a hybrid clock: request
*arrivals* follow the workload's virtual timeline (Poisson offsets in
seconds), while *service* advances the clock by the measured wall time of
each prefill / decode step.  That keeps runs deterministic in structure
(admission order, slot reuse) while reporting honest latencies for the
calibration bridge.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class Request:
    id: int
    arrival_s: float
    prompt: np.ndarray               # (S,) token ids
    max_new_tokens: int = 16
    # filled by the scheduler
    tokens: List[int] = field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    slot: Optional[int] = None

    @property
    def ttft_ms(self) -> float:
        """Arrival -> first generated token (queueing + prefill)."""
        return (self.t_first_token - self.arrival_s) * 1e3

    @property
    def tpot_ms(self) -> float:
        """Mean time per output token after the first."""
        extra = len(self.tokens) - 1
        if extra <= 0:
            return 0.0
        return (self.t_done - self.t_first_token) * 1e3 / extra


@dataclass
class ScheduleStats:
    ttft_ms: np.ndarray
    tpot_ms: np.ndarray
    latency_ms: np.ndarray           # arrival -> completion
    tokens_generated: int
    duration_s: float
    slot_reuses: int                 # admissions into a previously used slot
    peak_occupancy: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / max(self.duration_s, 1e-9)

    def summary(self) -> str:
        def pct(a, q):
            return float(np.percentile(a, q)) if a.size else float("nan")
        return (f"ttft p50={pct(self.ttft_ms, 50):.1f}ms "
                f"p95={pct(self.ttft_ms, 95):.1f}ms | "
                f"tpot mean={float(self.tpot_ms.mean()) if self.tpot_ms.size else float('nan'):.2f}ms | "
                f"throughput={self.tokens_per_s:.1f} tok/s | "
                f"slot reuses={self.slot_reuses} "
                f"peak occupancy={self.peak_occupancy}")


class ContinuousBatchingScheduler:
    """FIFO admission onto engine slots; decode advances all active slots
    together (the engine's single shared decode program)."""

    def __init__(self, engine):
        # engine: ServeEngine or PagedServeEngine (duck-typed: acquire_slot
        # / can_admit / admit / decode / evict)
        self.engine = engine
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}      # slot -> request
        self.completed: List[Request] = []
        self._slots_ever_used: set = set()
        self.slot_reuses = 0
        self.peak_occupancy = 0
        self.requeues = 0                # requests re-admitted after a crash

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def requeue_active(self, now: float) -> int:
        """Crash recovery: the engine's cache is gone, so every in-flight
        request restarts from its prompt.  Drains the engine (releasing
        slots and, for the paged engine, verifying the page pool comes
        back whole), resets per-request progress and puts the requests
        back on the queue in arrival order.  Returns how many were
        requeued."""
        drained = self.engine.drain()
        n = 0
        for slot in drained:
            req = self.active.pop(slot, None)
            if req is None:
                continue
            req.tokens.clear()
            req.slot = None
            req.t_admitted = None
            req.t_first_token = None
            self.queue.append(req)
            n += 1
        self.queue.sort(key=lambda r: r.arrival_s)    # stable: FIFO again
        self.requeues += n
        return n

    # -- one scheduling iteration ------------------------------------------

    def _admit_ready(self, now: float) -> float:
        """Admit queued requests that have arrived, while capacity lasts
        (free slots for the dense engine; free slots AND pages for the
        paged engine — ``can_admit`` reserves the request's full
        ``max_new_tokens`` so an admitted sequence always completes).
        Returns the clock after the prefill wall time of each admission."""
        while self.queue and self.queue[0].arrival_s <= now:
            head = self.queue[0]
            if not self.engine.can_admit(len(head.prompt),
                                         head.max_new_tokens):
                break
            slot = self.engine.acquire_slot()
            if slot is None:
                break
            req = self.queue.pop(0)
            t0 = time.perf_counter()
            first = self.engine.admit(req.prompt, slot=slot,
                                      reserve_tokens=req.max_new_tokens)
            now += time.perf_counter() - t0
            req.slot = slot
            req.t_admitted = now
            req.t_first_token = now
            req.tokens.append(first)
            self.active[slot] = req
            if slot in self._slots_ever_used:
                self.slot_reuses += 1
            self._slots_ever_used.add(slot)
            self.peak_occupancy = max(self.peak_occupancy, len(self.active))
            if len(req.tokens) >= req.max_new_tokens:    # prompt-only ask
                self._complete(slot, now)
        return now

    def _complete(self, slot: int, now: float) -> None:
        req = self.active.pop(slot)
        req.t_done = now
        self.engine.evict(slot)
        self.completed.append(req)

    def _decode_once(self, now: float) -> float:
        t0 = time.perf_counter()
        toks = self.engine.decode()
        now += time.perf_counter() - t0
        for slot in list(self.active):
            req = self.active[slot]
            req.tokens.append(int(toks[slot]))
            if len(req.tokens) >= req.max_new_tokens:
                self._complete(slot, now)
        return now

    # -- batch run over a workload -----------------------------------------

    def run(self, requests: Sequence[Request]) -> ScheduleStats:
        for r in sorted(requests, key=lambda r: r.arrival_s):
            self.submit(r)
        now = 0.0
        while self.queue or self.active:
            if not self.active and self.queue \
                    and self.queue[0].arrival_s > now:
                now = self.queue[0].arrival_s        # idle: jump to arrival
            now = self._admit_ready(now)
            if not self.active and self.queue \
                    and self.queue[0].arrival_s <= now:
                head = self.queue[0]
                raise ValueError(
                    f"request {head.id} (prompt {len(head.prompt)} + "
                    f"{head.max_new_tokens} new) can never be admitted on "
                    "an idle engine — it exceeds the engine's capacity")
            if self.active:
                now = self._decode_once(now)
        return self.stats(duration_s=now)

    def stats(self, duration_s: float) -> ScheduleStats:
        done = self.completed
        return ScheduleStats(
            ttft_ms=np.asarray([r.ttft_ms for r in done]),
            tpot_ms=np.asarray([r.tpot_ms for r in done
                                if len(r.tokens) > 1]),
            latency_ms=np.asarray([(r.t_done - r.arrival_s) * 1e3
                                   for r in done]),
            tokens_generated=sum(len(r.tokens) for r in done),
            duration_s=duration_s,
            slot_reuses=self.slot_reuses,
            peak_occupancy=self.peak_occupancy,
        )


def requests_from_events(events, prompts: np.ndarray,
                         max_new_tokens: int = 16) -> List[Request]:
    """Adapt ``serving.workload.poisson_requests`` events into scheduler
    requests; ``prompts`` (N, S) are cycled over events."""
    out = []
    for k, ev in enumerate(events):
        out.append(Request(id=k, arrival_s=ev.t,
                           prompt=np.asarray(prompts[k % len(prompts)]),
                           max_new_tokens=max_new_tokens))
    return out
