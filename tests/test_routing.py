"""Inference routing rules R1-R3 + event simulator invariants (§III/V-C)."""
import numpy as np
import pytest

from repro.core import HFLOPInstance
from repro.core.topology import ClusterTopology
from repro.routing import (EdgeState, LatencyModel, SimConfig,
                           compare_methods, route_request, simulate)


def _edges(cap=10.0, n=2):
    return {j: EdgeState(capacity_rps=cap) for j in range(n)}


def test_r1_busy_device_offloads_to_aggregator():
    dec = route_request(0, True, np.array([1]), _edges())
    assert dec.tier == "edge" and dec.edge == 1 and dec.rule == "R1"


def test_r1_flat_goes_to_cloud():
    dec = route_request(0, True, np.array([-1]), _edges())
    assert dec.tier == "cloud" and dec.rule == "R1-flat"


def test_r2_idle_device_serves_locally():
    dec = route_request(0, False, np.array([1]), _edges())
    assert dec.tier == "device" and dec.rule == "R2-local"


def test_r3_overflow_forwards_to_cloud():
    edges = _edges()
    edges[1].tokens = 0.5              # bucket exhausted (at capacity)
    dec = route_request(0, True, np.array([1]), edges)
    assert dec.tier == "cloud" and dec.rule == "R3-overflow"
    assert dec.hops == 2               # pays edge + cloud legs


def _topo(n=12, m=3, cap=6.0):
    assign = np.arange(n) % m
    return ClusterTopology(assign=assign, n_devices=n, n_edges=m,
                           lam=np.full(n, 2.0), r=np.full(m, cap), l=2)


def test_simulator_no_request_lost():
    topo = _topo()
    log = simulate(topo, SimConfig(duration_s=30, seed=1))
    assert len(log.latency_ms) == len(log.t) == len(log.device)
    assert np.all(log.latency_ms > 0)
    assert len(log.t) > 100            # Poisson with 24 req/s over 30s


def test_simulator_latency_ordering_flat_vs_hier():
    """Fig. 7: flat >> hierarchical latency."""
    n, m = 20, 4
    rng = np.random.default_rng(0)
    c_d = np.ones((n, m))
    loc = np.repeat(np.arange(m), 5)
    c_d[np.arange(n), loc] = 0.0
    inst = HFLOPInstance(c_d, np.ones(m), rng.uniform(2, 6, n),
                         np.full(m, 30.0), l=2)
    logs = compare_methods(inst, {"flat": None, "hier": loc},
                           SimConfig(duration_s=60, seed=2))
    assert logs["flat"].mean_latency() > 3 * logs["hier"].mean_latency()
    assert logs["flat"].tier_fractions()["cloud"] == pytest.approx(1.0)


def test_edge_tier_fraction_respects_capacity():
    """Tighter capacity -> more cloud overflow."""
    big = simulate(_topo(cap=50.0), SimConfig(duration_s=40, seed=3))
    small = simulate(_topo(cap=2.0), SimConfig(duration_s=40, seed=3))
    assert (small.tier_fractions()["cloud"]
            > big.tier_fractions()["cloud"])


def test_latency_model_ranges():
    lat = LatencyModel()
    rng = np.random.default_rng(0)
    edge = lat.rtt("edge", rng, 1000)
    cloud = lat.rtt("cloud", rng, 1000)
    assert edge.min() >= 8.0 and edge.max() <= 10.0        # paper §V-C1
    assert cloud.min() >= 50.0 and cloud.max() <= 100.0
    assert lat.infer_ms("cloud") == pytest.approx(lat.base_infer_ms)
    lat2 = LatencyModel(cloud_speedup=0.5)
    assert lat2.infer_ms("cloud") == pytest.approx(lat.base_infer_ms / 2)
