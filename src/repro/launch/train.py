"""HFL training driver.

Runs the full stack end-to-end: config -> model -> data pipeline ->
(hierarchical) train step -> aggregation schedule -> checkpoint.  On this
CPU container use ``--reduced`` (default) to actually execute; the full
configs are exercised by the dry-run (``repro.launch.dryrun``).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
      --steps 20 --mode hfl --clusters 2 --global-every 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.fl.collectives import cluster_divergence, stack_for_clusters
from repro.models import make_model
from repro.training.optimizer import AdamW
from repro.training.train_step import (hfl_global_round, make_hfl_train_step,
                                       make_train_step)


def make_batch(stream, cfg, batch_size, seq_len, clusters=0):
    m = cfg.model
    n = max(clusters, 1)
    batches = [stream.next_batch() for _ in range(n)]
    out = {k: np.stack([b[k] for b in batches]) for k in batches[0]}
    if clusters == 0:
        out = {k: v[0] for k, v in out.items()}
    extra = {}
    rng = np.random.default_rng(0)
    if m.family == "vlm":
        P = m.frontend.num_positions
        shape = ((clusters,) if clusters else ()) + (batch_size, P, m.d_model)
        extra["patches"] = (rng.normal(size=shape) * 0.02).astype(np.float32)
    if m.family == "audio":
        F = m.frontend.num_positions
        shape = ((clusters,) if clusters else ()) + (batch_size, F, m.d_model)
        extra["frames"] = (rng.normal(size=shape) * 0.02).astype(np.float32)
    out.update({k: jnp.asarray(v, jnp.bfloat16) for k, v in extra.items()})
    return {k: jnp.asarray(v) for k, v in out.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--mode", choices=("flat", "hfl"), default="hfl")
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--global-every", type=int, default=2,
                    help="the paper's l: local rounds per global round")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = full.reduced() if args.reduced else full
    api = make_model(cfg)
    m = cfg.model
    print(f"arch={args.arch} (reduced={args.reduced}) params...")
    params, _ = api.init_params(jax.random.key(0))
    opt = AdamW(lr=1e-3, state_dtype=cfg.run.opt_state_dtype)
    stream = TokenStream(TokenStreamConfig(
        vocab_size=max(m.vocab_size, 2), seq_len=args.seq,
        batch_size=args.batch))

    if args.mode == "flat":
        step = jax.jit(make_train_step(api, cfg, opt))
        opt_state = opt.init(params)
        for t in range(args.steps):
            batch = make_batch(stream, cfg, args.batch, args.seq)
            t0 = time.perf_counter()
            params, opt_state, loss = step(params, opt_state, batch)
            loss = float(loss)
            print(f"step {t:3d} loss={loss:.4f} "
                  f"({time.perf_counter() - t0:.2f}s)")
    else:
        C = args.clusters
        stacked = stack_for_clusters(params, C)
        opt_state = jax.vmap(opt.init)(stacked)
        local = jax.jit(make_hfl_train_step(api, cfg, opt))
        for t in range(args.steps):
            batch = make_batch(stream, cfg, args.batch, args.seq, clusters=C)
            t0 = time.perf_counter()
            stacked, opt_state, losses = local(stacked, opt_state, batch)
            line = (f"round {t:3d} losses="
                    f"{[round(float(x), 4) for x in losses]} "
                    f"({time.perf_counter() - t0:.2f}s)")
            if (t + 1) % args.global_every == 0:
                div = float(cluster_divergence(stacked))
                stacked = hfl_global_round(stacked)
                line += f"  [GLOBAL SYNC, divergence was {div:.2e}]"
            print(line)
        params = jax.tree.map(lambda x: x[0], stacked)

    if args.checkpoint:
        save_pytree(args.checkpoint, params)
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
