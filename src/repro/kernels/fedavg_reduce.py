"""Weighted model-replica reduction Pallas kernel — the FedAvg hot loop.

Aggregating C client/cluster replicas of a flattened parameter vector is
a (C x N) weighted column reduction.  On TPU the N dimension is tiled
into VMEM blocks; each grid step reduces all C replicas for its tile
(C is small — 20 clients / 4 clusters — so the full column block fits)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                # (C, bn)
    w = w_ref[...].astype(jnp.float32)                # (C,)
    wn = w / jnp.sum(w)
    o_ref[...] = jnp.dot(wn[None, :], x,
                         preferred_element_type=jnp.float32)[0].astype(
                             o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def fedavg_reduce(stacked: jax.Array, weights: jax.Array, *,
                  bn: int = 16384, interpret: bool = True) -> jax.Array:
    """stacked (C, N) replica matrix; weights (C,) -> (N,) average."""
    C, N = stacked.shape
    bn = min(bn, N)
    pad = (-N) % bn
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Np = N + pad
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((C, bn), lambda i: (0, i)),
            pl.BlockSpec((C,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), stacked.dtype),
        interpret=interpret,
    )(stacked, weights)
    return out[:N]
