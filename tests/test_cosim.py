"""Training–inference co-simulation subsystem: event core ordering,
shared Poisson streams, interference model, round timeline, drift
injection, and the end-to-end interference + recovery claims."""
import numpy as np
import pytest

from repro.core.topology import ClusterTopology
from repro.data import generate, inject_drift
from repro.fl import round_schedule
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.routing import (CalibratedLatencyModel, LatencyModel, SimConfig,
                           simulate)
from repro.routing.rules import RouteDecision
from repro.serving.workload import poisson_requests
from repro.sim import (CoSim, CoSimConfig, EventKind, InterferenceConfig,
                       InterferenceModel, ReactiveLoop, ReactivePolicy,
                       Simulation)


# ---------------------------------------------------------------------------
# event core
# ---------------------------------------------------------------------------

def test_event_ordering_at_equal_time():
    """Completions and state changes apply before same-instant arrivals;
    FIFO within a kind."""
    sim = Simulation()
    order = []
    sim.on(EventKind.REQUEST_COMPLETION,
           lambda s, e: order.append("completion"))
    sim.on(EventKind.ROUND_START, lambda s, e: order.append("round"))
    sim.on(EventKind.REQUEST_ARRIVAL,
           lambda s, e: order.append(f"arrival{e.node}"))
    sim.schedule(1.0, EventKind.REQUEST_ARRIVAL, node=1)
    sim.schedule(1.0, EventKind.ROUND_START)
    sim.schedule(1.0, EventKind.REQUEST_COMPLETION)
    sim.schedule(1.0, EventKind.REQUEST_ARRIVAL, node=2)
    sim.schedule(0.5, EventKind.REQUEST_ARRIVAL, node=3)
    n = sim.run()
    assert n == 5
    assert order == ["arrival3", "completion", "round",
                     "arrival1", "arrival2"]


def test_run_until_leaves_future_events_queued():
    sim = Simulation()
    seen = []
    sim.on(EventKind.TELEMETRY, lambda s, e: seen.append(e.t))
    for t in (1.0, 2.0, 3.0):
        sim.schedule(t, EventKind.TELEMETRY)
    sim.run(until=2.0)
    assert seen == [1.0, 2.0] and len(sim.queue) == 1


def test_epoch_end_orders_before_next_epoch_start():
    assert EventKind.EPOCH_END < EventKind.EPOCH_START


# ---------------------------------------------------------------------------
# shared Poisson arrivals (dedup satellite)
# ---------------------------------------------------------------------------

def _topo(n=12, m=3, cap=6.0, lam=2.0):
    return ClusterTopology(assign=np.arange(n) % m, n_devices=n, n_edges=m,
                           lam=np.full(n, float(lam)),
                           r=np.full(m, float(cap)), l=2)


def test_simulator_uses_shared_poisson_stream():
    """Same seed -> the simulator's arrival stream is exactly
    ``serving.workload.poisson_requests`` (the private copy is gone)."""
    topo = _topo()
    log = simulate(topo, SimConfig(duration_s=20, seed=7))
    events = poisson_requests(topo.lam, 20, seed=7)
    assert np.allclose(log.t, [e.t for e in events])
    assert np.array_equal(log.device, [e.device for e in events])


def test_poisson_requests_generator_seed_equivalence():
    lam = np.full(4, 3.0)
    a = poisson_requests(lam, 10, seed=3)
    b = poisson_requests(lam, 10, np.random.default_rng(3))
    assert [(e.t, e.device) for e in a] == [(e.t, e.device) for e in b]


def test_simulate_deterministic():
    topo = _topo()
    a = simulate(topo, SimConfig(duration_s=20, seed=5, busy_fraction=0.5))
    b = simulate(topo, SimConfig(duration_s=20, seed=5, busy_fraction=0.5))
    assert np.array_equal(a.latency_ms, b.latency_ms)
    assert a.rule == b.rule


# ---------------------------------------------------------------------------
# percentiles (reporting satellite)
# ---------------------------------------------------------------------------

def test_percentile_latency():
    log = simulate(_topo(), SimConfig(duration_s=20, seed=1))
    assert log.percentile_latency(50) == pytest.approx(
        float(np.percentile(log.latency_ms, 50)))
    pct = log.latency_percentiles()
    assert set(pct) == {"p50", "p95", "p99"}
    assert pct["p50"] <= pct["p95"] <= pct["p99"]
    win = log.windowed_percentile(5.0, 95)
    assert win.shape[1] == 2 and np.all(np.diff(win[:, 0]) > 0)


def _empty_log():
    from repro.routing.simulator import RequestLog
    return RequestLog(t=np.zeros(0), device=np.zeros(0, int),
                      tier=np.zeros(0, int), rule=[],
                      latency_ms=np.zeros(0))


def test_empty_log_accessors_return_nan():
    """Short co-sim smoke runs can serve zero requests; reporting must
    return NaN cleanly instead of crashing or warning (regression)."""
    import math
    import warnings
    log = _empty_log()
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # RuntimeWarning -> fail
        assert math.isnan(log.mean_latency())
        assert math.isnan(log.std_latency())
        assert math.isnan(log.percentile_latency(95))
        pct = log.latency_percentiles()
        assert set(pct) == {"p50", "p95", "p99"}
        assert all(math.isnan(v) for v in pct.values())
        assert all(math.isnan(v) for v in log.tier_fractions().values())
        assert log.windowed_percentile(5.0).shape == (0, 2)


def test_windowed_percentile_emits_nan_rows_for_empty_windows():
    """Arrival gaps used to be silently dropped from the timeline; they
    must surface as NaN rows so the window grid stays uniform."""
    from repro.routing.simulator import RequestLog
    log = RequestLog(t=np.array([1.0, 25.0]), device=np.zeros(2, int),
                     tier=np.zeros(2, int), rule=["R2-local"] * 2,
                     latency_ms=np.array([10.0, 20.0]))
    win = log.windowed_percentile(10.0, 95)
    assert win.shape == (3, 2)
    assert np.array_equal(win[:, 0], [0.0, 10.0, 20.0])
    assert win[0, 1] == pytest.approx(10.0)
    assert np.isnan(win[1, 1])
    assert win[2, 1] == pytest.approx(20.0)


# ---------------------------------------------------------------------------
# round timeline
# ---------------------------------------------------------------------------

def test_round_schedule_shape():
    sched = round_schedule(rounds=6, l=3, local_epochs=4, epoch_s=2.0,
                           upload_s=1.0, global_extra_s=0.5, gap_s=0.5)
    assert len(sched) == 6
    assert [w.is_global for w in sched] == [False, False, True,
                                            False, False, True]
    for w in sched:
        assert w.compute_end - w.start == pytest.approx(8.0)
        assert w.local_epochs == 4
    # non-overlapping, gap respected
    for a, b in zip(sched, sched[1:]):
        assert b.start == pytest.approx(a.upload_end + 0.5)
    # global rounds pay the extra cloud upload
    assert (sched[2].upload_end - sched[2].compute_end
            == pytest.approx(1.5))
    assert (sched[0].upload_end - sched[0].compute_end
            == pytest.approx(1.0))


# ---------------------------------------------------------------------------
# interference model
# ---------------------------------------------------------------------------

def test_interference_stretch():
    m = InterferenceModel()
    base = m.lat.infer_ms("edge")
    dec = RouteDecision("edge", 0)
    assert m.service_ms(0, dec) == pytest.approx(base)
    m.set_demand(("edge", 0), "agg", 0.5)
    assert m.service_ms(0, dec) == pytest.approx(2 * base)
    # other nodes unaffected
    assert m.service_ms(0, RouteDecision("edge", 1)) == pytest.approx(base)
    m.set_demand(("edge", 0), "agg", 0.0)
    assert m.service_ms(0, dec) == pytest.approx(base)


def test_interference_components_compose_and_floor():
    cfg = InterferenceConfig(floor=0.05)
    m = InterferenceModel(cfg=cfg)
    m.set_demand(("device", 3), "epoch", 0.4)
    m.set_demand(("device", 3), "res", 0.3)
    assert m.demand(("device", 3)) == pytest.approx(0.7)
    # demand saturates at 1 - floor -> stretch caps at 1/floor
    m.set_demand(("device", 3), "more", 5.0)
    assert m.demand(("device", 3)) == pytest.approx(0.95)
    assert m.stretch(("device", 3)) == pytest.approx(20.0)


def test_interference_composes_with_calibrated_occupancy():
    lat = CalibratedLatencyModel(tier_service_ms={"edge": 10.0},
                                 tier_slots={"edge": 2})
    m = InterferenceModel(lat)
    m.set_demand(("edge", 0), "agg", 0.5)
    dec = RouteDecision("edge", 0)
    # occupancy 3 on 2 slots -> 2x; training share 0.5 -> 2x; composed 4x
    assert m.service_ms(0, dec, occupancy=3) == pytest.approx(40.0)


def test_interference_from_measurements():
    class M:
        prefill_ms, decode_ms_per_token, batch_size = 4.0, 0.5, 2
    m = InterferenceModel.from_measurements({"edge": M()}, decode_tokens=4)
    assert isinstance(m.lat, CalibratedLatencyModel)
    assert m.service_ms(0, RouteDecision("edge", 0)) == pytest.approx(6.0)


# ---------------------------------------------------------------------------
# drift injection
# ---------------------------------------------------------------------------

def test_inject_drift_shifts_only_after_onset():
    ds = generate(num_days=3, n_sensors=8, seed=0)
    drifted = inject_drift(ds, start_step=288, severity=0.4,
                           ramp_steps=144)
    assert np.array_equal(drifted.speeds[:288], ds.speeds[:288])
    assert np.all(drifted.speeds[288:] <= ds.speeds[288:] + 1e-6)
    # normalization is preserved so the shift reaches the model
    assert np.array_equal(drifted.mean, ds.mean)
    assert np.array_equal(drifted.std, ds.std)
    late = slice(288 + 144, None)
    ratio = drifted.speeds[late].mean() / ds.speeds[late].mean()
    assert ratio < 0.75


def test_inject_drift_rejects_bad_start():
    ds = generate(num_days=2, n_sensors=4, seed=0)
    with pytest.raises(ValueError):
        inject_drift(ds, start_step=10 ** 6)


# ---------------------------------------------------------------------------
# co-simulation end-to-end
# ---------------------------------------------------------------------------

def _hot_zone(seed=0, n=20, m=4, hot=3.0, slack=1.35):
    rng = np.random.default_rng(seed)
    loc = np.repeat(np.arange(m), n // m)
    lam = rng.uniform(2.0, 4.0, n)
    lam[loc == 0] *= hot
    r = np.full(m, lam.sum() / m * slack)
    topo = ClusterTopology(assign=loc, n_devices=n, n_edges=m, lam=lam,
                           r=r, l=2)
    return topo, loc, lam, r


def _training(duration):
    rounds = max(int(duration / 20.0), 1)
    return round_schedule(rounds=rounds, l=2, local_epochs=5, epoch_s=3.5,
                          upload_s=2.0, gap_s=2.0)


def test_cosim_training_raises_p95():
    topo, *_ = _hot_zone()
    cfg = CoSimConfig(duration_s=45.0, seed=0)
    off = CoSim(topo, cfg).run()
    on = CoSim(topo, cfg, schedule=_training(45.0)).run()
    assert on.rounds_completed >= 2
    # serving-only: idle devices serve locally, nothing interferes
    assert off.log.tier_fractions()["device"] == pytest.approx(1.0)
    # with training the same workload measurably degrades
    assert (on.log.percentile_latency(95)
            > 2 * off.log.percentile_latency(95))


def test_cosim_deterministic_trace():
    topo, *_ = _hot_zone()
    cfg = CoSimConfig(duration_s=30.0, seed=3)
    a = CoSim(topo, cfg, schedule=_training(30.0)).run()
    b = CoSim(topo, cfg, schedule=_training(30.0)).run()
    assert a.trace == b.trace
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
    assert a.log.rule == b.log.rule
    # and a different seed genuinely changes the run
    c = CoSim(topo, CoSimConfig(duration_s=30.0, seed=4),
              schedule=_training(30.0)).run()
    assert len(c.trace) != len(a.trace) \
        or not np.array_equal(c.log.latency_ms, a.log.latency_ms)


def test_cosim_reactive_recovers_p95_gap():
    topo, loc, lam, r = _hot_zone()
    cfg = CoSimConfig(duration_s=60.0, seed=0)
    sched = _training(60.0)
    off = CoSim(topo, cfg).run()
    on = CoSim(topo, cfg, schedule=sched).run()
    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=2)
    ctl.deployment = Deployment.from_topology(topo)
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(p95_threshold_ms=20.0))
    rx = CoSim(topo, cfg, schedule=sched, reactive=loop).run()
    p_off = off.log.percentile_latency(95)
    p_on = on.log.percentile_latency(95)
    p_rx = rx.log.percentile_latency(95)
    assert ctl.recluster_count >= 1 and len(rx.reconfig_times) >= 1
    assert p_on > p_rx > p_off            # recovery, but not for free
    assert (p_on - p_rx) / (p_on - p_off) > 0.2


def test_cosim_capacity_change_applies_without_reactive_loop():
    """A CAPACITY_CHANGE event must alter admission even when nobody
    re-clusters (regression: it used to be a silent no-op)."""
    topo, *_ = _hot_zone()
    cfg = CoSimConfig(duration_s=30.0, seed=0)
    plain = CoSim(topo, cfg, schedule=_training(30.0)).run()
    cosim = CoSim(topo, cfg, schedule=_training(30.0))
    cosim.schedule_capacity_change(10.0, edge_id=0, new_rps=0.0)
    res = cosim.run()
    assert not np.array_equal(res.log.latency_ms, plain.log.latency_ms)
    rules = np.asarray(res.log.rule)
    e0 = np.isin(res.log.device, np.nonzero(topo.assign == 0)[0])
    after = (res.log.t >= 10.0) & e0
    # the dead-rate edge admits nothing: its busy devices all overflow
    assert np.all(rules[after & (np.asarray(res.log.tier) == 1)]
                  != "R1") or not np.any(after)
    assert cosim.proc.edges[0].capacity_rps == 0.0


def test_cosim_node_failure_spills_to_cloud():
    topo, *_ = _hot_zone()
    cfg = CoSimConfig(duration_s=30.0, seed=0)
    cosim = CoSim(topo, cfg, schedule=_training(30.0))
    cosim.schedule_failure(10.0, edge_id=0)
    res = cosim.run()
    rules = np.asarray(res.log.rule)
    e0 = np.isin(res.log.device, np.nonzero(topo.assign == 0)[0])
    before = rules[(res.log.t < 10.0) & e0]
    after = rules[(res.log.t >= 10.0) & e0]
    assert np.mean(after == "R3-overflow") > np.mean(before == "R3-overflow")
    # without a reactive loop nobody re-clusters
    assert res.reconfig_times == []
