"""Unified training–inference co-simulation.

Runs continual HFL training rounds and inference serving on the *same*
per-node compute timeline: the round schedule (``fl.hierarchy.
round_schedule``) becomes typed events on the shared event core, each
participating device's local epochs mark it busy (rule R1 offloads its
requests) and claim compute, aggregation uploads occupy the edges (and
the cloud on global rounds), and the interference model stretches
service times for whatever the node still serves.  Inference requests
ride the same heap via the ``RequestProcessor`` that also powers the
inference-only ``routing.simulator``.

An optional reactive loop (``sim.reactive.ReactiveLoop``) watches the
telemetry this engine emits and drives the learning controller's
``on_node_failure`` / ``on_capacity_change`` / ``on_accuracy_alarm``
hooks mid-simulation, swapping re-clustered deployments back in with a
modeled replica-migration cost.

Determinism: all randomness flows through one ``np.random.Generator``
seeded from ``CoSimConfig.seed`` (device speed factors first, then the
arrival streams, then per-request RTT draws in event order), so the
same seed yields an identical event trace and request log.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.topology import ClusterTopology
from repro.fl.hierarchy import RoundWindow
from repro.routing.latency import LatencyModel
from repro.routing.rules import RouteDecision
from repro.routing.simulator import RequestLog, RequestProcessor
from repro.serving.workload import poisson_requests
from repro.sim.events import Event, EventKind, Simulation
from repro.sim.interference import InterferenceConfig, InterferenceModel


@dataclass
class CoSimConfig:
    duration_s: float = 300.0
    seed: int = 0
    rate_scale: float = 1.0
    latency: LatencyModel = field(default_factory=LatencyModel)
    interference: InterferenceConfig = field(
        default_factory=InterferenceConfig)
    speed_spread: float = 0.3        # device heterogeneity: fastest device
    #                                  runs an epoch in (1-spread) x nominal
    telemetry_s: float = 2.0         # reactive monitor tick period
    reconfig_s: float = 5.0          # replica migration duration
    reconfig_penalty_ms: float = 25.0  # per-request cost while migrating
    record_trace: bool = True


@dataclass
class CoSimResult:
    log: RequestLog
    trace: List[Tuple[float, str, int]]
    rounds_completed: int
    reconfig_times: List[float]
    mse_series: np.ndarray           # (k, 2) [t, modeled val MSE]
    actions: List[Tuple[float, str]]  # reactive-loop decisions


class CoSim:
    """One co-simulation run over a topology.  ``schedule`` is the
    training timeline (None -> serving only); ``reactive`` an optional
    ``ReactiveLoop`` bound to a ``LearningController``."""

    def __init__(self, topo: ClusterTopology, cfg: CoSimConfig,
                 schedule: Optional[Sequence[RoundWindow]] = None,
                 reactive=None):
        self.cfg = cfg
        self.sim = Simulation(record_trace=cfg.record_trace)
        self.rng = np.random.default_rng(cfg.seed)
        n = topo.n_devices
        # per-device epoch-time multiplier in [1-spread, 1]: every device
        # finishes its local epochs by the round's nominal compute_end
        self.speed = 1.0 - cfg.speed_spread * self.rng.random(n)
        self.interference = InterferenceModel(cfg.latency, cfg.interference)
        self.proc = RequestProcessor(
            topo, self.rng, latency=cfg.latency, busy_fn=self._busy,
            service_fn=self.interference.service_ms,
            extra_ms_fn=self._reconfig_penalty)
        self.proc.bind(self.sim)

        self._busy_count = np.zeros(n, dtype=int)
        self._epochs_left: Dict[Tuple[int, int], np.ndarray] = {}
        self._active_rounds = 0
        self._active_aggs: Set[Tuple[int, int]] = set()
        self._sched_count = 0
        self.rounds_completed = 0
        self.last_round_end = -math.inf
        self.reconfig_until = -math.inf
        self.reconfig_times: List[float] = []
        self.reactive = reactive

        s = self.sim
        s.on(EventKind.ROUND_START, self._on_round_start)
        s.on(EventKind.EPOCH_START, self._on_epoch_start)
        s.on(EventKind.EPOCH_END, self._on_epoch_end)
        s.on(EventKind.AGG_START, self._on_agg_start)
        s.on(EventKind.AGG_END, self._on_agg_end)
        s.on(EventKind.ROUND_END, self._on_round_end)
        s.on(EventKind.NODE_FAILURE,
             lambda sim, ev: self.proc.fail_edge(ev.node))
        s.on(EventKind.CAPACITY_CHANGE, self._on_capacity_change)
        s.on(EventKind.RECONFIG_END, self._on_reconfig_end)

        for ev in poisson_requests(topo.lam * cfg.rate_scale,
                                   cfg.duration_s, self.rng):
            s.schedule(ev.t, EventKind.REQUEST_ARRIVAL, node=ev.device)
        if schedule is not None:
            self.add_training(schedule)
        if reactive is not None:
            reactive.bind(self)

    # -- environment / workload injection -----------------------------------

    def add_training(self, windows: Sequence[RoundWindow]) -> int:
        """Schedule a training burst: round/epoch/aggregation events for
        every window.  Returns the schedule id (sources in the
        interference model are tagged with it, so overlapping bursts
        compose instead of clobbering each other)."""
        sid = self._sched_count
        self._sched_count += 1
        for w in windows:
            self.sim.schedule(w.start, EventKind.ROUND_START,
                              payload=(sid, w))
            self.sim.schedule(w.compute_end, EventKind.AGG_START,
                              payload=(sid, w))
            self.sim.schedule(w.upload_end, EventKind.AGG_END,
                              payload=(sid, w))
            self.sim.schedule(w.upload_end, EventKind.ROUND_END,
                              payload=(sid, w))
        return sid

    def schedule_failure(self, t: float, edge_id: int) -> None:
        self.sim.schedule(t, EventKind.NODE_FAILURE, node=edge_id)

    def schedule_capacity_change(self, t: float, edge_id: int,
                                 new_rps: float) -> None:
        self.sim.schedule(t, EventKind.CAPACITY_CHANGE, node=edge_id,
                          payload=float(new_rps))

    def schedule_drift(self, t: float, drift_mse: Optional[float] = None,
                       ) -> None:
        self.sim.schedule(t, EventKind.DRIFT_ONSET, payload=drift_mse)

    # -- training timeline handlers -----------------------------------------

    def _on_round_start(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_rounds += 1
        nominal = (w.compute_end - w.start) / max(w.local_epochs, 1)
        assign = self.proc.topo.assign
        participants = np.nonzero(assign >= 0)[0]
        if participants.size == 0:   # flat FL: every device trains
            participants = np.arange(len(assign))
        left = np.zeros(len(assign), dtype=int)
        for i in participants:
            e_i = nominal * self.speed[i]
            for k in range(w.local_epochs):
                sim.schedule(w.start + k * e_i, EventKind.EPOCH_START,
                             node=int(i), payload=(sid, w))
                sim.schedule(w.start + (k + 1) * e_i, EventKind.EPOCH_END,
                             node=int(i), payload=(sid, w))
            left[i] = w.local_epochs
        self._epochs_left[(sid, w.index)] = left

    def _on_epoch_start(self, sim: Simulation, ev: Event) -> None:
        i = ev.node
        self._busy_count[i] += 1
        self.interference.set_demand(("device", i), "epoch",
                                     self.cfg.interference.device_train_share)

    def _on_epoch_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        i = ev.node
        self._busy_count[i] -= 1
        left = self._epochs_left[(sid, w.index)]
        left[i] -= 1
        if self._busy_count[i] == 0:
            self.interference.set_demand(("device", i), "epoch", 0.0)
            if left[i] == 0:
                # epochs done, round still open: residual work (checkpoint,
                # next-window data prep) degrades on-device serving
                self.interference.set_demand(
                    ("device", i), f"res{sid}:{w.index}",
                    self.cfg.interference.device_residual_share)

    def _on_agg_start(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_aggs.add((sid, w.index))
        share = self.cfg.interference.edge_agg_share
        for j in self.proc.edges:
            self.interference.set_demand(("edge", j), f"agg{sid}:{w.index}",
                                         share)
        if w.is_global:
            self.interference.set_demand(("cloud", 0),
                                         f"agg{sid}:{w.index}",
                                         self.cfg.interference.
                                         cloud_agg_share)

    def _on_agg_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_aggs.discard((sid, w.index))
        src = f"agg{sid}:{w.index}"
        for j in self.proc.edges:
            self.interference.set_demand(("edge", j), src, 0.0)
        self.interference.set_demand(("cloud", 0), src, 0.0)

    def _on_round_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        self._active_rounds -= 1
        src = f"res{sid}:{w.index}"
        for i in range(len(self._busy_count)):
            self.interference.set_demand(("device", i), src, 0.0)
        self._epochs_left.pop((sid, w.index), None)
        self.rounds_completed += 1
        self.last_round_end = sim.now

    def _on_capacity_change(self, sim: Simulation, ev: Event) -> None:
        """Apply the new rate to the edge's admission state even without
        a reactive loop (which would additionally re-cluster): the edge
        host genuinely got slower/faster, reactions or not."""
        st = self.proc.edges.get(int(ev.node))
        if st is not None:
            st.capacity_rps = float(ev.payload)
            st.tokens = min(st.tokens, st.capacity_rps * st.burst_s)

    # -- reactive-deployment plumbing ---------------------------------------

    def apply_deployment(self, deployment) -> None:
        """Swap in a re-clustered deployment mid-simulation, paying a
        modeled reconfiguration cost: replicas migrate for
        ``reconfig_s`` seconds during which edges carry migration load
        and every edge-touching request pays ``reconfig_penalty_ms``."""
        t = self.sim.now
        self.proc.set_topology(deployment.topology)
        # demands were keyed by old edge ids: rebuild edge-tier state
        self.interference.clear_tier("edge")
        share = self.cfg.interference.edge_agg_share
        for sid, idx in self._active_aggs:
            for j in self.proc.edges:
                self.interference.set_demand(("edge", j),
                                             f"agg{sid}:{idx}", share)
        for j in self.proc.edges:
            self.interference.set_demand(
                ("edge", j), "migration",
                self.cfg.interference.migration_share)
        self.reconfig_until = t + self.cfg.reconfig_s
        self.reconfig_times.append(t)
        self.sim.schedule(self.reconfig_until, EventKind.RECONFIG_END)

    def _on_reconfig_end(self, sim: Simulation, ev: Event) -> None:
        if sim.now >= self.reconfig_until:
            self.interference.clear_tier("edge", "migration")

    # -- pluggable policies for the request processor -----------------------

    @property
    def training_active(self) -> bool:
        return self._active_rounds > 0

    def _busy(self, i: int, t: float) -> bool:
        return self._busy_count[i] > 0

    def _reconfig_penalty(self, dec: RouteDecision, t: float) -> float:
        if t < self.reconfig_until and dec.edge is not None:
            return self.cfg.reconfig_penalty_ms
        return 0.0

    # -- run ----------------------------------------------------------------

    def run(self) -> CoSimResult:
        self.sim.run(until=self.cfg.duration_s)
        mse = (np.asarray(self.reactive.mse_series)
               if self.reactive is not None and self.reactive.mse_series
               else np.zeros((0, 2)))
        actions = (list(self.reactive.actions)
                   if self.reactive is not None else [])
        return CoSimResult(log=self.proc.log(), trace=list(self.sim.trace),
                           rounds_completed=self.rounds_completed,
                           reconfig_times=list(self.reconfig_times),
                           mse_series=mse, actions=actions)
