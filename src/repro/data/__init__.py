from repro.data.traffic import (TrafficDataset, continual_split, generate,
                                inject_drift, select_fl_sensors,
                                windows_for_sensor)
from repro.data.tokens import TokenStream, TokenStreamConfig

__all__ = ["TrafficDataset", "continual_split", "generate",
           "inject_drift", "select_fl_sensors", "windows_for_sensor",
           "TokenStream", "TokenStreamConfig"]
