"""Tiered serving subsystem.

Workload generation (numpy-only) is imported eagerly; the jax-backed
engine/replica/scheduler are lazy (PEP 562) so that numpy-only
consumers — the routing simulator sources its Poisson arrivals from
``serving.workload`` — don't pay (or require) the jax import.
"""
import importlib

from repro.serving.workload import (RequestEvent, batched_arrivals,
                                    poisson_request_arrays,
                                    poisson_requests)

_LAZY = {
    "EngineMeasurement": "repro.serving.engine",
    "PagedServeEngine": "repro.serving.engine",
    "ServeEngine": "repro.serving.engine",
    "bucket_len": "repro.serving.engine",
    "PagePool": "repro.serving.page_pool",
    "PagesExhausted": "repro.serving.page_pool",
    "DEFAULT_TIERS": "repro.serving.replica",
    "FAILOVER_ORDER": "repro.serving.replica",
    "HEALTH_STATES": "repro.serving.replica",
    "ReplicaPool": "repro.serving.replica",
    "TierSpec": "repro.serving.replica",
    "lm_tiers": "repro.serving.replica",
    "paged_lm_tiers": "repro.serving.replica",
    "ContinuousBatchingScheduler": "repro.serving.scheduler",
    "Request": "repro.serving.scheduler",
    "ScheduleStats": "repro.serving.scheduler",
    "requests_from_events": "repro.serving.scheduler",
}

__all__ = ["RequestEvent", "batched_arrivals", "poisson_request_arrays",
           "poisson_requests"] + list(_LAZY)


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(module), name)
