"""Seeded, deterministic chaos plans for the co-simulation.

A :class:`FaultPlan` describes *where and when* things break on the
continuum: edge/aggregator crash-and-recover cycles (MTTF/MTTR draws),
transient network partitions, request-drop and latency-spike bursts,
and correlated failure domains spanning whole LAN groups.  Plans are
pure descriptions — :func:`compile_plan` materializes them into sorted
:class:`FaultWindow` intervals using **only** the generator passed in,
which the co-sim wires to the shared per-run stream (contract DET003:
no fresh ``default_rng`` in fault or retry code).  The co-sim turns
each window into a ``FAULT_START``/``FAULT_END`` control-event pair,
so the same compiled plan drives the heap and the batched engines to
bit-identical fault timelines.

Non-perturbation contract: a run that never calls
``CoSim.schedule_faults`` draws nothing from this module and schedules
no fault events — its fingerprints are bit-identical to a build
without the chaos subsystem (pinned in ``tests/test_faults.py``
against ``tests/data/golden_fingerprints.json``).

Recipes::

    # one edge crashing and recovering (exponential MTTF/MTTR)
    EdgeOutagePlan(mttf_s=60.0, mttr_s=8.0, edges=(1,))

    # a whole LAN failure domain going dark together
    DomainOutagePlan(domains=((0, 1), (2, 3)), mttf_s=120.0, mttr_s=10.0)

    # transient partition: edge 2 unreachable for 15 s starting at t=30
    PartitionPlan(windows=((30.0, 45.0),), edges=(2,))

    # 20% request drops on edge 0 in recurring bursts
    DropBurstPlan(p_drop=0.2, every_s=40.0, burst_s=6.0, edges=(0,))

    # +12 ms network spike on every edge between t=50 and t=70
    LatencySpikePlan(windows=((50.0, 70.0),), spike_ms=12.0)

    # compose freely
    plan = EdgeOutagePlan(...) + DropBurstPlan(...)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

#: fault window kinds (``FaultWindow.kind``)
FAULT_CRASH = "crash"          # edge host down: attempts fail, retry/failover
FAULT_PARTITION = "partition"  # transiently unreachable: same request-plane
#                                effect as a crash, but no standby promotion
FAULT_DROP = "drop"            # edge serves, but drops requests w.p. param
FAULT_SPIKE = "spike"          # edge serves, +param ms network latency

#: kinds that make an edge unreachable to the request plane
DOWN_KINDS = frozenset({FAULT_CRASH, FAULT_PARTITION})


@dataclass(frozen=True)
class FaultWindow:
    """One materialized fault interval ``[t0, t1)`` on a set of edges.
    ``param`` is the drop probability (``drop``) or the added latency
    in ms (``spike``); unused for crash/partition."""
    t0: float
    t1: float
    kind: str
    edges: Tuple[int, ...]
    param: float = 0.0


class FaultPlan:
    """Base class: a composable, declarative chaos description.
    Subclasses implement :meth:`windows`; ``plan_a + plan_b`` composes.
    """

    def windows(self, rng: np.random.Generator, n_edges: int,
                duration_s: float) -> List[FaultWindow]:
        raise NotImplementedError

    def __add__(self, other: "FaultPlan") -> "ComposedPlan":
        mine = self.plans if isinstance(self, ComposedPlan) else (self,)
        theirs = (other.plans if isinstance(other, ComposedPlan)
                  else (other,))
        return ComposedPlan(plans=tuple(mine) + tuple(theirs))


@dataclass(frozen=True)
class ComposedPlan(FaultPlan):
    plans: Tuple[FaultPlan, ...] = ()

    def windows(self, rng, n_edges, duration_s):
        out: List[FaultWindow] = []
        for p in self.plans:          # fixed order: one shared draw stream
            out.extend(p.windows(rng, n_edges, duration_s))
        return out


def _resolve_edges(edges: Optional[Sequence[int]],
                   n_edges: int) -> Tuple[int, ...]:
    if edges is None:
        return tuple(range(n_edges))
    return tuple(int(e) for e in edges)


def _alternating_windows(rng: np.random.Generator, mttf_s: float,
                         mttr_s: float, start_s: float,
                         duration_s: float) -> List[Tuple[float, float]]:
    """Up/down renewal process: exponential time-to-failure, then
    exponential time-to-repair, repeated until the horizon.  One
    ``rng.exponential`` draw per phase, in timeline order — the draw
    sequence is the plan's identity."""
    out: List[Tuple[float, float]] = []
    t = start_s
    while t < duration_s:
        t += float(rng.exponential(mttf_s))
        if t >= duration_s:
            break
        dt = float(rng.exponential(mttr_s))
        out.append((t, min(t + dt, duration_s)))
        t += dt
    return out


@dataclass(frozen=True)
class EdgeOutagePlan(FaultPlan):
    """Independent crash-and-recover cycles per edge (aggregator
    hosts *are* edges in this stack, so this is also the aggregator
    crash plan).  Draws per edge in ascending edge order."""
    mttf_s: float
    mttr_s: float
    edges: Optional[Tuple[int, ...]] = None   # None = all edges
    start_s: float = 0.0
    kind: str = FAULT_CRASH

    def windows(self, rng, n_edges, duration_s):
        out: List[FaultWindow] = []
        for e in sorted(_resolve_edges(self.edges, n_edges)):
            for t0, t1 in _alternating_windows(
                    rng, self.mttf_s, self.mttr_s, self.start_s,
                    duration_s):
                out.append(FaultWindow(t0, t1, self.kind, (e,)))
        return out


@dataclass(frozen=True)
class DomainOutagePlan(FaultPlan):
    """Correlated failure domains: every edge of a domain (a LAN
    group, a rack, a shared uplink) goes down and recovers *together*
    — one MTTF/MTTR draw stream per domain, not per edge."""
    domains: Tuple[Tuple[int, ...], ...]
    mttf_s: float
    mttr_s: float
    start_s: float = 0.0
    kind: str = FAULT_CRASH

    def windows(self, rng, n_edges, duration_s):
        out: List[FaultWindow] = []
        for dom in self.domains:
            edges = tuple(sorted(int(e) for e in dom))
            for t0, t1 in _alternating_windows(
                    rng, self.mttf_s, self.mttr_s, self.start_s,
                    duration_s):
                out.append(FaultWindow(t0, t1, self.kind, edges))
        return out


@dataclass(frozen=True)
class PartitionPlan(FaultPlan):
    """Transient network partitions at fixed times (no draws): the
    edges are unreachable during each window but their state (bucket,
    in-flight training) survives — the request plane treats this
    exactly like a crash, but the co-sim skips standby promotion."""
    windows_s: Tuple[Tuple[float, float], ...]
    edges: Optional[Tuple[int, ...]] = None

    def windows(self, rng, n_edges, duration_s):
        edges = _resolve_edges(self.edges, n_edges)
        return [FaultWindow(float(t0), min(float(t1), duration_s),
                            FAULT_PARTITION, edges)
                for t0, t1 in self.windows_s if t0 < duration_s]


@dataclass(frozen=True)
class DropBurstPlan(FaultPlan):
    """Recurring request-drop bursts: every ``every_s`` (exponential
    gaps), the affected edges drop each served request with
    probability ``p_drop`` for ``burst_s`` seconds."""
    p_drop: float
    every_s: float
    burst_s: float
    edges: Optional[Tuple[int, ...]] = None
    start_s: float = 0.0

    def windows(self, rng, n_edges, duration_s):
        edges = _resolve_edges(self.edges, n_edges)
        out: List[FaultWindow] = []
        t = self.start_s
        while True:
            t += float(rng.exponential(self.every_s))
            if t >= duration_s:
                break
            out.append(FaultWindow(t, min(t + self.burst_s, duration_s),
                                   FAULT_DROP, edges, self.p_drop))
            t += self.burst_s
        return out


@dataclass(frozen=True)
class LatencySpikePlan(FaultPlan):
    """Fixed latency-spike windows: +``spike_ms`` on every request
    that touches an affected edge (served there or transiting it).
    Purely deterministic — no draws, no drops, no retries."""
    windows_s: Tuple[Tuple[float, float], ...]
    spike_ms: float
    edges: Optional[Tuple[int, ...]] = None

    def windows(self, rng, n_edges, duration_s):
        edges = _resolve_edges(self.edges, n_edges)
        return [FaultWindow(float(t0), min(float(t1), duration_s),
                            FAULT_SPIKE, edges, self.spike_ms)
                for t0, t1 in self.windows_s if t0 < duration_s]


def compile_plan(plan: FaultPlan, rng: np.random.Generator,
                 n_edges: int, duration_s: float) -> List[FaultWindow]:
    """Materialize ``plan`` into a sorted list of non-empty fault
    windows clipped to ``[0, duration_s)``.  All randomness comes from
    ``rng`` — the co-sim passes its shared per-run generator, so the
    compiled timeline is identical across engines and runs."""
    wins = [w for w in plan.windows(rng, n_edges, duration_s)
            if w.t1 > w.t0 and w.t0 < duration_s]
    wins.sort(key=lambda w: (w.t0, w.t1, w.kind, w.edges))
    return wins
