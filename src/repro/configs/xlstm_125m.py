"""xlstm-125m [ssm] — sLSTM + mLSTM blocks, no separate FFN (d_ff=0).

12L d_model=768 4H vocab=50304.
[arXiv:2405.04517]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig, XLSTMConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="xlstm-125m",
        family="ssm",
        source="arXiv:2405.04517",
        num_layers=12,
        d_model=768,
        d_ff=0,                     # blocks carry their own up-projection
        vocab_size=50_304,
        norm="layernorm",
        attention=AttentionConfig(kind="none", num_heads=4, num_kv_heads=4,
                                  head_dim=192),
        xlstm=XLSTMConfig(num_heads=4, slstm_layers=(3, 9),
                          proj_factor_mlstm=2.0, proj_factor_slstm=1.333,
                          conv_width=4),
        tie_embeddings=True,
    ),
    run=RunConfig(microbatches=1, remat="layer", max_cache_len=524_288),
)
