#!/usr/bin/env bash
# CI entry point: the repo's tier-1 verification in one command.
#   scripts/ci.sh            # run the tier-1 test suite
#   scripts/ci.sh -k serving # pass extra pytest args through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
