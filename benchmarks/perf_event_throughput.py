"""Request-engine throughput: vectorized batched plane vs per-request
heap, on the paper's Fig. 7 configuration.

Measures end-to-end ``simulate()`` wall-clock (arrival generation,
routing, admission, service, logging) for both engines on the same
seeded workload and reports simulated requests per second, the
batched/heap speedup, and the distributional parity (p50/p95 relative
difference, tier fractions).  A second section runs the full
co-simulation (training interference + reactive loop) both ways and
checks the stronger co-sim guarantee: **bit-identical** request logs
and control-plane trace fingerprints — there routing is deterministic
and the batched engine consumes the RTT stream in heap order.

  python -m benchmarks.perf_event_throughput             # full (~1 min)
  python -m benchmarks.perf_event_throughput --smoke     # CI seconds
  python -m benchmarks.perf_event_throughput --rate-scale 100  # 10^6 reqs
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import solve_heuristic
from repro.core.topology import ClusterTopology
from repro.routing import SimConfig, simulate
from repro.sim.events import control_trace
from repro.sim.scenarios import SCENARIOS, run_scenario

from benchmarks.common import emit
from benchmarks.fig7_inference_latency import build_scenario


def fig7_topology(seed: int = 0) -> ClusterTopology:
    """The Fig. 7 hot-zone continuum under the HFLOP assignment."""
    inst, _ = build_scenario(seed)
    sol = solve_heuristic(inst)
    return ClusterTopology(assign=np.asarray(sol.assign),
                           n_devices=inst.n, n_edges=inst.m,
                           lam=inst.lam, r=inst.r, l=inst.l)


def run(duration_s: float = 600.0, rate_scale: float = 1.0, seed: int = 0,
        parity_scenarios: Tuple[str, ...] = ("straggler", "churn"),
        parity_duration_s: float = 60.0) -> Dict[str, float]:
    """One engine-vs-engine measurement + parity check.  Returns the
    headline numbers (also CSV-emitted)."""
    topo = fig7_topology(seed)
    out: Dict[str, float] = {}
    logs = {}
    for engine in ("heap", "batched"):
        cfg = SimConfig(duration_s=duration_s, seed=seed, engine=engine,
                        rate_scale=rate_scale)
        t0 = time.perf_counter()
        log = simulate(topo, cfg)
        wall = time.perf_counter() - t0
        logs[engine] = log
        rps = log.t.size / wall if wall > 0 else float("inf")
        out[f"{engine}_requests_per_s"] = rps
        emit(f"event_engine_{engine}", wall * 1e6,
             f"requests={log.t.size};wall_s={wall:.3f};"
             f"requests_per_s={rps:.0f};rate_scale={rate_scale:g}")
    speedup = (out["batched_requests_per_s"]
               / max(out["heap_requests_per_s"], 1e-9))
    out["speedup"] = speedup
    emit("event_engine_speedup", speedup,
         f"speedup={speedup:.1f};target=50")

    # distributional parity on the inference-only path (the busy coin
    # flip interleaves generator draws differently per engine, so the
    # logs agree in distribution, not bit-for-bit)
    lh, lb = logs["heap"], logs["batched"]
    p50h, p50b = lh.percentile_latency(50), lb.percentile_latency(50)
    p95h, p95b = lh.percentile_latency(95), lb.percentile_latency(95)
    d50 = abs(p50h - p50b) / max(p50h, 1e-9)
    d95 = abs(p95h - p95b) / max(p95h, 1e-9)
    tiers_match = np.array_equal(lh.tier, lb.tier)
    out["p50_rel_diff"], out["p95_rel_diff"] = d50, d95
    emit("event_engine_parity_simulate", max(d50, d95) * 1e6,
         f"p50_rel_diff={d50:.5f};p95_rel_diff={d95:.5f};"
         f"tiers_identical={'yes' if tiers_match else 'NO'};tol=0.01")

    # bit-exact parity on the co-sim path, across the scenario engine
    all_bit = True
    for sc_name in parity_scenarios:
        for policy in ("reactive", "budgeted"):
            rb = run_scenario(SCENARIOS[sc_name](), policy=policy,
                              seed=seed, duration_s=parity_duration_s,
                              engine="batched")
            rh = run_scenario(SCENARIOS[sc_name](), policy=policy,
                              seed=seed, duration_s=parity_duration_s,
                              engine="heap")
            bit = (rb.control_fingerprint() == rh.control_fingerprint()
                   and np.array_equal(rb.log.latency_ms, rh.log.latency_ms)
                   and control_trace(rb.trace) == control_trace(rh.trace))
            all_bit &= bit
            emit(f"event_engine_parity_{sc_name}_{policy}",
                 0.0 if bit else 1.0,
                 f"control_fp_identical={'yes' if bit else 'NO'};"
                 f"n_requests={rb.log.t.size}")
    out["cosim_bit_identical"] = 1.0 if all_bit else 0.0
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="lambda multiplier (100 -> ~10^6 requests; "
                         "the heap side is what takes the time)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI sizes (shorter horizon)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        out = run(duration_s=240.0, rate_scale=args.rate_scale,
                  seed=args.seed, parity_duration_s=45.0)
    else:
        out = run(duration_s=args.duration, rate_scale=args.rate_scale,
                  seed=args.seed)
    print(f"\nbatched {out['batched_requests_per_s']:,.0f} req/s vs heap "
          f"{out['heap_requests_per_s']:,.0f} req/s -> "
          f"{out['speedup']:.1f}x; p50/p95 parity "
          f"{out['p50_rel_diff']:.5f}/{out['p95_rel_diff']:.5f}; "
          f"co-sim bit-identical: "
          f"{'yes' if out['cosim_bit_identical'] else 'NO'}")


if __name__ == "__main__":
    main()
