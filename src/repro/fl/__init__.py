"""Hierarchical federated learning subsystem.

The round-timeline types (``repro.fl.schedule``: numpy/stdlib-only)
are imported eagerly; everything else — aggregation, clients,
collectives, compression, the continual-HFL runner — is jax-backed and
lazy (PEP 562), so the co-simulation stack (``repro.sim`` imports
``round_schedule``) stays a jax-free importer (contract LAYER001).
"""
import importlib

from repro.fl.schedule import RoundWindow, round_schedule

_LAZY = {
    "cluster_fedavg": "repro.fl.aggregation",
    "fedavg": "repro.fl.aggregation",
    "global_fedavg": "repro.fl.aggregation",
    "ClientBatch": "repro.fl.client",
    "eval_clients": "repro.fl.client",
    "stack_clients": "repro.fl.client",
    "train_clients_locally": "repro.fl.client",
    "unstack_client": "repro.fl.client",
    "cluster_divergence": "repro.fl.collectives",
    "cluster_slice": "repro.fl.collectives",
    "flat_allreduce": "repro.fl.collectives",
    "global_sync": "repro.fl.collectives",
    "hierarchical_allreduce": "repro.fl.collectives",
    "stack_for_clusters": "repro.fl.collectives",
    "EFState": "repro.fl.compression",
    "compressed_global_sync": "repro.fl.compression",
    "dequantize_int8": "repro.fl.compression",
    "init_ef_state": "repro.fl.compression",
    "quantize_int8": "repro.fl.compression",
    "sync_bytes": "repro.fl.compression",
    "ContinualHFL": "repro.fl.hierarchy",
    "HFLResult": "repro.fl.hierarchy",
    "HFLRunConfig": "repro.fl.hierarchy",
    "continuous_vs_static": "repro.fl.hierarchy",
}

__all__ = ["RoundWindow", "round_schedule"] + list(_LAZY)


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(module), name)
