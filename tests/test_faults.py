"""Chaos subsystem: fault plans, retry/failover parity, standby
promotion, quorum accounting, and the non-perturbation contract.

Two hard guarantees anchor this file:

- **Non-perturbation**: with no chaos plan installed — or a plan that
  compiles to zero windows — every scenario cell is bit-identical to
  the pre-fault-subsystem goldens (``tests/data/golden_fingerprints
  .json``), both engines, all policies.
- **Engine parity**: with faults enabled, the heap and batched engines
  produce bit-identical control fingerprints, request logs, and
  fault/retry/failover accounting.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.sim.scenarios import (SCENARIOS, Scenario, domain_outage_scenario,
                                 outage_scenario, run_scenario)
from repro.sim.faults import (FAULT_CRASH, FAULT_PARTITION, FaultPlan,
                              FaultWindow, DropBurstPlan, EdgeOutagePlan,
                              PartitionPlan, compile_plan)
from repro.sim.request_plane import RetryPolicy, backoff_delay

DATA = pathlib.Path(__file__).parent / "data"

GOLDEN_SCENARIOS = ("baseline", "straggler", "mobility", "multi_tenant",
                    "churn")
POLICIES = ("static", "reactive", "budgeted")


def _capture(scenario: Scenario):
    """Wrap a scenario so the test can read the CoSim after the run."""
    box = {}

    def inject(cosim):
        box["cosim"] = cosim
        scenario.inject(cosim)

    return Scenario(scenario.name, scenario.description, inject), box


# ---------------------------------------------------------------------------
# non-perturbation
# ---------------------------------------------------------------------------

def test_goldens_bit_identical_without_faults():
    """Every pre-existing scenario cell (5 scenarios x 3 policies x 2
    engines) matches the golden fingerprints recorded before the chaos
    subsystem landed — faults disabled perturb *nothing*."""
    golden = json.loads((DATA / "golden_fingerprints.json").read_text())
    assert len(golden) == 30
    for key, want in golden.items():
        name, policy, engine = key.split("|")
        res = run_scenario(SCENARIOS[name](), policy=policy, seed=0,
                           duration_s=40.0, engine=engine)
        assert res.fingerprint() == want["fingerprint"], key
        assert res.control_fingerprint() == want["control_fingerprint"], key
        assert res.n_requests == want["n_requests"], key


@pytest.mark.parametrize("engine", ["heap", "batched"])
def test_armed_but_empty_plan_is_identity(engine):
    """Arming the retry core with a plan that compiles to zero windows
    must not move a single bit: the heap engine then routes every
    request through the scalar core, so this pins the claim that
    ``_serve_attempt`` reproduces the fault-free path exactly."""
    empty = PartitionPlan(windows_s=())

    def inject(cosim):
        cosim.schedule_faults(empty, standby=True, quorum=0.5)

    plain = run_scenario(SCENARIOS["baseline"](), policy="reactive",
                         seed=1, duration_s=25.0, engine=engine)
    armed = run_scenario(Scenario("armed", "", inject), policy="reactive",
                         seed=1, duration_s=25.0, engine=engine)
    assert armed.fingerprint() == plain.fingerprint()
    assert armed.n_requests == plain.n_requests


# ---------------------------------------------------------------------------
# fault plans compile deterministically
# ---------------------------------------------------------------------------

def test_compiled_plan_deterministic_and_clipped():
    plan = (EdgeOutagePlan(mttf_s=5.0, mttr_s=2.0, edges=(0, 1))
            + DropBurstPlan(p_drop=0.4, every_s=6.0, burst_s=2.0)
            + PartitionPlan(windows_s=((3.0, 80.0),), edges=(2,)))
    a = compile_plan(plan, np.random.default_rng(9), n_edges=4,
                     duration_s=30.0)
    b = compile_plan(plan, np.random.default_rng(9), n_edges=4,
                     duration_s=30.0)
    assert a == b
    assert all(w.t1 <= 30.0 and w.t0 < w.t1 for w in a)
    assert any(w.kind == FAULT_PARTITION for w in a)
    # a different stream moves the renewal windows
    c = compile_plan(plan, np.random.default_rng(10), n_edges=4,
                     duration_s=30.0)
    assert c != a


# ---------------------------------------------------------------------------
# engine parity with faults live
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,policy", [
    ("outage", "static"), ("outage", "reactive"),
    ("domain_outage", "reactive")])
def test_fault_scenarios_engine_parity(name, policy):
    """Heap and batched engines agree bit-for-bit on the control
    trace, the request log, and every fault counter — while the chaos
    actually engages (nonzero attempts, retries and failovers or
    drops), so the parity is not vacuous."""
    rows = {}
    for engine in ("heap", "batched"):
        sc, box = _capture(SCENARIOS[name]())
        res = run_scenario(sc, policy=policy, seed=0, duration_s=40.0,
                           engine=engine)
        p = box["cosim"].proc
        rows[engine] = dict(
            fp=res.control_fingerprint(),
            t=np.asarray(res.log.t), lat=np.asarray(res.log.latency_ms),
            tier=np.asarray(res.log.tier), rule=list(res.log.rule),
            attempts=p.fault_attempts, retries=p.retries_scheduled,
            dispatched=p.retries_dispatched, failovers=p.failovers,
            drops=p.fault_drops)
    h, b = rows["heap"], rows["batched"]
    assert h["fp"] == b["fp"]
    assert np.array_equal(h["t"], b["t"])
    assert np.array_equal(h["lat"], b["lat"])
    assert np.array_equal(h["tier"], b["tier"])
    assert h["rule"] == b["rule"]
    for k in ("attempts", "retries", "dispatched", "failovers", "drops"):
        assert h[k] == b[k], k
    assert h["attempts"] > 0
    assert h["retries"] > 0


def test_failover_rule_logged_and_latency_includes_backoff():
    """Exhausted retries fail over to the cloud under rule
    ``R4-failover`` and the logged latency folds in the wait since the
    original arrival."""
    sc, box = _capture(outage_scenario())
    res = run_scenario(sc, policy="static", seed=0, duration_s=40.0,
                       engine="batched")
    p = box["cosim"].proc
    rules = np.asarray(res.log.rule)
    n_failover = int(np.sum(rules == "R4-failover"))
    assert n_failover == p.failovers > 0
    # failed-over requests waited through >= 1 backoff, so their
    # latencies dominate the overall median
    lat = np.asarray(res.log.latency_ms)
    assert np.median(lat[rules == "R4-failover"]) > np.median(lat)


# ---------------------------------------------------------------------------
# accounting identities (the CI hard gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["heap", "batched"])
def test_availability_accounting_identity(engine):
    """Every arrival is logged exactly once unless its retry is still
    pending at the horizon, and every failed attempt either scheduled
    a retry or failed over — no request is silently lost."""
    base = run_scenario(SCENARIOS["baseline"](), policy="static", seed=0,
                        duration_s=40.0, engine=engine)
    sc, box = _capture(outage_scenario())
    res = run_scenario(sc, policy="static", seed=0, duration_s=40.0,
                       engine=engine)
    p = box["cosim"].proc
    pending = p.retries_scheduled - p.retries_dispatched
    assert pending >= 0
    assert res.n_requests + pending == base.n_requests
    assert p.fault_attempts == p.retries_scheduled + p.failovers
    assert 0 <= p.fault_drops <= p.fault_attempts


def test_backoff_delay_capped_exponential():
    pol = RetryPolicy(base_backoff_s=0.1, backoff_cap_s=0.35, jitter=0.5)
    # attempt k doubles the base until the cap; u stretches by jitter
    assert backoff_delay(pol, 0, 0.0) == pytest.approx(0.1)
    assert backoff_delay(pol, 1, 0.0) == pytest.approx(0.2)
    assert backoff_delay(pol, 4, 0.0) == pytest.approx(0.35)
    assert backoff_delay(pol, 0, 1.0) > backoff_delay(pol, 0, 0.0)


# ---------------------------------------------------------------------------
# aggregator warm standby + quorum
# ---------------------------------------------------------------------------

class _FixedCrash(FaultPlan):
    """Crash windows at fixed times (test-only): deterministic standby
    promotion without renewal-draw luck."""

    def __init__(self, windows, edges):
        self.windows_s = tuple(windows)
        self.edges = tuple(edges)

    def windows(self, rng, n_edges, duration_s):
        return [FaultWindow(t0, min(t1, duration_s), FAULT_CRASH,
                            self.edges)
                for t0, t1 in self.windows_s]


@pytest.mark.parametrize("engine", ["heap", "batched"])
def test_standby_promotion_and_restore(engine):
    """A crashed aggregator's devices re-home to the warm standby for
    the outage — absorbing the fault before any request can fail — and
    go home when it recovers."""
    plan = _FixedCrash([(5.0, 15.0)], edges=(0,))

    def inject(cosim):
        inject.home = cosim.proc.topo.assign.copy()
        cosim.schedule_faults(plan, standby=True, quorum=0.0)

    sc, box = _capture(Scenario("standby", "", inject))
    run_scenario(sc, policy="static", seed=0, duration_s=30.0,
                 engine=engine)
    c = box["cosim"]
    assert c.standby_promotions == 1
    # the crash was fully absorbed: no attempt ever failed
    assert c.proc.fault_attempts == 0
    # devices re-homed at FAULT_START went home at FAULT_END
    assert np.array_equal(c.proc.topo.assign, inject.home)
    assert [(round(t, 3), what) for t, what, _, _ in c.fault_log] == [
        (5.0, "start"), (15.0, "end")]


def test_standby_disabled_exposes_crash_to_request_plane():
    plan = _FixedCrash([(5.0, 15.0)], edges=(0,))

    def inject(cosim):
        cosim.schedule_faults(plan, standby=False)

    sc, box = _capture(Scenario("nostandby", "", inject))
    run_scenario(sc, policy="static", seed=0, duration_s=30.0,
                 engine="batched")
    c = box["cosim"]
    assert c.standby_promotions == 0
    assert c.proc.fault_attempts > 0


@pytest.mark.parametrize("engine", ["heap", "batched"])
def test_quorum_and_staleness_bound(engine):
    """A partition that strands most devices behind unreachable
    aggregators denies round quorum; consecutive below-quorum rounds
    past the staleness bound are flagged."""
    plan = PartitionPlan(windows_s=((0.0, 100.0),))  # all edges, all run

    def inject(cosim):
        cosim.schedule_faults(plan, standby=False, quorum=0.9,
                              max_stale_rounds=1)

    sc, box = _capture(Scenario("noquorum", "", inject))
    run_scenario(sc, policy="static", seed=0, duration_s=100.0,
                 engine=engine)
    c = box["cosim"]
    assert c.rounds_completed >= 2
    assert c.rounds_below_quorum == c.rounds_completed
    assert not c.last_round_quorum_ok
    assert c.stale_bound_exceeded == c.rounds_completed - 1
