"""Registry of the assigned architectures (+ the paper's own model)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "whisper-small": "repro.configs.whisper_small",
    "stablelm-1.6b": "repro.configs.stablelm_1p6b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1p8b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "llama3-405b": "repro.configs.llama3_405b",
    "gru-traffic": "repro.configs.gru_traffic",
}

ASSIGNED = tuple(k for k in _MODULES if k != "gru-traffic")


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs(include_paper_model: bool = False) -> Dict[str, ArchConfig]:
    names = list(ASSIGNED) + (["gru-traffic"] if include_paper_model else [])
    return {n: get_config(n) for n in names}


def applicable_shapes(cfg: ArchConfig) -> List[InputShape]:
    """The assigned input shapes this arch runs (DESIGN.md §4 table)."""
    shapes = [INPUT_SHAPES["train_4k"], INPUT_SHAPES["prefill_32k"],
              INPUT_SHAPES["decode_32k"]]
    if cfg.model.sub_quadratic:
        shapes.append(INPUT_SHAPES["long_500k"])
    return shapes
