"""Latency model for inference serving (paper §V-C1).

The paper measured HTTP round-trip times: cloud 50-100 ms, edge 8-10 ms.
Processing time is the model's inference time, scaled per serving tier:
Fig. 8 sweeps a "theoretical speedup of up to 95%" of cloud vs edge
compute, i.e. cloud_infer = edge_infer * (1 - speedup).

Two service-time models share this interface:

  - :class:`LatencyModel` — the paper's constant closed-form per-tier
    inference time (the fast default; reproduces Fig. 7/8 exactly);
  - :class:`CalibratedLatencyModel` — per-tier service times *measured*
    from the real serving engines (``ReplicaPool.measure()``), with
    occupancy-dependent slowdown once a replica's continuous-batching
    slots are oversubscribed.  Built via
    ``LatencyModel.from_measurements(...)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping

import numpy as np


@dataclass(frozen=True)
class LatencyModel:
    edge_rtt_ms: tuple = (8.0, 10.0)       # uniform, paper §V-C1
    cloud_rtt_ms: tuple = (50.0, 100.0)    # uniform, paper §V-C1
    device_rtt_ms: tuple = (0.0, 0.0)      # on-device serving: no network
    base_infer_ms: float = 2.0             # GRU forward on an edge host
    cloud_speedup: float = 0.0             # Fig. 8: 0..0.95
    device_slowdown: float = 2.0           # devices slower than edge hosts

    def rtt(self, tier: str, rng: np.random.Generator,
            size=None) -> np.ndarray:
        lo, hi = {"device": self.device_rtt_ms,
                  "edge": self.edge_rtt_ms,
                  "cloud": self.cloud_rtt_ms}[tier]
        return rng.uniform(lo, hi, size)

    def infer_ms(self, tier: str, occupancy: float = 0.0) -> float:
        """Service time of one request on ``tier``.  ``occupancy`` is the
        number of requests already in service on the chosen replica; the
        constant model ignores it (closed-form paper behaviour)."""
        if tier == "cloud":
            return self.base_infer_ms * (1.0 - self.cloud_speedup)
        if tier == "device":
            return self.base_infer_ms * self.device_slowdown
        return self.base_infer_ms

    def occupancy_dependent(self, tier: str) -> bool:
        """Whether ``infer_ms`` on ``tier`` varies with occupancy — the
        batched request engine takes its fully vectorized path only
        when it does not."""
        return False

    def flat_service_slots(self, tier: str) -> float:
        """The step boundary of the occupancy-service coupling: while a
        replica on ``tier`` has strictly fewer than this many requests
        in service, ``infer_ms`` returns the flat base — the regime the
        batched engine's closed-form bulk replay
        (:func:`repro.sim.request_plane.occupancy_replay`) exploits.
        The constant model is flat everywhere: ``math.inf``."""
        return math.inf

    def base_service_ms(self, tier: str) -> float:
        """Service time in the flat (occupancy below
        :meth:`flat_service_slots`) regime — bit-identical to
        ``infer_ms(tier, occupancy=o)`` for every such ``o``, which is
        what lets the bulk replay broadcast one scalar."""
        return self.infer_ms(tier)

    def infer_ms_array(self, tier: str, occupancy: np.ndarray,
                       ) -> np.ndarray:
        """Vectorized :meth:`infer_ms` over an occupancy array (the
        constant model broadcasts one scalar)."""
        occupancy = np.asarray(occupancy, dtype=np.float64)
        return np.full(occupancy.shape, self.infer_ms(tier))

    def forward_hop_ms(self, rng: np.random.Generator) -> float:
        """Edge->cloud forwarding hop (R3 overflow): the request pays the
        edge leg plus the cloud leg."""
        return float(self.rtt("cloud", rng))

    @classmethod
    def from_measurements(cls, measurements: Mapping[str, object],
                          decode_tokens: int = 0,
                          **kwargs) -> "CalibratedLatencyModel":
        """Build a calibrated model from per-tier engine measurements
        (``ReplicaPool.measure()`` output, or anything exposing
        ``prefill_ms`` / ``decode_ms_per_token`` / ``batch_size``).

        ``decode_tokens`` is the per-request generation length the
        simulator should assume; 0 means prefill-only service (the
        paper's GRU: one forward per request).  Extra ``kwargs`` override
        the network RTT fields.

        Measurements carrying an ``occupancy_ms`` sweep (``measure(...,
        occupancy_levels=...)``) additionally yield a *measured* service
        curve: per-request service interpolated between the swept
        concurrency levels instead of the closed-form ``(occ+1)/slots``
        stretch — real high-occupancy points from the paged engines
        rather than extrapolation past the dense slot boundary."""
        service, slots, sweep = {}, {}, {}
        for tier, m in measurements.items():
            service[tier] = float(m.prefill_ms
                                  + decode_tokens * m.decode_ms_per_token)
            slots[tier] = int(m.batch_size)
            occ = tuple(getattr(m, "occupancy_ms", ()) or ())
            if occ and decode_tokens > 0:
                pts = sorted(
                    (int(lvl), float(m.prefill_ms + decode_tokens * ms))
                    for lvl, ms in occ)
                sweep[tier] = tuple(pts)
        return CalibratedLatencyModel(tier_service_ms=service,
                                      tier_slots=slots, tier_sweep=sweep,
                                      **kwargs)


@dataclass(frozen=True)
class CalibratedLatencyModel(LatencyModel):
    """Per-tier service times measured from the serving engines.

    ``infer_ms`` becomes occupancy-dependent: a replica's continuous-
    batching slots serve concurrently at the measured rate; once
    ``occupancy`` exceeds the slot count, requests time-share the decode
    program and per-request service stretches proportionally.  Tiers
    without a measurement fall back to the constant closed-form model, so
    a partially calibrated pool still simulates."""
    tier_service_ms: Dict[str, float] = field(default_factory=dict)
    tier_slots: Dict[str, int] = field(default_factory=dict)
    # measured occupancy sweep per tier: ((concurrency, service_ms), ...)
    # ascending in concurrency; empty -> closed-form stretch
    tier_sweep: Dict[str, tuple] = field(default_factory=dict)

    def infer_ms(self, tier: str, occupancy: float = 0.0) -> float:
        if self.tier_sweep.get(tier):
            # route through the array path so scalar and vectorized
            # lookups are bit-identical (occupancy_replay contract)
            return float(self.infer_ms_array(
                tier, np.asarray(occupancy, dtype=np.float64)))
        base = self.tier_service_ms.get(tier)
        if base is None:
            return super().infer_ms(tier, occupancy)
        slots = max(self.tier_slots.get(tier, 1), 1)
        oversubscription = max((occupancy + 1.0) / slots, 1.0)
        return base * oversubscription

    def occupancy_dependent(self, tier: str) -> bool:
        return tier in self.tier_service_ms or tier in self.tier_sweep

    def flat_service_slots(self, tier: str) -> float:
        """Occupancy boundary of the flat service regime.  With a
        measured sweep: the lowest swept concurrency level (occupancies
        below it interpolate to the level's own flat value, so the
        closed-form bulk replay stays exact).  Without: the
        continuous-batching slot count where the ``(occupancy + 1) /
        slots`` stretch kicks in.  Unmeasured tiers inherit the constant
        model's ``inf``."""
        sweep = self.tier_sweep.get(tier)
        if sweep:
            return float(sweep[0][0])
        if tier not in self.tier_service_ms:
            return super().flat_service_slots(tier)
        return float(max(self.tier_slots.get(tier, 1), 1))

    def infer_ms_array(self, tier: str, occupancy: np.ndarray,
                       ) -> np.ndarray:
        occupancy = np.asarray(occupancy, dtype=np.float64)
        sweep = self.tier_sweep.get(tier)
        if sweep:
            levels = np.asarray([s[0] for s in sweep], np.float64)
            svc = np.asarray([s[1] for s in sweep], np.float64)
            c = occupancy + 1.0
            out = np.interp(c, levels, svc)   # clamps flat below levels[0]
            # beyond the highest measured level: time-share the last
            # measured rate (same shape as the closed-form stretch)
            return np.where(c > levels[-1], svc[-1] * c / levels[-1], out)
        base = self.tier_service_ms.get(tier)
        if base is None:
            return super().infer_ms_array(tier, occupancy)
        slots = max(self.tier_slots.get(tier, 1), 1)
        return base * np.maximum((occupancy + 1.0) / slots, 1.0)
