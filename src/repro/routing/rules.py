"""Inference request routing rules (paper §III):

  R1  device busy training          -> offload to its aggregator
  R2  device idle / not in round    -> serve locally (or closest aggregator)
  R3  aggregator serves its busy devices with priority; load beyond its
      capacity is forwarded to the cloud (aggregator acts as device proxy)

The router is deliberately separated from the event simulator so the same
logic drives (a) the paper-faithful discrete-event evaluation and (b) the
TPU serving driver, where "edge" = pod and "cloud" = cross-pod overflow.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class EdgeState:
    """Leaky-bucket admission state of one aggregator: r_j is a *rate*
    (requests/s, the paper's capacity semantics); the bucket smooths
    bursts over ~1 s.  Requests beyond the sustainable rate overflow to
    the cloud (rule R3)."""
    capacity_rps: float              # r_j
    tokens: float = 0.0
    last_t: float = 0.0
    burst_s: float = 1.0             # bucket depth in seconds of capacity
    in_service: int = 0              # retained for observability

    def __post_init__(self):
        if np.isfinite(self.capacity_rps):
            self.tokens = self.capacity_rps * self.burst_s

    def _refill(self, now: float) -> None:
        cap = self.capacity_rps * self.burst_s
        self.tokens = min(cap, self.tokens
                          + self.capacity_rps * max(now - self.last_t, 0.0))
        self.last_t = now

    def has_room(self, priority: bool, now: float = None) -> bool:
        if not np.isfinite(self.capacity_rps):
            return True
        if now is not None:
            self._refill(now)
        # R3: non-priority (external/idle-device) requests are admitted
        # only if load is sufficiently below capacity
        reserve = 0.0 if priority else 0.2 * self.capacity_rps * self.burst_s
        return self.tokens - 1.0 >= reserve

    def admit(self, now: float) -> None:
        self._refill(now)
        self.tokens -= 1.0
        self.in_service += 1


@dataclass
class RouteDecision:
    tier: str                        # device | edge | cloud
    edge: Optional[int] = None
    hops: int = 1                    # network legs paid
    rule: str = ""


def route_request(device: int, busy_training: bool, assign: np.ndarray,
                  edges: dict, external: bool = False,
                  now: float = None) -> RouteDecision:
    """Apply R1-R3 for one request.  ``edges`` maps edge id -> EdgeState."""
    j = int(assign[device]) if 0 <= device < len(assign) else -1
    if busy_training:                                   # R1
        if j < 0:                                       # flat FL: no edge
            return RouteDecision("cloud", None, hops=1, rule="R1-flat")
        st = edges[j]
        if st.has_room(priority=True, now=now):         # R3 priority
            return RouteDecision("edge", j, hops=1, rule="R1")
        return RouteDecision("cloud", j, hops=2, rule="R3-overflow")
    # R2: idle device serves locally; external requests go to the closest
    # aggregator (non-priority admission per R3)
    if not external:
        return RouteDecision("device", None, hops=0, rule="R2-local")
    if j >= 0 and edges[j].has_room(priority=False, now=now):
        return RouteDecision("edge", j, hops=1, rule="R2-edge")
    return RouteDecision("cloud", j if j >= 0 else None,
                         hops=2 if j >= 0 else 1, rule="R2-cloud")
