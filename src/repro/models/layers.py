"""Basic layers: norms, MLPs, embeddings, logits head."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(pb: ParamBuilder, path: str, dim: int, kind: str) -> None:
    pb.param(f"{path}/scale", (dim,), ("embed",), init="ones")
    if kind == "layernorm":
        pb.param(f"{path}/bias", (dim,), ("embed",), init="zeros")


def apply_norm(p: Dict[str, Any], x: jax.Array, kind: str,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    else:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU for act='silu', plain 2-matrix MLP for act='gelu')
# ---------------------------------------------------------------------------

def init_mlp(pb: ParamBuilder, path: str, d_model: int, d_ff: int,
             act: str, ff_axis: str = "mlp") -> None:
    if act == "silu":
        pb.param(f"{path}/wi_gate", (d_model, d_ff), ("embed", ff_axis))
        pb.param(f"{path}/wi_up", (d_model, d_ff), ("embed", ff_axis))
    else:
        pb.param(f"{path}/wi", (d_model, d_ff), ("embed", ff_axis))
    pb.param(f"{path}/wo", (d_ff, d_model), (ff_axis, "embed"))


def apply_mlp(p: Dict[str, Any], x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp_act")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embedding + logits
# ---------------------------------------------------------------------------

def init_embedding(pb: ParamBuilder, cfg: ModelConfig) -> None:
    v = cfg.padded_vocab
    pb.param("embed/table", (v, cfg.d_model), ("vocab", "embed"),
             init="normal", scale=0.02)
    if not cfg.tie_embeddings:
        pb.param("lm_head/w", (cfg.d_model, v), ("embed", "vocab"))


def embed_tokens(params: Dict[str, Any], cfg: ModelConfig,
                 tokens: jax.Array) -> jax.Array:
    x = params["embed"]["table"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return shard(x, "batch", "seq", "embed_act")


def logits_from_hidden(params: Dict[str, Any], cfg: ModelConfig,
                       x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["lm_head"]["w"]
    logits = jnp.einsum("...d,dv->...v", x, w)
    return shard(logits, "batch", "seq", "vocab_act")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab_size: int) -> jax.Array:
    """Mean next-token CE; ignores label positions >= vocab_size or < 0."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
