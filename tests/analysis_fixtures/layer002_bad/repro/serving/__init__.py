"""Fixture: serving facade that eagerly imports jax (contract breach)."""
import jax


def engine():
    return jax
