"""FedAvg and hierarchical aggregation over stacked client parameters.

Clients are stacked on a leading axis; cluster-local aggregation is a
segment-mean over that axis (the host-level mirror of the TPU psum over
the "data" mesh axis), and global aggregation averages cluster models
(mirror of the psum over the "pod" axis)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def fedavg(stacked: PyTree, weights: Optional[jax.Array] = None) -> PyTree:
    """Weighted average over the leading (client) axis."""
    if weights is None:
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    w = weights / jnp.sum(weights)

    def avg(x):
        wshape = (w.shape[0],) + (1,) * (x.ndim - 1)
        return jnp.sum(x * w.reshape(wshape).astype(x.dtype), axis=0)

    return jax.tree.map(avg, stacked)


def cluster_fedavg(stacked: PyTree, cluster_ids: np.ndarray,
                   weights: Optional[np.ndarray] = None) -> PyTree:
    """Per-cluster FedAvg (local aggregation round).

    Returns stacked params where client i's slot holds its *cluster
    model* — exactly what each aggregator redistributes to its members."""
    cluster_ids = np.asarray(cluster_ids)
    C = cluster_ids.shape[0]
    w = np.ones(C) if weights is None else np.asarray(weights, float)
    seg = jnp.asarray(cluster_ids)
    n_seg = int(cluster_ids.max()) + 1
    wj = jnp.asarray(w)
    denom = jax.ops.segment_sum(wj, seg, n_seg)

    def agg(x):
        xw = x * wj.reshape((C,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        sums = jax.ops.segment_sum(xw, seg, n_seg)
        means = sums / denom.reshape((n_seg,) + (1,) * (x.ndim - 1)
                                     ).astype(x.dtype)
        return means[seg]

    return jax.tree.map(agg, stacked)


def global_fedavg(stacked: PyTree, cluster_ids: np.ndarray,
                  weights: Optional[np.ndarray] = None) -> PyTree:
    """Global aggregation round: average the *cluster* models (one vote
    per cluster, weighted by cluster data size), then broadcast back to
    every client slot."""
    cluster_ids = np.asarray(cluster_ids)
    C = cluster_ids.shape[0]
    w = np.ones(C) if weights is None else np.asarray(weights, float)
    # cluster model = weighted mean of members; global = weighted mean of
    # cluster models by total member weight
    local = cluster_fedavg(stacked, cluster_ids, w)
    seg = jnp.asarray(cluster_ids)
    n_seg = int(cluster_ids.max()) + 1
    wj = jnp.asarray(w)
    cw = jax.ops.segment_sum(wj, seg, n_seg)          # cluster weights

    def agg(x):
        # one representative row per cluster
        first = jnp.zeros((n_seg,) + x.shape[1:], x.dtype)
        first = first.at[seg].set(x)                  # last member wins; all equal
        gw = cw / jnp.sum(cw)
        glob = jnp.sum(first * gw.reshape((n_seg,) + (1,) * (x.ndim - 1)
                                          ).astype(x.dtype), axis=0)
        return jnp.broadcast_to(glob, x.shape)

    return jax.tree.map(agg, local)
