"""HFLOP problem + solver correctness (paper §IV)."""
import numpy as np
import pytest

from repro.core import (HFLOPInstance, build_ilp, is_feasible, objective,
                        paper_cost_instance, random_instance, solve_bnb,
                        solve_bruteforce, solve_greedy, solve_heuristic,
                        solve_uncapacitated, violations)


def test_instance_shapes():
    inst = random_instance(5, 3, seed=0)
    assert inst.n == 5 and inst.m == 3
    assert inst.T == 5


def test_objective_matches_manual():
    inst = HFLOPInstance(
        c_d=np.array([[0.0, 1.0], [1.0, 0.0]]),
        c_e=np.array([2.0, 3.0]), lam=np.ones(2), r=np.full(2, 10.0), l=2)
    assign = np.array([0, 1])
    # local: (0 + 0) * l=2 ; edges 0,1 open: 2 + 3
    assert objective(inst, assign) == pytest.approx(5.0)
    assign2 = np.array([0, 0])
    assert objective(inst, assign2) == pytest.approx(1.0 * 2 + 2.0)


def test_capacity_violation_detected():
    inst = HFLOPInstance(c_d=np.zeros((3, 1)), c_e=np.ones(1),
                         lam=np.array([1.0, 1.0, 1.0]),
                         r=np.array([2.0]), l=1, T=2)
    assert violations(inst, np.array([0, 0, 0]))
    assert not violations(inst, np.array([0, 0, -1]))


@pytest.mark.parametrize("seed", range(8))
def test_bnb_matches_bruteforce(seed):
    T = None if seed % 2 == 0 else 4
    inst = random_instance(n=6, m=3, seed=seed, T=T)
    bf = solve_bruteforce(inst)
    bb = solve_bnb(inst)
    assert bb.optimal
    assert bb.cost == pytest.approx(bf.cost, abs=1e-6)
    assert is_feasible(inst, bb.assign)


@pytest.mark.parametrize("seed", range(6))
def test_heuristic_feasible_and_bounded(seed):
    inst = random_instance(n=25, m=5, seed=seed)
    h = solve_heuristic(inst)
    assert is_feasible(inst, h.assign)
    g = solve_greedy(inst)
    assert h.cost <= g.cost + 1e-9          # local search only improves


def test_tight_capacity_exact():
    for seed in range(4):
        inst = random_instance(n=7, m=3, seed=100 + seed, T=5,
                               capacity_slack=1.05)
        bf = solve_bruteforce(inst)
        bb = solve_bnb(inst)
        assert bb.cost == pytest.approx(bf.cost, abs=1e-6)


def test_uncapacitated_lower_bound():
    """Fig. 9: the uncapacitated variant is a cost lower bound."""
    for seed in range(5):
        inst = paper_cost_instance(30, 5, seed=seed, capacity_slack=1.2)
        cap = solve_heuristic(inst)
        uncap = solve_uncapacitated(inst)
        assert uncap.cost <= cap.cost + 1e-9


def test_capacity_monotonicity():
    """Raising every r_j can never increase the optimal cost."""
    inst = random_instance(n=6, m=3, seed=3, capacity_slack=1.1)
    base = solve_bnb(inst).cost
    bigger = HFLOPInstance(inst.c_d, inst.c_e, inst.lam, inst.r * 2.0,
                           l=inst.l, T=inst.T)
    assert solve_bnb(bigger).cost <= base + 1e-9


def test_cflp_reduction():
    """Any CFLP instance maps to HFLOP with T=n (paper §IV-B remark)."""
    rng = np.random.default_rng(0)
    setup = rng.uniform(1, 2, 3)           # facility open costs
    transport = rng.uniform(0, 1, (6, 3))
    demand = rng.uniform(0.1, 0.5, 6)
    cap = np.full(3, demand.sum())
    inst = HFLOPInstance(c_d=transport, c_e=setup, lam=demand, r=cap,
                         l=1, T=6)
    sol = solve_bnb(inst)
    assert sol.optimal
    assert int(np.sum(sol.assign >= 0)) == 6   # all demand covered


def test_ilp_encoding_consistency():
    inst = random_instance(6, 3, seed=1, T=4)
    ilp = build_ilp(inst)
    bf = solve_bruteforce(inst)
    v = np.zeros(ilp.c.shape[0])
    for i, j in enumerate(bf.assign):
        if j >= 0:
            v[ilp.x_index(i, j)] = 1
    for j in np.unique(bf.assign[bf.assign >= 0]):
        v[ilp.y_index(j)] = 1
    assert np.all(ilp.A @ v <= ilp.b + 1e-9)
    assert ilp.c @ v == pytest.approx(bf.cost)
