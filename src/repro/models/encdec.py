"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment carve-out:
``input_specs()`` delivers precomputed frame embeddings (B, F, d) straight
into the encoder.  Positions are sinusoidal (whisper uses sinusoidal
encoder positions; we use sinusoidal on both sides instead of a learned
decoder table so the 32k decode stress shape needs no giant position
parameter — recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import ParamBuilder, stack_axes, stack_params, to_dtype
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embedding, init_mlp, init_norm,
                                 logits_from_hidden)
from repro.models.transformer import sinusoidal_positions


def _init_enc_layer(rng, cfg):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_norm(pb, "ln1", cfg.d_model, cfg.norm)
    attn.init_gqa(pb, "attn", cfg.d_model, cfg.attention)
    init_norm(pb, "ln2", cfg.d_model, cfg.norm)
    init_mlp(pb, "mlp", cfg.d_model, cfg.d_ff, cfg.act)
    return pb.build()


def _init_dec_layer(rng, cfg):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_norm(pb, "ln1", cfg.d_model, cfg.norm)
    attn.init_gqa(pb, "self_attn", cfg.d_model, cfg.attention)
    init_norm(pb, "ln_x", cfg.d_model, cfg.norm)
    attn.init_gqa(pb, "cross_attn", cfg.d_model, cfg.attention)
    init_norm(pb, "ln2", cfg.d_model, cfg.norm)
    init_mlp(pb, "mlp", cfg.d_model, cfg.d_ff, cfg.act)
    return pb.build()


def init_params(rng, cfg: ModelConfig):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_embedding(pb, cfg)
    enc = [_init_enc_layer(jax.random.fold_in(rng, 4000 + i), cfg)
           for i in range(cfg.encoder_layers)]
    dec = [_init_dec_layer(jax.random.fold_in(rng, 5000 + i), cfg)
           for i in range(cfg.num_layers)]
    pb.subtree("encoder", stack_params([p for p, _ in enc]),
               stack_axes(enc[0][1]))
    pb.subtree("decoder", stack_params([p for p, _ in dec]),
               stack_axes(dec[0][1]))
    init_norm(pb, "enc_norm", cfg.d_model, cfg.norm)
    init_norm(pb, "final_norm", cfg.d_model, cfg.norm)
    return pb.build()


def encode(params, cfg: ModelConfig, frames: jax.Array,
           remat: str = "layer") -> jax.Array:
    """frames (B,F,d) from the stub frontend -> encoder output (B,F,d)."""
    F = frames.shape[1]
    x = frames + sinusoidal_positions(F, cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.arange(F, dtype=jnp.int32)

    def body(xc, p):
        h = apply_norm(p["ln1"], xc, cfg.norm, cfg.norm_eps)
        xc = xc + attn.gqa_forward(p["attn"], cfg.attention, h, positions,
                                   None, causal=False)
        h = apply_norm(p["ln2"], xc, cfg.norm, cfg.norm_eps)
        return xc + apply_mlp(p["mlp"], h, cfg.act), None

    body_fn = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return apply_norm(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


def _dec_layer(cfg, p, x, positions, enc_out):
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    x = x + attn.gqa_forward(p["self_attn"], cfg.attention, h, positions,
                             None, causal=True)
    h = apply_norm(p["ln_x"], x, cfg.norm, cfg.norm_eps)
    x = x + attn.gqa_forward(p["cross_attn"], cfg.attention, h, positions,
                             None, kv_source=enc_out)
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, cfg.act)


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds: Optional[jax.Array] = None,
            remat: str = "layer") -> Tuple[jax.Array, jax.Array]:
    """extra_embeds = stub frame embeddings (B,F,d) -> logits over decoder
    positions."""
    assert extra_embeds is not None, "whisper needs frame embeddings"
    enc_out = encode(params, cfg, extra_embeds, remat)
    x = embed_tokens(params, cfg, tokens)
    S = x.shape[1]
    x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(xc, p):
        return _dec_layer(cfg, p, xc, positions, enc_out), None

    body_fn = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(body_fn, x, params["decoder"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    """Self-attention caches + precomputed cross K/V slots (filled by
    ``prime_cross_cache`` from the encoder output)."""
    if dtype is None:
        from repro.models.common import to_dtype
        dtype = to_dtype(cfg.dtype)
    a = cfg.attention
    F = cfg.frontend.num_positions
    per_self = [attn.init_kv_cache(batch, max_len, a.num_kv_heads,
                                   a.head_dim, dtype)
                for _ in range(cfg.num_layers)]
    cross_k = jnp.zeros((cfg.num_layers, batch, F, a.num_kv_heads,
                         a.head_dim), dtype)
    return {
        "self": jax.tree.map(lambda *xs: jnp.stack(xs), *per_self),
        "cross_k": cross_k,
        "cross_v": jnp.zeros_like(cross_k),
    }


def prime_cross_cache(params, cfg: ModelConfig, cache, enc_out: jax.Array):
    """Precompute per-layer cross-attention K/V from encoder output."""
    ks, vs = [], []
    for i in range(cfg.num_layers):
        p = jax.tree.map(lambda t: t[i], params["decoder"])["cross_attn"]
        ks.append(jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"]))
        vs.append(jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"]))
    return {**cache, "cross_k": jnp.stack(ks).astype(cache["cross_k"].dtype),
            "cross_v": jnp.stack(vs).astype(cache["cross_v"].dtype)}


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                cache, extra_embeds=None):
    x = embed_tokens(params, cfg, tokens)
    x = x + sinusoidal_positions(1, cfg.d_model, offset=pos).astype(x.dtype)[None]
    a = cfg.attention

    def body(xc, xs):
        p, c_self, ck, cv = xs
        h = apply_norm(p["ln1"], xc, cfg.norm, cfg.norm_eps)
        y, c2 = attn.gqa_decode(p["self_attn"], a, h, pos, c_self, None)
        xc = xc + y
        h = apply_norm(p["ln_x"], xc, cfg.norm, cfg.norm_eps)
        y, _ = attn.gqa_decode(p["cross_attn"], a, h, pos, c2, None,
                               cross_kv=(ck, cv))
        xc = xc + y
        h = apply_norm(p["ln2"], xc, cfg.norm, cfg.norm_eps)
        return xc + apply_mlp(p["mlp"], h, cfg.act), c2

    x, new_self = jax.lax.scan(
        body, x, (params["decoder"], cache["self"], cache["cross_k"],
                  cache["cross_v"]))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    new_cache = {**cache, "self": new_self}
    return logits_from_hidden(params, cfg, x), new_cache
