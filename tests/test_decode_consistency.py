"""Teacher-forcing consistency: stepwise decode (with KV/ring/latent/SSM
caches) must reproduce the full forward pass logits position by position.
Run in fp32 to isolate cache logic from bf16 noise."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model

ARCHS = ["stablelm-1.6b", "h2o-danube-1.8b", "gemma3-1b",
         "deepseek-v2-lite-16b", "zamba2-1.2b", "xlstm-125m",
         "qwen2-moe-a2.7b", "internvl2-76b"]
S = 12
B = 2


def _fp32(cfg):
    model = dataclasses.replace(cfg.model, dtype="float32",
                                param_dtype="float32")
    if model.moe is not None:
        # batch vs stepwise dispatch must see identical (no-drop) capacity:
        # capacity drops are a function of the token-batch size, which is
        # the one intentional semantic difference between the two paths.
        model = dataclasses.replace(model, moe=dataclasses.replace(
            model.moe, capacity_factor=float(model.moe.num_experts)))
    return dataclasses.replace(cfg, model=model)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = _fp32(get_config(arch).reduced())
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (B, S)),
                         jnp.int32)
    batch = {"tokens": tokens}
    kw = {}
    if cfg.model.family == "vlm":
        # decode consistency for the pure-text path
        pass
    full_logits, _ = api.forward(params, batch)
    cache = api.init_cache(B, S)
    outs = []
    for t in range(S):
        logits, cache = api.decode_step(params, tokens[:, t:t + 1],
                                        jnp.int32(t), cache)
        outs.append(np.asarray(logits[:, 0], np.float32))
    step_logits = np.stack(outs, axis=1)
    # MoE dispatch differs between batch (t*k tokens) and stepwise (k
    # tokens) paths only via capacity drops; reduced configs have slack.
    np.testing.assert_allclose(step_logits,
                               np.asarray(full_logits, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_swa_ring_cache_evicts_correctly():
    """With a ring cache smaller than the sequence, decode must match a
    windowed forward (old positions masked)."""
    cfg = _fp32(get_config("h2o-danube-1.8b").reduced())
    # reduced window = 64 > S here, so shrink further
    a = dataclasses.replace(cfg.model.attention, window=4)
    cfg = dataclasses.replace(cfg, model=dataclasses.replace(
        cfg.model, attention=a))
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.model.vocab_size, (B, 10)),
                         jnp.int32)
    full_logits, _ = api.forward(params, {"tokens": tokens})
    cache = api.init_cache(B, 10)   # capacity min(10, window=4) = 4 slots
    outs = []
    for t in range(10):
        logits, cache = api.decode_step(params, tokens[:, t:t + 1],
                                        jnp.int32(t), cache)
        outs.append(np.asarray(logits[:, 0], np.float32))
    np.testing.assert_allclose(np.stack(outs, 1),
                               np.asarray(full_logits, np.float32),
                               atol=2e-3, rtol=2e-3)
