"""Contract checker tests: every rule against bad/good/suppressed
fixtures, the live tree self-check, CLI exit codes, and the
injection acceptance tests from the contract spec (CONTRACTS.md)."""
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import (AstCache, FreshRngInFaultPathRule,
                            GlobalRngRule, EventEffectsRule,
                            JaxFreeImportRule, LazyFacadeRule,
                            NonPerturbationRule, Project,
                            TelemetryBindOnceRule, WallClockRule,
                            run_analysis)

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURES = os.path.join(HERE, "analysis_fixtures")
REPO_ROOT = os.path.dirname(HERE)
SRC = os.path.join(REPO_ROOT, "src")


def file_findings(rule, case, name, module):
    """Run a per-file rule over one fixture file, with suppressions
    applied the same way the runner applies them."""
    path = os.path.join(FIXTURES, case, name + ".py")
    ctx = AstCache().get(path, f"{case}/{name}.py", module)
    out = []
    for f in rule.check_file(ctx):
        if not ctx.suppressed(f.line, f.rule):
            out.append(f)
    return out


# ---------------------------------------------------------------------------
# per-file rules: DET001 / DET002 / TEL001 / TEL002
# ---------------------------------------------------------------------------

FILE_RULE_CASES = [
    (GlobalRngRule, "det001", "repro.sim.fixture", 3),
    (FreshRngInFaultPathRule, "det003", "repro.sim.faults", 4),
    (WallClockRule, "det002", "repro.sim.fixture", 3),
    (NonPerturbationRule, "tel001", "repro.sim.fixture", 4),
    (TelemetryBindOnceRule, "tel002", "repro.sim.fixture", 2),
]


@pytest.mark.parametrize("rule_cls,case,module,min_bad", FILE_RULE_CASES)
def test_bad_fixture_flagged(rule_cls, case, module, min_bad):
    findings = file_findings(rule_cls(), case, "bad", module)
    assert len(findings) >= min_bad, [f.format() for f in findings]
    assert all(f.rule == rule_cls.id for f in findings)
    assert all(f.line > 0 for f in findings)


@pytest.mark.parametrize("rule_cls,case,module,_", FILE_RULE_CASES)
def test_good_fixture_clean(rule_cls, case, module, _):
    findings = file_findings(rule_cls(), case, "good", module)
    assert findings == [], [f.format() for f in findings]


@pytest.mark.parametrize("rule_cls,case,module,_", FILE_RULE_CASES)
def test_suppressed_fixture_clean(rule_cls, case, module, _):
    rule = rule_cls()
    # the violation is real (rule fires) ...
    path = os.path.join(FIXTURES, case, "suppressed.py")
    ctx = AstCache().get(path, "suppressed.py", module)
    raw = rule.check_file(ctx)
    assert raw, "suppressed fixture should contain a real violation"
    # ... but the inline `# contract: ok` comment absorbs it
    assert file_findings(rule, case, "suppressed", module) == []


def test_det001_out_of_scope_module_ignored():
    rule = GlobalRngRule()
    path = os.path.join(FIXTURES, "det001", "bad.py")
    ctx = AstCache().get(path, "bad.py", "not_repro.module")
    assert rule.check_file(ctx) == []


def test_det003_function_scope_only_flags_fault_helpers():
    """In request-plane/simulator modules DET003 checks only
    retry/backoff/failover/fault functions — percentile_ci's bootstrap
    default_rng stays sanctioned."""
    rule = FreshRngInFaultPathRule()
    path = os.path.join(FIXTURES, "det003", "bad.py")
    # same file, function-scoped module: backoff_delay and
    # pick_failover match the fault-path name pattern; the plain
    # `windows` helper falls out of scope
    ctx = AstCache().get(path, "bad.py", "repro.routing.simulator")
    findings = rule.check_file(ctx)
    module_findings = rule.check_file(
        AstCache().get(path, "bad.py", "repro.sim.faults"))
    assert 0 < len(findings) < len(module_findings)
    windows_lines = {f.line for f in module_findings} - \
        {f.line for f in findings}
    assert windows_lines                 # `windows` flagged only module-wide
    # out-of-scope module: nothing
    ctx = AstCache().get(path, "bad.py", "repro.benchmark.helper")
    assert rule.check_file(ctx) == []
    # live fault/retry code is clean under the rule
    for rel in ("repro/sim/faults.py", "repro/sim/request_plane.py",
                "repro/routing/simulator.py"):
        mod = rel[:-3].replace("/", ".")
        live = AstCache().get(os.path.join(SRC, rel), rel, mod)
        assert rule.check_file(live) == [], rel


def test_det002_allows_tracer_module():
    rule = WallClockRule()
    path = os.path.join(FIXTURES, "det002", "bad.py")
    ctx = AstCache().get(path, "bad.py", "repro.telemetry.tracer")
    assert rule.check_file(ctx) == []


# ---------------------------------------------------------------------------
# project rules: LAYER001 / LAYER002 / EVT001 over mini-trees
# ---------------------------------------------------------------------------

def project_findings(rule, tree):
    return rule.check_project(Project(os.path.join(FIXTURES, tree)))


def test_layer001_transitive_jax_flagged():
    findings = project_findings(JaxFreeImportRule(), "layer001_bad")
    assert findings, "protected module reaching jax must be flagged"
    assert any("repro/sim/engine.py" in f.path for f in findings)
    assert any("jax" in f.message and "->" in f.message
               for f in findings)


def test_layer001_lazy_imports_clean():
    assert project_findings(JaxFreeImportRule(), "layer001_good") == []


def test_layer002_eager_facade_flagged():
    findings = project_findings(LazyFacadeRule(), "layer002_bad")
    assert findings
    assert all(f.rule == "LAYER002" for f in findings)


def test_layer002_lazy_facade_clean():
    assert project_findings(LazyFacadeRule(), "layer002_good") == []


def test_evt001_missing_and_stale_flagged():
    findings = project_findings(EventEffectsRule(), "evt001_bad")
    msgs = [f.message for f in findings]
    assert any("TELEMETRY" in m and "no EVENT_EFFECTS" in m
               for m in msgs), msgs
    assert any("stale key" in m and "ROUND_END" in m for m in msgs), msgs


def test_evt001_complete_mapping_clean():
    assert project_findings(EventEffectsRule(), "evt001_good") == []


# ---------------------------------------------------------------------------
# live tree: the repo satisfies its own contracts
# ---------------------------------------------------------------------------

def test_live_tree_zero_findings():
    result = run_analysis(REPO_ROOT)
    assert result.ok, "\n" + result.format()
    assert result.files_checked > 50
    # the only sanctioned suppression: cosim budget-observer wiring,
    # documented in CONTRACTS.md — new suppressions must be added there
    sites = {(p, r) for p, _line, r in result.suppressions_used}
    assert sites == {("src/repro/sim/cosim.py", "TEL001")}, sites


# ---------------------------------------------------------------------------
# CLI: exit codes and JSON output
# ---------------------------------------------------------------------------

def run_cli(*argv):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT)


def test_cli_clean_tree_exit_zero():
    proc = run_cli()
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "contract check OK" in proc.stdout


def test_cli_bad_tree_exit_one(tmp_path):
    proc = run_cli("--root", os.path.join(FIXTURES, "layer001_bad"),
                   "--rules", "LAYER001",
                   "--json", str(tmp_path / "out.json"))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "LAYER001" in proc.stdout
    import json
    data = json.loads((tmp_path / "out.json").read_text())
    assert data["ok"] is False
    assert data["counts"].get("LAYER001", 0) >= 1


def test_cli_unknown_rule_exit_two():
    assert run_cli("--rules", "NOPE999").returncode == 2


def test_cli_missing_root_exit_two(tmp_path):
    assert run_cli("--root", str(tmp_path)).returncode == 2


# ---------------------------------------------------------------------------
# injection acceptance tests: mutating the real tree trips the gate
# ---------------------------------------------------------------------------

def copy_src_tree(tmp_path):
    dst = tmp_path / "src" / "repro"
    shutil.copytree(os.path.join(SRC, "repro"), dst,
                    ignore=shutil.ignore_patterns("__pycache__"))
    return tmp_path


def test_injected_global_rng_fails_gate(tmp_path):
    root = copy_src_tree(tmp_path)
    target = root / "src" / "repro" / "sim" / "request_plane.py"
    with open(target, "a") as f:
        f.write("\n\ndef _injected(n):\n"
                "    import numpy as np\n"
                "    return np.random.rand(n)\n")
    result = run_analysis(str(root))
    assert not result.ok
    assert any(f.rule == "DET001" and "request_plane" in f.path
               for f in result.findings)


def test_added_event_kind_without_effects_fails_gate(tmp_path):
    root = copy_src_tree(tmp_path)
    target = root / "src" / "repro" / "sim" / "events.py"
    source = target.read_text()
    marker = "    REQUEST_ARRIVAL = 15"
    assert marker in source
    target.write_text(source.replace(
        marker, marker + "\n    INJECTED_KIND = 16", 1))
    result = run_analysis(str(root))
    assert not result.ok
    assert any(f.rule == "EVT001" and "INJECTED_KIND" in f.message
               for f in result.findings)


def test_injected_eager_jax_import_fails_gate(tmp_path):
    root = copy_src_tree(tmp_path)
    target = root / "src" / "repro" / "routing" / "simulator.py"
    target.write_text("import jax\n" + target.read_text())
    result = run_analysis(str(root))
    assert not result.ok
    assert any(f.rule == "LAYER001" and "simulator" in f.path
               for f in result.findings)
