"""Fixture: protected sim module reaching jax transitively."""
from repro.trainer import train_step


def run(params, batch):
    return train_step(params, batch)
