"""TEL002 suppressed fixture: sanctioned per-call resolve."""
from repro.telemetry import maybe


class Router:
    def route(self, telemetry):
        return maybe(telemetry)  # contract: ok TEL002
