"""Communication-cost accounting (paper §V-D).

The paper counts traffic *over metered links only* (zero-cost links are
free), with each model exchange = upload + download of the serialized
model (594 KB for the use-case GRU), l local aggregation rounds per
global round, and convergence after ``total_rounds`` aggregation rounds.

Reference numbers reproduced by the tests / Fig. 9 benchmark
(4 edges, 20 devices, 100 rounds):  flat FL 2.37 GB, HFLOP 0.53 GB,
uncapacitated 0.24 GB.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hflop import HFLOPInstance

# paper: "594 KB in serialized format".  594e3 (not 594*1024) reproduces
# the paper's absolute volumes exactly: 100 rounds x 20 devices x 2 dirs
# x 594 KB = 2.376 GB ("approximately 2.37 GB" for flat FL in §V-D) and
# 50 global rounds x 4 edges x 2 x 594 KB = 0.2376 GB (uncapacitated).
GRU_MODEL_BYTES = 594_000


@dataclass(frozen=True)
class CostReport:
    metered_bytes: float              # traffic over metered links
    local_bytes: float                # device<->aggregator share
    global_bytes: float               # aggregator<->cloud share
    n_global_rounds: int
    n_local_rounds: int

    @property
    def gigabytes(self) -> float:
        return self.metered_bytes / 1e9


def flat_fl_cost(n_devices: int, total_rounds: int,
                 model_bytes: int = GRU_MODEL_BYTES,
                 device_cloud_cost: np.ndarray | float = 1.0) -> CostReport:
    """Centralized FL: every aggregation round, every device exchanges the
    model with the cloud (metered unless its cost is 0)."""
    costs = np.broadcast_to(np.asarray(device_cloud_cost, float),
                            (n_devices,))
    metered = int(np.sum(costs > 0))
    total = total_rounds * metered * 2 * model_bytes
    return CostReport(metered_bytes=total, local_bytes=0.0,
                      global_bytes=total, n_global_rounds=total_rounds,
                      n_local_rounds=0)


def hfl_cost(inst: HFLOPInstance, assign: np.ndarray, total_rounds: int,
             model_bytes: int = GRU_MODEL_BYTES) -> CostReport:
    """Hierarchical FL under an HFLOP assignment.

    ``total_rounds`` counts *local* aggregation rounds (as in Fig. 6);
    a global round happens every ``inst.l`` local rounds.  Traffic over
    zero-cost device-edge links is free; edge-cloud links are metered
    when c_e > 0."""
    assign = np.asarray(assign)
    ok = assign >= 0
    n_global = total_rounds // inst.l
    metered_dev = int(np.sum(inst.c_d[np.arange(inst.n)[ok], assign[ok]] > 0))
    local = total_rounds * metered_dev * 2 * model_bytes
    open_edges = np.unique(assign[ok])
    metered_edges = int(np.sum(inst.c_e[open_edges] > 0))
    glob = n_global * metered_edges * 2 * model_bytes
    return CostReport(metered_bytes=local + glob, local_bytes=local,
                      global_bytes=glob, n_global_rounds=n_global,
                      n_local_rounds=total_rounds)


def savings_vs_flat(inst: HFLOPInstance, assign: np.ndarray,
                    total_rounds: int,
                    model_bytes: int = GRU_MODEL_BYTES) -> float:
    """Fig. 9 metric: % communication-cost reduction vs standard FL."""
    flat = flat_fl_cost(inst.n, total_rounds, model_bytes)
    hier = hfl_cost(inst, assign, total_rounds, model_bytes)
    return 100.0 * (1.0 - hier.metered_bytes / flat.metered_bytes)
