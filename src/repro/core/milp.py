"""A small dense MILP solver: two-phase primal simplex + best-first
branch & bound over binary variables.

Built because the container is offline (the paper uses CPLEX; we need an
exact reference solver for HFLOP).  Designed for correctness on the
instance sizes the tests and Fig.-2-style scaling sweeps use, not for
industrial scale — large instances are handled by the heuristics in
``repro.core.solvers``.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.telemetry.tracer import wall_clock

_EPS = 1e-9


class LPResult:
    __slots__ = ("status", "x", "obj")

    def __init__(self, status: str, x: Optional[np.ndarray], obj: float):
        self.status = status  # optimal | infeasible | unbounded
        self.x = x
        self.obj = obj


def _simplex(T: np.ndarray, basis: np.ndarray, n_total: int,
             max_iter: int = 20000) -> str:
    """In-place tableau simplex.  T is (m+1, n_total+1) with objective row
    last; basis (m,) column indices.  Returns status."""
    m = T.shape[0] - 1
    for it in range(max_iter):
        # entering: Dantzig, Bland fallback near stall
        red = T[-1, :n_total]
        if it < max_iter // 2:
            e = int(np.argmin(red))
            if red[e] >= -_EPS:
                return "optimal"
        else:  # Bland
            neg = np.nonzero(red < -_EPS)[0]
            if neg.size == 0:
                return "optimal"
            e = int(neg[0])
        col = T[:m, e]
        pos = col > _EPS
        if not np.any(pos):
            return "unbounded"
        ratios = np.full(m, np.inf)
        ratios[pos] = T[:m, -1][pos] / col[pos]
        r = int(np.argmin(ratios))
        # ties: Bland on basis index to avoid cycling
        tie = np.nonzero(np.abs(ratios - ratios[r]) < _EPS)[0]
        if tie.size > 1:
            r = int(tie[np.argmin(basis[tie])])
        piv = T[r, e]
        T[r] /= piv
        for k in range(m + 1):
            if k != r and abs(T[k, e]) > _EPS:
                T[k] -= T[k, e] * T[r]
        basis[r] = e
    return "iteration_limit"


def solve_lp(c: np.ndarray, A: np.ndarray, b: np.ndarray,
             ub: Optional[np.ndarray] = None) -> LPResult:
    """min c.x  s.t.  A x <= b,  0 <= x (<= ub per-var if given)."""
    c = np.asarray(c, float)
    A = np.asarray(A, float)
    b = np.asarray(b, float).copy()
    nv = c.shape[0]
    if ub is not None:
        fin = np.isfinite(ub)
        if np.any(fin):
            rows = np.zeros((int(fin.sum()), nv))
            rows[np.arange(int(fin.sum())), np.nonzero(fin)[0]] = 1.0
            A = np.vstack([A, rows])
            b = np.concatenate([b, ub[fin]])
    mrows = A.shape[0]
    # rows with negative rhs: flip sign so b >= 0, slack coeff -1, add artificial
    flip = b < 0
    A = A.copy()
    A[flip] *= -1.0
    b[flip] *= -1.0
    slack_sign = np.where(flip, -1.0, 1.0)
    n_art = int(flip.sum())
    n_total = nv + mrows + n_art
    T = np.zeros((mrows + 1, n_total + 1))
    T[:mrows, :nv] = A
    T[:mrows, nv:nv + mrows] = np.diag(slack_sign)
    art_cols = []
    k = 0
    basis = np.zeros(mrows, dtype=int)
    for i in range(mrows):
        if flip[i]:
            col = nv + mrows + k
            T[i, col] = 1.0
            basis[i] = col
            art_cols.append(col)
            k += 1
        else:
            basis[i] = nv + i
    T[:mrows, -1] = b
    if n_art:
        # phase 1: min sum of artificials
        T[-1, art_cols] = 1.0
        for i in range(mrows):
            if flip[i]:
                T[-1] -= T[i]
        st = _simplex(T, basis, n_total)
        if st != "optimal" or T[-1, -1] < -1e-7:
            return LPResult("infeasible", None, np.inf)
        art_set = set(art_cols)
        # drive remaining (degenerate, zero-level) artificials out of the
        # basis; rows where no real column is available are redundant.
        for i in range(mrows):
            if basis[i] in art_set:
                if T[i, -1] > 1e-7:
                    return LPResult("infeasible", None, np.inf)
                row = T[i, :nv + mrows]
                cand = np.nonzero(np.abs(row) > 1e-7)[0]
                if cand.size:
                    e = int(cand[0])
                    T[i] /= T[i, e]
                    for k2 in range(mrows + 1):
                        if k2 != i and abs(T[k2, e]) > _EPS:
                            T[k2] -= T[k2, e] * T[i]
                    basis[i] = e
                else:
                    T[i, :] = 0.0          # redundant row
        # phase 2 objective
        T[-1, :] = 0.0
        T[-1, :nv] = c
        for i in range(mrows):
            if basis[i] < nv:
                T[-1] -= c[basis[i]] * T[i]
        # forbid artificial columns (all non-basic now)
        for col in art_cols:
            T[:mrows, col] = 0.0
            T[-1, col] = 1e30
    else:
        T[-1, :nv] = c
    st = _simplex(T, basis, n_total)
    if st == "unbounded":
        return LPResult("unbounded", None, -np.inf)
    if st != "optimal":
        return LPResult("infeasible", None, np.inf)
    x = np.zeros(n_total)
    x[basis] = T[:len(basis), -1]
    xv = x[:nv]
    return LPResult("optimal", xv, float(c @ xv))


@dataclass(order=True)
class _Node:
    bound: float
    seq: int
    fixed: Dict[int, float] = field(compare=False)


@dataclass
class MILPResult:
    status: str
    x: Optional[np.ndarray]
    obj: float
    nodes: int
    wall_time_s: float


def solve_milp(c: np.ndarray, A: np.ndarray, b: np.ndarray,
               incumbent_x: Optional[np.ndarray] = None,
               branch_priority: Optional[np.ndarray] = None,
               rounding: Optional[Callable[[np.ndarray],
                                           Optional[np.ndarray]]] = None,
               max_nodes: int = 200_000,
               time_limit_s: float = 600.0) -> MILPResult:
    """Best-first B&B for min c.x, A x <= b, x in {0,1}^n.

    ``rounding(x_frac)`` may return a feasible integer vector used to
    tighten the incumbent.  ``branch_priority`` raises branching priority
    for the flagged variables (HFLOP: branch y_j before x_ij)."""
    t0 = wall_clock()
    nv = c.shape[0]
    ub = np.ones(nv)
    best_x, best_obj = None, np.inf
    if incumbent_x is not None:
        v = np.asarray(incumbent_x, float)
        if np.all(A @ v <= b + 1e-7):
            best_x, best_obj = v, float(c @ v)

    def lp_with_fixed(fixed: Dict[int, float]) -> LPResult:
        if not fixed:
            return solve_lp(c, A, b, ub)
        idx = np.asarray(sorted(fixed), int)
        vals = np.asarray([fixed[i] for i in sorted(fixed)])
        free = np.setdiff1d(np.arange(nv), idx)
        res = solve_lp(c[free], A[:, free], b - A[:, idx] @ vals,
                       ub[free])
        if res.x is None:
            return res
        full = np.zeros(nv)
        full[free] = res.x
        full[idx] = vals
        return LPResult(res.status, full, float(c @ full))

    seq = 0
    root = lp_with_fixed({})
    if root.status != "optimal":
        return MILPResult(root.status, best_x, best_obj, 1,
                          wall_clock() - t0)
    heap: List[_Node] = [_Node(root.obj, seq, {})]
    nodes = 0
    while heap:
        node = heapq.heappop(heap)
        if node.bound >= best_obj - 1e-9:
            continue
        nodes += 1
        if nodes > max_nodes or wall_clock() - t0 > time_limit_s:
            return MILPResult("limit", best_x, best_obj, nodes,
                              wall_clock() - t0)
        res = lp_with_fixed(node.fixed)
        if res.status != "optimal" or res.obj >= best_obj - 1e-9:
            continue
        x = res.x
        frac = np.abs(x - np.round(x))
        frac[list(node.fixed)] = 0.0
        if np.all(frac < 1e-6):
            xi = np.round(x)
            obj = float(c @ xi)
            if np.all(A @ xi <= b + 1e-7) and obj < best_obj:
                best_x, best_obj = xi, obj
            continue
        if rounding is not None:
            cand = rounding(x)
            if cand is not None:
                cobj = float(c @ cand)
                if cobj < best_obj and np.all(A @ cand <= b + 1e-7):
                    best_x, best_obj = cand, cobj
        score = frac.copy()
        if branch_priority is not None:
            score = score * (1.0 + 10.0 * branch_priority)
        k = int(np.argmax(score))
        for val in (1.0, 0.0):
            child = dict(node.fixed)
            child[k] = val
            r = lp_with_fixed(child)
            if r.status == "optimal" and r.obj < best_obj - 1e-9:
                seq += 1
                heapq.heappush(heap, _Node(r.obj, seq, child))
    status = "optimal" if best_x is not None else "infeasible"
    return MILPResult(status, best_x, best_obj, nodes,
                      wall_clock() - t0)
