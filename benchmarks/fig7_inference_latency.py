"""Paper Fig. 7: inference response times under continual training for
(a) flat/centralized FL, (b) location-based hierarchical clustering,
(c) HFLOP (inference-load-aware) clustering.

Scenario: 20 devices in 4 geographic clusters, but request load is
*skewed by location* (one hot zone) — exactly the case where
location-only clustering overloads one edge and spills to the cloud
while HFLOP balances by capacity.  Paper reference values:
flat 79.07+-15.94 ms, hier 17.72+-24.26 ms, HFLOP 9.89+-4.63 ms.

``--rate-scale`` sweeps the saturation regime the batched request
plane makes feasible (1000 -> ~10^7 requests in seconds) and
``--calibrated`` swaps in the occupancy-coupled service model; every
row reports a bootstrap 95% CI on p95, computed order-statistic-style
off the exact columnar log (``RequestLog.percentile_ci``)."""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import HFLOPInstance, solve_heuristic
from repro.routing import CalibratedLatencyModel, LatencyModel, \
    SimConfig, compare_methods
from benchmarks.common import emit


def build_scenario(seed=0, n=20, m=4, hot_factor=3.0, cap_slack=1.35):
    # one definition of the hot-zone continuum, shared with the
    # scenario engine (identical draws)
    from repro.sim.scenarios import hot_zone_topology
    _, loc, lam, r = hot_zone_topology(seed=seed, n=n, m=m,
                                       hot=hot_factor, slack=cap_slack)
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    inst = HFLOPInstance(c_d, np.ones(m), lam, r, l=2)
    return inst, loc


def run(duration_s=240.0, seed=0, rate_scale=1.0, calibrated=False,
        service_ms=40.0, slots=2):
    inst, loc = build_scenario(seed)
    hflop = solve_heuristic(inst)
    lat = (CalibratedLatencyModel(tier_service_ms={"edge": service_ms},
                                  tier_slots={"edge": slots})
           if calibrated else LatencyModel())
    cfg = SimConfig(duration_s=duration_s, seed=seed,
                    rate_scale=rate_scale, latency=lat)
    logs = compare_methods(inst, {"flat": None, "hier_location": loc,
                                  "hflop": hflop.assign}, cfg)
    out = {}
    tag = "_calibrated" if calibrated else ""
    for name, log in logs.items():
        mean, std = log.mean_latency(), log.std_latency()
        cloud = log.tier_fractions()["cloud"]
        pct = log.latency_percentiles()
        ci_lo, ci_hi = log.percentile_ci(95)
        emit(f"fig7_{name}{tag}", mean * 1000,
             f"mean_ms={mean:.2f};std_ms={std:.2f};cloud_frac={cloud:.3f};"
             f"p50={pct['p50']:.2f};p95={pct['p95']:.2f};"
             f"p99={pct['p99']:.2f};p95_ci_lo={ci_lo:.2f};"
             f"p95_ci_hi={ci_hi:.2f};n={log.t.size};"
             f"rate_scale={rate_scale:g}")
        out[name] = (mean, std, cloud)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=240.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="lambda multiplier (1000 -> ~10^7 requests)")
    ap.add_argument("--calibrated", action="store_true",
                    help="occupancy-coupled (calibrated) edge service "
                         "instead of the constant closed-form model")
    ap.add_argument("--service-ms", type=float, default=40.0)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    r = run(duration_s=args.duration, seed=args.seed,
            rate_scale=args.rate_scale, calibrated=args.calibrated,
            service_ms=args.service_ms, slots=args.slots)
    print("\npaper reference: flat 79.07+-15.94 | hier 17.72+-24.26 | "
          "hflop 9.89+-4.63 (ms)")
