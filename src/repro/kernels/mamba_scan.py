"""Mamba2 SSD chunk Pallas kernel (zamba2's compute hot spot).

One grid step processes one (batch, head-block) pair and loops over the
sequence chunks *sequentially inside the kernel*, carrying the (N x P)
SSD state in VMEM — the TPU-native shape of the recurrence: intra-chunk
work is two MXU matmuls (C.B^T decay-masked, then score @ u), the
inter-chunk state update is a rank-N outer-product accumulation.

Layout: heads are tiled by ``bh``; B/C are per-group (ngroups=1 for the
assigned configs) and broadcast across the head tile."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_final_ref,
                s_ref, *, nchunks: int, Q: int, bh: int, N: int, P: int):
    s_ref[...] = jnp.zeros_like(s_ref)

    def chunk(ci, _):
        x = x_ref[0, ci].astype(jnp.float32)          # (Q, bh, P)
        dt = dt_ref[0, ci].astype(jnp.float32)        # (Q, bh)
        A = a_ref[...].astype(jnp.float32)            # (bh,)
        Bm = b_ref[0, ci].astype(jnp.float32)         # (Q, N)
        Cm = c_ref[0, ci].astype(jnp.float32)         # (Q, N)

        la = dt * A[None, :]                          # (Q, bh) log decay
        cum = jnp.cumsum(la, axis=0)
        u = x * dt[..., None]                         # (Q, bh, P)

        # intra-chunk: scores (Q,Q) per head tile, decay-masked
        diff = cum[:, None, :] - cum[None, :, :]      # (Qi, Qj, bh)
        ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
        tri = (ii >= jj)[..., None]
        decay = jnp.where(tri, jnp.exp(diff), 0.0)    # (Q,Q,bh)
        cb = jnp.dot(Cm, Bm.T,
                     preferred_element_type=jnp.float32)  # (Qi,Qj)
        scores = cb[..., None] * decay                # (Q,Q,bh)
        y_intra = jnp.einsum("ijh,jhp->ihp", scores, u)

        # inter-chunk: contribution of the carried state
        w_in = jnp.exp(cum)                           # (Q,bh)
        s_prev = s_ref[...]                           # (bh,N,P)
        y_inter = jnp.einsum("qn,hnp,qh->qhp", Cm, s_prev, w_in)

        y_ref[0, ci] = (y_intra + y_inter).astype(y_ref.dtype)

        # state update: S = a_chunk * S_prev + sum_j wlast_j B_j (x) u_j
        wlast = jnp.exp(cum[-1:, :] - cum)            # (Q,bh)
        s_loc = jnp.einsum("qn,qhp,qh->hnp", Bm, u, wlast)
        a_chunk = jnp.exp(cum[-1, :])                 # (bh,)
        s_ref[...] = a_chunk[:, None, None] * s_prev + s_loc
        return 0

    jax.lax.fori_loop(0, nchunks, chunk, 0)
    s_final_ref[0] = s_ref[...].astype(s_final_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bh", "interpret"))
def mamba_chunk_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
                     Bm: jax.Array, Cm: jax.Array, *, chunk: int = 64,
                     bh: int = 0, interpret: bool = True):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative;
    Bm/Cm (B,L,N) (ngroups=1).  Returns (y (B,L,H,P), state (B,H,N,P))."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0
    nchunks = L // chunk
    bh = bh or H
    assert H % bh == 0
    xr = x.reshape(B, nchunks, chunk, H, P)
    dtr = dt.reshape(B, nchunks, chunk, H)
    Br = Bm.reshape(B, nchunks, chunk, N)
    Cr = Cm.reshape(B, nchunks, chunk, N)
    kernel = functools.partial(_ssd_kernel, nchunks=nchunks, Q=chunk,
                               bh=bh, N=N, P=P)
    y, s = pl.pallas_call(
        kernel,
        grid=(B, H // bh),
        in_specs=[
            pl.BlockSpec((1, nchunks, chunk, bh, P),
                         lambda b, h: (b, 0, 0, h, 0)),
            pl.BlockSpec((1, nchunks, chunk, bh), lambda b, h: (b, 0, 0, h)),
            pl.BlockSpec((bh,), lambda b, h: (h,)),
            pl.BlockSpec((1, nchunks, chunk, N), lambda b, h: (b, 0, 0, 0)),
            pl.BlockSpec((1, nchunks, chunk, N), lambda b, h: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nchunks, chunk, bh, P),
                         lambda b, h: (b, 0, 0, h, 0)),
            pl.BlockSpec((1, bh, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nchunks, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, N, P), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A, Br, Cr)
    return y.reshape(B, L, H, P), s
