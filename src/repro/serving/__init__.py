from repro.serving.engine import ServeEngine
from repro.serving.workload import (RequestEvent, batched_arrivals,
                                    poisson_requests)

__all__ = ["ServeEngine", "RequestEvent", "batched_arrivals",
           "poisson_requests"]
