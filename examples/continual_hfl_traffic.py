"""End-to-end driver (paper use case, Fig. 6 scale): continual
hierarchical FL of the traffic GRU over 20 clients / 4 edge aggregators,
with HFLOP clustering, periodic global rounds, inference serving in the
loop, and accuracy-triggered re-training via the inference controller.

  PYTHONPATH=src python examples/continual_hfl_traffic.py --rounds 20
  (--rounds 100 reproduces the paper's full Fig. 6 horizon)
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core import HFLOPInstance, solve_heuristic
from repro.core.topology import ClusterTopology
from repro.data.traffic import generate, select_fl_sensors
from repro.fl.hierarchy import ContinualHFL, HFLRunConfig
from repro.routing import SimConfig, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--max-batches", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    need_days = 22 + 7 + (args.rounds * 36) // 288 + 2
    ds = generate(num_days=need_days, seed=args.seed)
    sensors = select_fl_sensors(ds, per_cluster=5, seed=args.seed)
    n, m = len(sensors), 4
    rng = np.random.default_rng(args.seed)
    lam = rng.uniform(2.0, 6.0, n)
    loc = ds.cluster_of[sensors]
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    inst = HFLOPInstance(c_d, np.ones(m), lam,
                         np.full(m, lam.sum() / m * 1.3), l=2)
    sol = solve_heuristic(inst)
    topo = ClusterTopology.from_solution(inst, sol)
    print(topo.describe())

    cfg = get_config("gru-traffic")
    run = HFLRunConfig(rounds=args.rounds, max_batches=args.max_batches,
                       seed=args.seed)
    hfl = ContinualHFL(cfg, ds, sensors, topo, run, mode="hier")

    alarm_threshold = 0.30
    for t in range(args.rounds):
        res = hfl.run_rounds(rounds=1)
        mse = float(res.mse.mean())
        kind = "GLOBAL" if (t + 1) % topo.l == 0 else "local"
        line = f"round {t:3d} [{kind:6s}] val MSE {mse:.5f}"
        # inference controller: serve this round's requests, watch accuracy
        log = simulate(topo, SimConfig(duration_s=10, seed=t))
        line += (f" | served {len(log.t):4d} reqs, "
                 f"p50 {np.percentile(log.latency_ms, 50):.1f} ms")
        if mse > alarm_threshold and t > 5:
            line += "  << accuracy alarm: would trigger new HFL task"
        print(line)


if __name__ == "__main__":
    main()
