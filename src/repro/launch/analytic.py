"""Analytic (napkin-math) roofline model per (arch x shape x mesh).

Why this exists: XLA-CPU's ``cost_analysis()`` counts each ``while`` body
ONCE, so scanned programs (layer scan, microbatch scan, SSD chunk scan)
under-report FLOPs/bytes by the trip count (verified: llama3-405b train
HLO flops ~= analytic/2016 = microbatches x layers).  The dry-run
therefore records BOTH the raw HLO numbers (exact per-iteration costs,
collective schedule, memory image) and this analytic model (correct trip
counts).  §Roofline uses the analytic terms for dominant-bottleneck
calls; §Perf hypotheses are sized here and validated against the HLO
artifacts where the change is per-iteration visible.

All quantities are per-device per-step; terms in seconds on TPU v5e."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ArchConfig, InputShape
from repro.launch.mesh import (DCI_BW, HBM_BW, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16)


def _axis(mesh_shape: Dict[str, int], name: str) -> int:
    return mesh_shape.get(name, 1)


@dataclass
class AnalyticRoofline:
    flops: float
    hbm_bytes: float
    ici_bytes: float                 # intra-pod collective bytes
    dci_bytes: float                 # cross-pod collective bytes

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.ici_bytes / ICI_BW_PER_LINK + self.dci_bytes / DCI_BW

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    def mfu(self, model_flops_per_dev: float) -> float:
        t = max(self.compute_s, self.memory_s, self.collective_s)
        return model_flops_per_dev / PEAK_FLOPS_BF16 / t if t else 0.0

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "ici_bytes": self.ici_bytes, "dci_bytes": self.dci_bytes,
                "compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant}


def _attn_flops_per_token(cfg: ArchConfig, s_context: float) -> float:
    """2 * (QK + AV) flops per token per layer-average."""
    m = cfg.model
    a = m.attention
    if a.kind == "none":
        return 0.0
    # average context per query (causal ~ S/2, windowed ~ min(W, S/2))
    total = 0.0
    L = m.num_layers
    from repro.models.transformer import FULL_WINDOW, layer_window
    if m.family == "hybrid":
        n_attn = max(1, m.num_layers // max(m.shared_attn_every, 1))
        w = a.window or FULL_WINDOW
        ctx = min(w, s_context / 2)
        qk = a.num_heads * a.head_dim
        return n_attn / L * 4.0 * ctx * qk * 2  # QK^T + AV
    for i in range(L):
        w = layer_window(cfg.model, i)
        ctx = min(w, s_context / 2) if w != FULL_WINDOW else s_context / 2
        if a.kind == "mla" and a.mla:
            qk = a.num_heads * (a.mla.qk_nope_head_dim + a.mla.qk_rope_head_dim)
            av = a.num_heads * a.mla.v_head_dim
        else:
            qk = a.num_heads * a.head_dim
            av = qk
        total += 2.0 * ctx * (qk + av) * 2
    return total / L


def _cache_bytes_per_seq(cfg: ArchConfig, S: int) -> float:
    """KV/state cache bytes per sequence (decode reads all of it)."""
    m = cfg.model
    a = m.attention
    from repro.models.transformer import FULL_WINDOW, layer_window
    if m.family == "ssm" and m.xlstm:       # matrix memories
        dc = int(m.d_model * m.xlstm.proj_factor_mlstm)
        hd = dc // m.xlstm.num_heads
        per_mlstm = m.xlstm.num_heads * hd * hd * 4
        return m.num_layers * per_mlstm
    if m.family == "hybrid" and m.ssm:
        d_in = m.d_model * m.ssm.expand
        H = d_in // m.ssm.head_dim
        per = H * m.ssm.state_dim * m.ssm.head_dim * 4
        n_attn = max(1, m.num_layers // max(m.shared_attn_every, 1))
        w = min(a.window or S, S)
        attn_cache = n_attn * w * a.num_kv_heads * a.head_dim * 2 * 2
        return m.num_layers * per + attn_cache
    total = 0.0
    for i in range(m.num_layers):
        w = layer_window(cfg.model, i)
        c = min(w, S) if w != FULL_WINDOW else S
        if a.kind == "mla" and a.mla:
            total += c * (a.mla.kv_lora_rank + a.mla.qk_rope_head_dim) * 2
        else:
            total += c * a.num_kv_heads * a.head_dim * 2 * 2
    return total


def activation_peak_bytes(cfg: ArchConfig, shape: InputShape, mesh) -> float:
    """Per-device activation high-water mark (remat stashes + logits +
    attention transient) — complements XLA's argument accounting, whose
    CPU-backend peak metric mirrors argument size."""
    m = cfg.model
    ms = dict(mesh.shape)
    chips = mesh.devices.size
    dp = _axis(ms, "pod") * _axis(ms, "data") * _axis(ms, "cluster")
    tp = _axis(ms, "model")
    B, S = shape.global_batch, shape.seq_len
    d_bytes = 2
    vocab = m.padded_vocab if m.vocab_size else 1
    if shape.mode == "train":
        k = max(cfg.run.microbatches, 1)
        tok_dev = B * S / dp / k
        stash = tok_dev * m.d_model * d_bytes * max(m.num_layers, 1) / tp
        logits = tok_dev * vocab / tp * 4 * 2     # fwd fp32 + grad
        a = m.attention
        heads_dev = max(1, a.num_heads // tp)
        chunk = min(S, 2048)
        attn_t = heads_dev * chunk * min(S, 1 << 30) * 4 * (B / dp / k)
        return stash + logits + attn_t
    if shape.mode == "prefill":
        tok_dev = B * S / dp
        act = tok_dev * m.d_model * d_bytes * 4 / tp
        logits = tok_dev * vocab / tp * 2
        return act + logits
    bdev = max(1.0, B / dp)
    return bdev * vocab * 4 + bdev * m.d_model * 4 * 8


def analytic_roofline(cfg: ArchConfig, shape: InputShape, mesh,
                      hfl_mode: bool = False,
                      global_sync_this_step: bool = False
                      ) -> AnalyticRoofline:
    m = cfg.model
    ms = dict(mesh.shape)
    chips = mesh.devices.size
    dp = _axis(ms, "pod") * _axis(ms, "data") * _axis(ms, "cluster")
    tp = _axis(ms, "model")
    B, S = shape.global_batch, shape.seq_len
    n_active = m.active_param_count()
    p_bytes_total = m.param_count() * 2          # bf16
    p_dev = p_bytes_total / chips
    d_bytes = 2

    if shape.mode == "train":
        tokens = B * S
        tok_dev = tokens / dp
        remat_f = 4.0 / 3.0 if cfg.run.remat != "none" else 1.0
        flops = (6.0 * n_active + 3.0 * _attn_flops_per_token(cfg, S)
                 ) * tokens * remat_f / chips
        k = cfg.run.microbatches
        # HBM: weights touched fwd+bwd+remat per microbatch (gathered copies
        # are written+read), grads, optimizer read+write
        opt_itemsize = 4 if cfg.run.opt_state_dtype == "float32" else 2
        opt_dev = m.param_count() * 2 * opt_itemsize / chips
        hbm = (p_dev * 3 * k                      # weight reads x microbatch
               + p_dev * 2                        # grad write+read
               + opt_dev * 2                      # moments r/w
               + tok_dev * m.d_model * d_bytes * m.num_layers / tp * 8)
        # collectives:
        #  - FSDP all-gather of params over 'data' (+pod if not HFL) per
        #    microbatch x (fwd + bwd-with-remat ~ 2)
        #  - gradient reduce-scatter over the same axes
        #  - 2 TP all-reduces per layer per microbatch of activations
        ag = p_dev * 2 * k
        gs = p_dev
        tp_ar = (2 * m.num_layers * tok_dev * m.d_model * d_bytes / tp * k
                 ) if tp > 1 else 0.0
        ici = ag + gs + tp_ar
        dci = 0.0
        if "pod" in ms and ms["pod"] > 1 and not hfl_mode:
            # flat data-parallel spans pods: grad sync crosses DCI
            dci = gs
        if hfl_mode and global_sync_this_step:
            dci = p_dev                           # param mean across pods
        return AnalyticRoofline(flops, hbm, ici, dci)

    if shape.mode == "prefill":
        tokens = B * S
        flops = (2.0 * n_active + _attn_flops_per_token(cfg, S)
                 ) * tokens / chips
        tok_dev = tokens / dp
        hbm = p_dev + tok_dev * m.d_model * d_bytes * m.num_layers / tp * 4
        tp_ar = (2 * m.num_layers * tok_dev * m.d_model * d_bytes / tp
                 ) if tp > 1 else 0.0
        ici = p_dev + tp_ar                       # weight all-gather + TP
        return AnalyticRoofline(flops, hbm, ici, 0.0)

    # decode: one token per sequence, read the whole cache
    flops = (2.0 * n_active * B
             + 2.0 * _cache_bytes_per_seq(cfg, S) / 2 * B) / chips
    cache_dev = _cache_bytes_per_seq(cfg, S) * B / chips
    bdev = max(1.0, B / dp)
    hbm = p_dev + cache_dev + cache_dev           # read + rewrite cache
    tp_ar = (2 * m.num_layers * bdev * m.d_model * d_bytes
             ) if tp > 1 else 0.0
    ici = tp_ar + p_dev * 0.0                     # weights resident for decode
    return AnalyticRoofline(flops, hbm, ici, 0.0)
