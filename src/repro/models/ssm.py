"""Mamba2 (State Space Duality) block: chunked parallel training scan and
O(1)-state decode.  Used by zamba2 (hybrid) and available standalone.

Recurrence per head (state N x P):
    S_t = a_t * S_{t-1} + B_t (x) u_t        a_t = exp(dt_t * A),  u_t = dt_t * x_t
    y_t = C_t . S_t + D * x_t

Training uses the chunked SSD algorithm: intra-chunk attention-like matmuls
plus an inter-chunk state recurrence (lax.scan over chunks).  The Pallas
kernel in ``repro.kernels.mamba_scan`` implements the same math with VMEM
tiling; this module is the XLA path and the kernels' oracle source.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import ParamBuilder, shard


class SSMState(NamedTuple):
    """Decode-time state: conv ring buffer + SSD state."""
    conv: jax.Array   # (B, W-1, conv_ch)
    s: jax.Array      # (B, H, N, P)


def mamba_dims(d_model: int, s: SSMConfig) -> Dict[str, int]:
    d_in = d_model * s.expand
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.ngroups * s.state_dim
    return dict(d_in=d_in, H=H, P=s.head_dim, N=s.state_dim,
                G=s.ngroups, conv_ch=conv_ch)


def init_mamba2(pb: ParamBuilder, path: str, d_model: int,
                s: SSMConfig) -> None:
    dd = mamba_dims(d_model, s)
    d_in, H, N, G, conv_ch = dd["d_in"], dd["H"], dd["N"], dd["G"], dd["conv_ch"]
    # fused input projection: [z, x, B, C, dt]
    pb.param(f"{path}/in_proj", (d_model, 2 * d_in + 2 * G * N + H),
             ("embed", "mlp"))
    pb.param(f"{path}/conv_w", (s.conv_width, conv_ch), (None, "mlp"))
    pb.param(f"{path}/conv_b", (conv_ch,), ("mlp",), init="zeros")
    pb.param(f"{path}/A_log", (H,), ("heads",), init="zeros", dtype=jnp.float32)
    pb.param(f"{path}/D", (H,), ("heads",), init="ones", dtype=jnp.float32)
    pb.param(f"{path}/dt_bias", (H,), ("heads",), init="zeros",
             dtype=jnp.float32)
    pb.param(f"{path}/norm_scale", (d_in,), ("mlp",), init="ones")
    pb.param(f"{path}/out_proj", (d_in, d_model), ("mlp", "embed"))


def _split_proj(p, x, d_model, s: SSMConfig):
    dd = mamba_dims(d_model, s)
    d_in, GN, H = dd["d_in"], dd["G"] * dd["N"], dd["H"]
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"])
    z = zxbcdt[..., :d_in]
    xin = zxbcdt[..., d_in:2 * d_in]
    Bm = zxbcdt[..., 2 * d_in:2 * d_in + GN]
    Cm = zxbcdt[..., 2 * d_in + GN:2 * d_in + 2 * GN]
    dt = zxbcdt[..., 2 * d_in + 2 * GN:]
    return z, xin, Bm, Cm, dt, dd


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: xbc (B,L,ch), w (W,ch)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for k in range(W):  # W is small (4); unrolled adds fuse well
        out = out + pad[:, k:k + xbc.shape[1], :] * w[k]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                s_init: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative; Bm/Cm (B,L,G,N).
    Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    B, L, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    c = L // chunk
    Q = chunk

    la = (dt * A).astype(jnp.float32)                        # log a_t (B,L,H)
    u = (xh.astype(jnp.float32) * dt[..., None])             # (B,L,H,P)

    def r(x_, sh):  # reshape to chunks
        return x_.reshape((B, c, Q) + sh)
    la_c = r(la, (H,))
    u_c = r(u, (H, P))
    B_c = jnp.repeat(r(Bm.astype(jnp.float32), (G, N)), rep, axis=3)  # (B,c,Q,H,N)
    C_c = jnp.repeat(r(Cm.astype(jnp.float32), (G, N)), rep, axis=3)

    cum = jnp.cumsum(la_c, axis=2)                           # (B,c,Q,H)
    # intra-chunk: decay matrix per head, masked lower-triangular
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,c,i,j,H)
    ii = jnp.arange(Q)
    tri = (ii[:, None] >= ii[None, :])
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", C_c, B_c) * decay
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, u_c)

    # per-chunk local end state: sum_j exp(cum_Q - cum_j) B_j (x) u_j
    wlast = jnp.exp(cum[:, :, -1:, :] - cum)                 # (B,c,Q,H)
    s_local = jnp.einsum("bcqhn,bcqhp,bcqh->bchnp", B_c, u_c, wlast)
    a_chunk = jnp.exp(cum[:, :, -1, :])                      # (B,c,H)

    s0 = (jnp.zeros((B, H, N, P), jnp.float32) if s_init is None
          else s_init.astype(jnp.float32))

    def chunk_step(s_prev, inp):
        a_l, s_loc = inp                                     # (B,H), (B,H,N,P)
        s_out = a_l[..., None, None] * s_prev + s_loc
        return s_out, s_prev                                  # emit state *before* chunk

    s_last, s_prevs = jax.lax.scan(
        chunk_step, s0,
        (a_chunk.transpose(1, 0, 2), s_local.transpose(1, 0, 2, 3, 4)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)               # (B,c,H,N,P)

    w_in = jnp.exp(cum)                                      # L_i within chunk
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", C_c, s_prevs, w_in)

    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(xh.dtype), s_last


def mamba2_forward(p: Dict[str, Any], d_model: int, s: SSMConfig,
                   x: jax.Array) -> jax.Array:
    z, xin, Bm, Cm, dt, dd = _split_proj(p, x, d_model, s)
    H, P, N, G = dd["H"], dd["P"], dd["N"], dd["G"]
    B, L, _ = x.shape
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin = xbc[..., :dd["d_in"]].reshape(B, L, H, P)
    Bm = xbc[..., dd["d_in"]:dd["d_in"] + G * N].reshape(B, L, G, N)
    Cm = xbc[..., dd["d_in"] + G * N:].reshape(B, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(s.chunk, L)
    if L % chunk:
        raise ValueError(f"seq len {L} not divisible by chunk {chunk}")
    y, _ = ssd_chunked(xin, dt, A, Bm, Cm, chunk)
    y = y + xin * p["D"][:, None].astype(y.dtype)
    y = y.reshape(B, L, dd["d_in"])
    y = _gated_norm(y, z, p["norm_scale"])
    y = shard(y, "batch", "seq", "mlp_act")
    return jnp.einsum("ble,ed->bld", y, p["out_proj"])


def init_ssm_state(batch: int, d_model: int, s: SSMConfig,
                   dtype=jnp.float32) -> SSMState:
    dd = mamba_dims(d_model, s)
    return SSMState(
        conv=jnp.zeros((batch, s.conv_width - 1, dd["conv_ch"]), dtype),
        s=jnp.zeros((batch, dd["H"], dd["N"], dd["P"]), jnp.float32),
    )


def mamba2_decode(p: Dict[str, Any], d_model: int, s: SSMConfig,
                  x: jax.Array, state: SSMState
                  ) -> Tuple[jax.Array, SSMState]:
    """x (B,1,d) -> (y (B,1,d), new state)."""
    z, xin, Bm, Cm, dt, dd = _split_proj(p, x, d_model, s)
    H, P, N, G = dd["H"], dd["P"], dd["N"], dd["G"]
    B = x.shape[0]
    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)[:, 0]      # (B,ch)
    # conv ring step
    buf = jnp.concatenate([state.conv, xbc[:, None, :].astype(state.conv.dtype)],
                          axis=1)                            # (B,W,ch)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = buf[:, 1:, :]
    xin = conv_out[:, :dd["d_in"]].reshape(B, H, P)
    Bm = conv_out[:, dd["d_in"]:dd["d_in"] + G * N].reshape(B, G, N)
    Cm = conv_out[:, dd["d_in"] + G * N:].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)     # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt1 * (-jnp.exp(p["A_log"])))                # (B,H)
    u = xin.astype(jnp.float32) * dt1[..., None]             # (B,H,P)
    s_new = (a[..., None, None] * state.s
             + Bh[..., :, None] * u[..., None, :])           # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, s_new)
    y = y + xin.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, 1, dd["d_in"]).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"])
    return out, SSMState(conv=new_conv, s=s_new)
