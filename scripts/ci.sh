#!/usr/bin/env bash
# CI entry point: the repo's tier-1 verification in one command.
#   scripts/ci.sh            # tier-1 test suite + fast co-sim smoke
#   scripts/ci.sh -k serving # pass extra pytest args through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# fast co-sim smoke: exercises the event core, interference model and
# reactive loop end-to-end on every CI run (seconds, CSV to stdout)
python -m benchmarks.run --smoke
