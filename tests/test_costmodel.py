"""Communication-cost accounting vs the paper's §V-D absolute numbers."""
import numpy as np
import pytest

from repro.core import (GRU_MODEL_BYTES, HFLOPInstance, flat_fl_cost,
                        hfl_cost, savings_vs_flat)


def _usecase_instance(n=20, m=4):
    """The paper's clustered topology: every device has a zero-cost edge."""
    c_d = np.ones((n, m))
    assign = np.repeat(np.arange(m), n // m)
    c_d[np.arange(n), assign] = 0.0
    return HFLOPInstance(c_d, c_e=np.ones(m), lam=np.ones(n),
                         r=np.full(m, np.inf), l=2), assign


def test_flat_fl_matches_paper():
    """Paper: ~2.37 GB for flat FL (20 devices, 100 rounds, 594 KB)."""
    rep = flat_fl_cost(20, 100)
    assert rep.gigabytes == pytest.approx(2.376, abs=0.01)


def test_uncapacitated_matches_paper():
    """Paper: ~0.24 GB when every device sits on its free edge (only the
    4 edge->cloud links are metered, 50 global rounds)."""
    inst, assign = _usecase_instance()
    rep = hfl_cost(inst, assign, total_rounds=100)
    assert rep.n_global_rounds == 50
    assert rep.gigabytes == pytest.approx(0.2376, abs=0.005)


def test_capacitated_between_bounds():
    """With finite capacities forcing ~2-3 devices to non-free edges, the
    volume lands between the uncapacitated bound and flat FL (paper's
    0.53 GB point)."""
    inst, assign = _usecase_instance()
    # force 3 devices onto metered edges (capacity spillover)
    spilled = assign.copy()
    spilled[:3] = (spilled[:3] + 1) % 4
    rep = hfl_cost(inst, spilled, total_rounds=100)
    assert 0.2376 < rep.gigabytes < 2.376
    assert rep.gigabytes == pytest.approx(0.2376 + 3 * 100 * 2
                                          * GRU_MODEL_BYTES / 1e9, rel=1e-6)


def test_savings_positive_and_ordered():
    inst, assign = _usecase_instance()
    s = savings_vs_flat(inst, assign, 100)
    assert s == pytest.approx(90.0, abs=1.0)   # 0.2376 vs 2.376 -> 90%
