"""Unified model API: one entry point per architecture family.

``make_model(arch_cfg)`` returns a :class:`ModelApi` whose functions share
a uniform batch convention:

  - LM families:   batch = {"tokens": (B,S) i32, "labels": (B,S) i32}
  - vlm:           + "patches": (B,P,d) stub patch embeddings (prefix)
  - audio:         + "frames": (B,F,d) stub frame embeddings (encoder)
  - rnn (paper):   batch = {"windows": (B,T,1) f32, "targets": (B,1) f32}
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ModelConfig
from repro.models import encdec, gru, hybrid, transformer, xlstm
from repro.models.layers import cross_entropy_loss


class ModelApi(NamedTuple):
    cfg: ArchConfig
    init_params: Callable[[jax.Array], Tuple[Any, Any]]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]
    loss: Callable[[Any, Dict[str, jax.Array]], jax.Array]
    init_cache: Callable[[int, int], Any]
    decode_step: Callable[..., Tuple[jax.Array, Any]]
    # one-shot full-sequence prefill writing the KV/latent cache; None for
    # inherently recurrent families (the engine falls back to a fused
    # scan-over-decode program there)
    prefill: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    # paged-cache path (block-table pool); transformer families only —
    # recurrent/hybrid state is O(1) per sequence, paging buys nothing
    init_paged_cache: Optional[Callable[[int, int], Any]] = None
    paged_prefill: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    paged_decode_step: Optional[Callable[..., Tuple[jax.Array, Any]]] = None


def _extra(batch: Dict[str, jax.Array], m: ModelConfig):
    if m.family == "vlm":
        return batch.get("patches")
    if m.family == "audio":
        return batch.get("frames")
    return None


def make_model(cfg: ArchConfig) -> ModelApi:
    m = cfg.model
    remat = cfg.run.remat

    if m.family == "rnn":
        def fwd(params, batch):
            return gru.forward(params, m, batch["windows"]), jnp.zeros(())

        def loss(params, batch):
            return gru.mse_loss(params, m, batch["windows"],
                                batch["targets"])

        return ModelApi(
            cfg=cfg,
            init_params=lambda rng: gru.init_params(rng, m),
            forward=fwd,
            loss=loss,
            init_cache=lambda b, n: None,
            decode_step=lambda params, tokens, pos, cache, **kw:
                gru.decode_step(params, m, tokens, pos, cache),
        )

    if m.family == "ssm":          # xlstm
        mod = xlstm
    elif m.family == "hybrid":     # zamba2
        mod = hybrid
    elif m.family == "audio":      # whisper
        mod = encdec
    else:                          # dense / moe / vlm
        mod = transformer

    def fwd(params, batch):
        kw = {}
        if mod in (transformer, hybrid, encdec, xlstm):
            kw["remat"] = remat
        return mod.forward(params, m, batch["tokens"],
                           extra_embeds=_extra(batch, m), **kw)

    def loss(params, batch):
        logits, aux = fwd(params, batch)
        labels = batch["labels"]
        if m.family == "vlm" and "patches" in batch:
            P = batch["patches"].shape[1]
            pad = jnp.full(labels.shape[:1] + (P,), -100, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        return cross_entropy_loss(logits, labels, m.vocab_size) + aux

    def decode(params, tokens, pos, cache, **kw):
        return mod.decode_step(params, m, tokens, pos, cache, **kw)

    cache_dtype = (jnp.dtype(cfg.run.cache_dtype)
                   if cfg.run.cache_dtype else None)

    prefill = None
    init_paged_cache = paged_prefill = paged_decode_step = None
    if mod is transformer:
        def prefill(params, tokens, cache, length=None, **kw):
            return transformer.prefill(params, m, tokens, cache,
                                       length=length, **kw)

        def init_paged_cache(num_pages, page_size):
            return transformer.init_paged_cache(m, num_pages, page_size,
                                                dtype=cache_dtype)

        def paged_prefill(params, tokens, cache, block_tables, length=None):
            return transformer.paged_prefill(params, m, tokens, cache,
                                             block_tables, length=length)

        def paged_decode_step(params, tokens, pos, cache, block_tables):
            return transformer.paged_decode_step(params, m, tokens, pos,
                                                 cache, block_tables)

    return ModelApi(
        cfg=cfg,
        init_params=lambda rng: mod.init_params(rng, m),
        forward=fwd,
        loss=loss,
        init_cache=lambda b, n: mod.init_cache(m, b, n, dtype=cache_dtype),
        decode_step=decode,
        prefill=prefill,
        init_paged_cache=init_paged_cache,
        paged_prefill=paged_prefill,
        paged_decode_step=paged_decode_step,
    )
