"""Paper Fig. 9: communication-cost savings vs standard FL for increasing
edge-node density (fixed device count), comparing HFLOP and its
uncapacitated lower-bound variant; plus the §V-D absolute volumes for the
use-case topology (paper: 2.37 / 0.53 / 0.24 GB)."""
from __future__ import annotations

import numpy as np

from repro.core import (GRU_MODEL_BYTES, HFLOPInstance, flat_fl_cost,
                        hfl_cost, paper_cost_instance, savings_vs_flat,
                        solve_heuristic, solve_uncapacitated)
from benchmarks.common import emit


def run(n=200, densities=(2, 5, 10, 20, 40), seeds=3, total_rounds=100,
        capacity_slack=1.3):
    rows = []
    for m in densities:
        s_cap, s_unc = [], []
        for seed in range(seeds):
            inst = paper_cost_instance(n, m, seed=seed,
                                       capacity_slack=capacity_slack)
            cap = solve_heuristic(inst)
            unc = solve_uncapacitated(inst)
            s_cap.append(savings_vs_flat(inst, cap.assign, total_rounds))
            s_unc.append(savings_vs_flat(inst, unc.assign, total_rounds))
        ci = lambda a: 1.96 * np.std(a) / np.sqrt(len(a))
        emit(f"fig9_m{m}_hflop", np.mean(s_cap) * 1000,
             f"savings_pct={np.mean(s_cap):.2f};ci={ci(s_cap):.2f}")
        emit(f"fig9_m{m}_uncap", np.mean(s_unc) * 1000,
             f"savings_pct={np.mean(s_unc):.2f};ci={ci(s_unc):.2f}")
        rows.append((m, np.mean(s_cap), np.mean(s_unc)))
    return rows


def usecase_volumes(total_rounds=100):
    """§V-D absolute numbers for the 4-edge / 20-device use case with a
    capacity draw that forces a few devices off their free edge."""
    rng = np.random.default_rng(0)
    n, m = 20, 4
    loc = np.repeat(np.arange(m), 5)
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    lam = rng.uniform(0.5, 1.5, n)
    # hot cluster 0: its edge covers only 4 of its 5 members' load, and the
    # remaining slack elsewhere absorbs ~1 more -> ~1-2 devices pay metered
    # links (the paper's 0.53 GB operating point)
    r = np.array([np.sort(lam[loc == 0])[:4].sum() * 1.01]
                 + [lam[loc == j].sum() * 1.25 for j in range(1, m)])
    inst = HFLOPInstance(c_d, np.ones(m), lam, r, l=2)
    flat = flat_fl_cost(n, total_rounds)
    cap = solve_heuristic(inst)
    unc = solve_uncapacitated(inst)
    v_flat = flat.gigabytes
    v_cap = hfl_cost(inst, cap.assign, total_rounds).gigabytes
    v_unc = hfl_cost(inst, unc.assign, total_rounds).gigabytes
    emit("fig9_usecase_flat_gb", v_flat * 1e6, f"GB={v_flat:.3f};paper=2.37")
    emit("fig9_usecase_hflop_gb", v_cap * 1e6, f"GB={v_cap:.3f};paper=0.53")
    emit("fig9_usecase_uncap_gb", v_unc * 1e6, f"GB={v_unc:.3f};paper=0.24")
    return v_flat, v_cap, v_unc


if __name__ == "__main__":
    run()
    usecase_volumes()
