"""TEL001 suppressed fixture: sanctioned observer wiring."""


class Handler:
    def __init__(self, budget, tel):
        self._tel = tel
        if self._tel is not None:
            budget.observer = self._on_charge  # contract: ok TEL001

    def _on_charge(self, amount):
        self._tel.metrics.counter("charges").inc()
