"""Device-population partitioning for the hierarchically decomposed
HFLOP solver (``repro.core.solvers.solve_decomposed``).

The decomposition follows the client–edge–cloud structure of HFL
(Liu et al., arXiv:1905.06641) and heterogeneity-aware topology design
(Gao et al., arXiv:2409.19509): the *edge set* is partitioned into
regions, every device is attached to the region of its cheapest edge
(its LAN host in the paper's cost model), each region is solved as an
independent capacitated sub-problem, and a stitch pass repairs the
boundary.  Two partitioners:

  * **LAN grouping** — for the paper's cost structure (each device has
    one zero-cost edge, every other edge costs ``unit_cost``), edges
    are interchangeable beyond their home load, so regions are built by
    balanced-load grouping of edges (largest home load first, into the
    currently lightest region);
  * **k-medoids on cost columns** — for generic instances, edges are
    clustered by the similarity of their ``c_d`` column over a
    deterministic device sample, so edges that look alike to the
    device population land in the same region.

The module also carries :class:`LanHFLOPInstance` — an *implicit*
representation of the paper's Fig. 9 cost structure that never
materializes the dense ``(n, m)`` cost matrix.  At n = 10^6 devices x
m = 10^3 edges the dense matrix is 8 GB; the structured form is three
1-D arrays, and the decomposed solver only densifies per-region
``(n_r, m_r)`` blocks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Union

import numpy as np

from repro.core.hflop import HFLOPInstance


# ---------------------------------------------------------------------------
# structured (LAN) instance — the paper cost model without the dense matrix
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LanHFLOPInstance:
    """The Fig. 9 cost structure in implicit form: device i costs 0 at
    its LAN edge ``free[i]`` (-1 = no LAN edge) and ``unit_cost`` at
    every other edge.  Semantically identical to the dense
    ``paper_cost_instance`` (``to_dense`` round-trips exactly) but O(n)
    memory, so million-device instances fit."""
    free: np.ndarray                 # (n,) int64, zero-cost edge or -1
    c_e: np.ndarray                  # (m,) edge open costs
    lam: np.ndarray                  # (n,) device request rates
    r: np.ndarray                    # (m,) edge serving capacities
    unit_cost: float = 1.0
    l: int = 2
    T: Optional[int] = None          # min participating devices (None -> n)

    def __post_init__(self):
        object.__setattr__(self, "free", np.asarray(self.free, np.int64))
        object.__setattr__(self, "c_e", np.asarray(self.c_e, np.float64))
        object.__setattr__(self, "lam", np.asarray(self.lam, np.float64))
        object.__setattr__(self, "r", np.asarray(self.r, np.float64))
        if self.T is None:
            object.__setattr__(self, "T", self.n)

    @property
    def n(self) -> int:
        return self.free.shape[0]

    @property
    def m(self) -> int:
        return self.c_e.shape[0]

    def cost_rows(self, ids: np.ndarray) -> np.ndarray:
        """Dense ``c_d`` rows for a batch of devices — the only shape
        the vectorized solvers ever need."""
        ids = np.asarray(ids)
        rows = np.full((ids.size, self.m), self.unit_cost)
        has = self.free[ids] >= 0
        rows[np.nonzero(has)[0], self.free[ids][has]] = 0.0
        return rows

    def local_costs(self, assign: np.ndarray) -> np.ndarray:
        """Per-device local cost of an assignment (0 on the LAN edge,
        ``unit_cost`` elsewhere; unassigned devices cost 0)."""
        assign = np.asarray(assign)
        ok = assign >= 0
        return np.where(ok & (assign != self.free),
                        self.unit_cost, 0.0) * self.l

    def objective(self, assign: np.ndarray) -> float:
        assign = np.asarray(assign)
        ok = assign >= 0
        local = float(np.sum(self.local_costs(assign)))
        open_edges = np.unique(assign[ok])
        return local + float(np.sum(self.c_e[open_edges]))

    def violations(self, assign: np.ndarray) -> List[str]:
        out = []
        assign = np.asarray(assign)
        if assign.shape != (self.n,):
            return [f"assign shape {assign.shape} != ({self.n},)"]
        if np.any(assign >= self.m):
            out.append("assignment to nonexistent edge")
        participating = int(np.sum(assign >= 0))
        if participating < self.T:
            out.append(f"participation {participating} < T={self.T}")
        valid = (assign >= 0) & (assign < self.m)
        loads = np.bincount(assign[valid], weights=self.lam[valid],
                            minlength=self.m)
        for j in np.nonzero(loads > self.r + 1e-9)[0]:
            out.append(f"edge {j}: load {loads[j]:.3f} > "
                       f"r={self.r[j]:.3f}")
        return out

    def is_feasible(self, assign: np.ndarray) -> bool:
        return not self.violations(assign)

    def to_dense(self) -> HFLOPInstance:
        """Materialize the dense instance (small n only — 8 GB at
        n=10^6, m=10^3)."""
        c_d = np.full((self.n, self.m), self.unit_cost)
        has = self.free >= 0
        c_d[np.nonzero(has)[0], self.free[has]] = 0.0
        return HFLOPInstance(c_d, self.c_e, self.lam, self.r,
                             l=self.l, T=self.T)


def paper_cost_lan(n: int, m: int, seed: int = 0, l: int = 2,
                   capacity_slack: float = 1.5) -> LanHFLOPInstance:
    """The Fig. 9 setup in structured form.  Consumes the generator
    stream in exactly the order ``hflop.paper_cost_instance`` does, so
    ``paper_cost_lan(n, m, seed).to_dense()`` equals
    ``paper_cost_instance(n, m, seed)`` array-for-array (asserted in
    the tests) — the structured path is the *same* instance, just never
    materialized."""
    rng = np.random.default_rng(seed)
    free = rng.integers(0, m, n)
    lam = rng.uniform(0.1, 1.0, n)
    raw = rng.uniform(0.5, 1.5, m)
    r = raw / raw.sum() * lam.sum() * capacity_slack
    return LanHFLOPInstance(free=free, c_e=np.ones(m), lam=lam, r=r,
                            unit_cost=1.0, l=l, T=n)


AnyInstance = Union[HFLOPInstance, LanHFLOPInstance]


def sub_instance(inst: AnyInstance, devices: np.ndarray,
                 edges: np.ndarray, T: Optional[int] = None,
                 ) -> HFLOPInstance:
    """Dense region sub-problem: the (devices x edges) block of the
    cost structure with the region's own capacities."""
    devices = np.asarray(devices)
    edges = np.asarray(edges)
    if isinstance(inst, LanHFLOPInstance):
        c_d = np.full((devices.size, edges.size), inst.unit_cost)
        inv = np.full(inst.m, -1, np.int64)
        inv[edges] = np.arange(edges.size)
        loc = np.where(inst.free[devices] >= 0,
                       inv[np.clip(inst.free[devices], 0, inst.m - 1)], -1)
        has = loc >= 0
        c_d[np.nonzero(has)[0], loc[has]] = 0.0
    else:
        c_d = inst.c_d[np.ix_(devices, edges)]
    return HFLOPInstance(c_d, inst.c_e[edges], inst.lam[devices],
                         inst.r[edges], l=inst.l,
                         T=devices.size if T is None else T)


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Partition:
    """Region labels over edges and devices.  Devices always live in
    the region of their cheapest edge; region indices are dense
    ``0..n_regions-1``."""
    region_of_edge: np.ndarray       # (m,) int64
    region_of_device: np.ndarray     # (n,) int64
    n_regions: int
    method: str = ""

    def edges_in(self, region: int) -> np.ndarray:
        return np.nonzero(self.region_of_edge == region)[0]

    def devices_in(self, region: int) -> np.ndarray:
        return np.nonzero(self.region_of_device == region)[0]


def default_regions(n: int, m: int, target_edges: int = 16,
                    target_devices: int = 50_000) -> int:
    """Region count balancing sub-problem size: ~``target_edges`` edges
    and at most ~``target_devices`` devices per region."""
    return max(1, min(m, max(-(-m // target_edges),
                             -(-n // target_devices))))


def _balance_edges(weight: np.ndarray, k: int) -> np.ndarray:
    """Greedy balanced grouping: heaviest edge first, into the region
    with the least total weight so far (deterministic: stable sort,
    lowest-index region on ties)."""
    m = weight.shape[0]
    labels = np.empty(m, np.int64)
    totals = np.zeros(k)
    for j in np.argsort(-weight, kind="stable"):
        g = int(np.argmin(totals))
        labels[j] = g
        totals[g] += weight[j]
    return labels


def _kmedoids_edges(c_d: np.ndarray, k: int, sample_rows: int = 512,
                    iters: int = 8) -> np.ndarray:
    """Deterministic k-medoids over the columns of ``c_d``: edges whose
    cost columns look alike to (a sample of) the device population end
    up in the same region.  Farthest-point init from the most central
    column; a few alternation rounds of assign / medoid-update."""
    n, m = c_d.shape
    if k >= m:
        return np.arange(m, dtype=np.int64)
    rows = (c_d if n <= sample_rows
            else c_d[np.linspace(0, n - 1, sample_rows).astype(np.int64)])
    X = np.ascontiguousarray(rows.T)               # (m, s) edge profiles
    D = np.abs(X[:, None, :] - X[None, :, :]).mean(axis=2)
    med = [int(np.argmin(D.sum(axis=1)))]          # most central edge
    while len(med) < k:
        d_min = D[:, med].min(axis=1)
        d_min[med] = -np.inf
        med.append(int(np.argmax(d_min)))
    med = np.asarray(sorted(med), np.int64)
    labels = np.argmin(D[:, med], axis=1)
    for _ in range(iters):
        new_med = med.copy()
        for g in range(k):
            members = np.nonzero(labels == g)[0]
            if members.size == 0:
                continue
            within = D[np.ix_(members, members)].sum(axis=1)
            new_med[g] = int(members[np.argmin(within)])
        new_labels = np.argmin(D[:, new_med], axis=1)
        if np.array_equal(new_med, med) and np.array_equal(new_labels,
                                                          labels):
            break
        med, labels = new_med, new_labels
    # compact away empty regions
    used, labels = np.unique(labels, return_inverse=True)
    return labels.astype(np.int64)


def _device_home_edges(inst: AnyInstance, chunk: int = 65_536,
                       ) -> np.ndarray:
    """Cheapest edge per device (the LAN host under the paper cost
    model), computed in bounded-memory chunks for dense instances."""
    if isinstance(inst, LanHFLOPInstance):
        # devices without a LAN edge are indifferent: home them on edge 0
        return np.where(inst.free >= 0, inst.free, 0)
    n = inst.n
    out = np.empty(n, np.int64)
    for a in range(0, n, chunk):
        out[a:a + chunk] = np.argmin(inst.c_d[a:a + chunk], axis=1)
    return out


def partition_instance(inst: AnyInstance,
                       regions: Optional[int] = None) -> Partition:
    """Partition the continuum: group edges into ``regions`` regions
    (LAN-load balancing for structured instances, k-medoids on cost
    columns otherwise) and attach every device to the region of its
    cheapest edge."""
    n, m = inst.n, inst.m
    k = default_regions(n, m) if regions is None else int(regions)
    k = max(1, min(k, m))
    home = _device_home_edges(inst)
    if isinstance(inst, LanHFLOPInstance):
        weight = np.bincount(home, weights=inst.lam, minlength=m)
        region_of_edge = _balance_edges(weight, k)
        method = "lan-balanced"
    else:
        region_of_edge = _kmedoids_edges(inst.c_d, k)
        method = "kmedoids"
    region_of_device = region_of_edge[home]
    return Partition(region_of_edge=region_of_edge,
                     region_of_device=region_of_device,
                     n_regions=int(region_of_edge.max()) + 1,
                     method=method)
