from repro.routing.latency import LatencyModel
from repro.routing.rules import EdgeState, RouteDecision, route_request
from repro.routing.simulator import (RequestLog, SimConfig, compare_methods,
                                     simulate)

__all__ = ["LatencyModel", "EdgeState", "RouteDecision", "route_request",
           "RequestLog", "SimConfig", "compare_methods", "simulate"]
