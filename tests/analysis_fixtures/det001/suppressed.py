"""DET001 suppressed fixture: sanctioned global draw."""
import numpy as np


def sample(n):
    return np.random.rand(n)  # contract: ok DET001
