"""Paper Fig. 2: time to derive the optimal HFLOP solution vs instance
size.  The paper used CPLEX on an 8-core Ryzen; we report our own exact
branch-and-bound (dense-simplex LP relaxation) plus the heuristic path
used for large instances, with 95% CIs over seeds."""
from __future__ import annotations

import time

import numpy as np

from repro.core import random_instance, solve_bnb, solve_heuristic
from benchmarks.common import emit


def run(sizes=((10, 3), (20, 4), (40, 5), (80, 6)), seeds=3,
        time_limit=60.0, heur_sizes=((500, 20), (2000, 50), (10000, 100))):
    rows = []
    for (n, m) in sizes:
        ts, opt = [], 0
        for s in range(seeds):
            inst = random_instance(n, m, seed=s)
            t0 = time.perf_counter()
            sol = solve_bnb(inst, time_limit_s=time_limit)
            ts.append(time.perf_counter() - t0)
            opt += int(sol.optimal)
        mean = np.mean(ts)
        ci = 1.96 * np.std(ts) / max(np.sqrt(len(ts)), 1)
        emit(f"fig2_bnb_n{n}_m{m}", mean * 1e6,
             f"optimal={opt}/{seeds};ci95_s={ci:.3f}")
        rows.append((n, m, mean, ci, opt))
    for (n, m) in heur_sizes:
        ts = []
        for s in range(seeds):
            inst = random_instance(n, m, seed=s)
            t0 = time.perf_counter()
            solve_heuristic(inst)
            ts.append(time.perf_counter() - t0)
        emit(f"fig2_heuristic_n{n}_m{m}", np.mean(ts) * 1e6,
             f"ci95_s={1.96 * np.std(ts) / np.sqrt(len(ts)):.3f}")
        rows.append((n, m, np.mean(ts), 0.0, -1))
    return rows


if __name__ == "__main__":
    run()
