from repro.routing.latency import CalibratedLatencyModel, LatencyModel
from repro.routing.rules import EdgeState, RouteDecision, route_request
from repro.routing.simulator import (RequestLog, SimConfig, compare_methods,
                                     simulate)

__all__ = ["CalibratedLatencyModel", "LatencyModel", "EdgeState",
           "RouteDecision", "route_request", "RequestLog", "SimConfig",
           "compare_methods", "simulate"]
