"""FL aggregation, compression, collectives, continual loop."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.fl import (ClientBatch, EFState, cluster_fedavg,
                      compressed_global_sync, dequantize_int8, fedavg,
                      global_fedavg, global_sync, init_ef_state,
                      quantize_int8, stack_clients, stack_for_clusters,
                      sync_bytes)


def _stacked(C=6, shape=(4, 3), seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(C,) + shape), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(C, shape[1])), jnp.float32)}


def test_fedavg_weighted_mean():
    st = _stacked()
    w = jnp.asarray([1, 2, 3, 4, 5, 6.0])
    out = fedavg(st, w)
    manual = np.average(np.asarray(st["w"]), axis=0, weights=np.asarray(w))
    np.testing.assert_allclose(np.asarray(out["w"]), manual, rtol=1e-6)


def test_cluster_fedavg_segments():
    st = _stacked(C=6)
    cid = np.array([0, 0, 1, 1, 2, 2])
    out = cluster_fedavg(st, cid)
    for k in range(3):
        members = np.nonzero(cid == k)[0]
        manual = np.mean(np.asarray(st["w"])[members], axis=0)
        for i in members:
            np.testing.assert_allclose(np.asarray(out["w"])[i], manual,
                                       rtol=1e-5)


def test_global_fedavg_broadcasts_single_model():
    st = _stacked(C=6)
    cid = np.array([0, 0, 1, 1, 2, 2])
    out = global_fedavg(st, cid)
    w = np.asarray(out["w"])
    for i in range(1, 6):
        np.testing.assert_allclose(w[i], w[0], rtol=1e-5)
    # equal weights: global model = overall mean
    np.testing.assert_allclose(w[0], np.mean(np.asarray(st["w"]), axis=0),
                               rtol=1e-5)


def test_global_sync_equals_mean():
    params = {"w": jnp.arange(12.0).reshape(3, 4)}
    stacked = stack_for_clusters(params, 4)
    stacked = jax.tree.map(
        lambda x: x + jnp.arange(4.0).reshape(4, 1, 1), stacked)
    out = global_sync(stacked)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(params["w"]) + 1.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(out["w"][3]), rtol=1e-6)


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-7


def test_compressed_sync_error_feedback_converges():
    """Identical replicas + EF: after sync all replicas equal, and the
    anchor tracks the true mean within one quantization step."""
    rng = np.random.default_rng(1)
    shared = rng.normal(size=(8, 8))           # replicas start identical
    base = {"w": jnp.asarray(np.broadcast_to(shared, (4, 8, 8)),
                             jnp.float32)}
    ef = init_ef_state(base)
    drift = jnp.asarray(rng.normal(size=(4, 8, 8)) * 0.1, jnp.float32)
    moved = {"w": base["w"] + drift}
    synced, ef2 = compressed_global_sync(moved, ef)
    w = np.asarray(synced["w"])
    np.testing.assert_allclose(w[0], w[3], rtol=1e-6)
    true_mean = np.mean(np.asarray(moved["w"]), axis=0)
    assert np.abs(w[0] - true_mean).max() < 0.01   # int8 of 0.1-scale drift
    # residual bounded by quantization step
    assert float(jnp.abs(ef2.residual["w"]).max()) < 0.01


def test_sync_bytes_compression_ratio():
    st = {"w": jnp.zeros((4, 1024), jnp.float32)}
    assert sync_bytes(st, compressed=False) == 4096
    assert sync_bytes(st, compressed=True) == 1024


def test_train_clients_locally_improves_loss():
    from repro.configs import get_config
    from repro.fl.client import eval_clients, train_clients_locally
    from repro.models import gru
    cfg = get_config("gru-traffic")
    rng = np.random.default_rng(0)
    # learnable toy signal: next value = 0.9 * last
    T, N, C = 12, 200, 3
    X = rng.normal(size=(C, N, T, 1)).astype(np.float32)
    y = (X[:, :, -1, :] * 0.9).astype(np.float32)
    data = ClientBatch(X=jnp.asarray(X), y=jnp.asarray(y))
    p0, _ = gru.init_params(jax.random.key(0), cfg.model)
    stacked = stack_clients([p0] * C)
    before = np.asarray(eval_clients(stacked, data, cfg=cfg))
    out, _ = train_clients_locally(stacked, data, jax.random.key(1),
                                   cfg=cfg, epochs=3, batch_size=20,
                                   lr=5e-3)
    after = np.asarray(eval_clients(out, data, cfg=cfg))
    assert (after < before).all()
