"""Controller reaction paths (paper §III last paragraph): node-failure
edge-id remapping, capacity-change re-clustering, accuracy-alarm
threshold semantics, recluster counting, the reactive loop driving the
hooks from inside the co-simulation, and the recluster-accounting
regressions (cooldown stamping, drift-credit gating, topology->
inventory edge mapping)."""
import numpy as np
import pytest

from repro.core import is_feasible
from repro.core.topology import ClusterTopology
from repro.orchestration import (DeviceNode, EdgeNode, Inventory,
                                 LearningController, random_inventory)
from repro.orchestration.controller import Deployment
from repro.sim import (AccuracyModel, CoSim, CoSimConfig, ReactiveLoop,
                       ReactivePolicy, ReconfigBudget)


def _controller(n=16, m=4, seed=0):
    inv = random_inventory(n=n, m=m, seed=seed, capacity_slack=1.8)
    return LearningController(inventory=inv, l=2)


# ---------------------------------------------------------------------------
# on_node_failure: edge-id remapping (regression for the satellite fix)
# ---------------------------------------------------------------------------

def test_node_failure_remaps_lan_edges():
    """Removing edge 1 renumbers 2->1, 3->2; devices must follow their
    *physical* edge, not keep a stale id."""
    devices = [DeviceNode(0, lam=1.0, lan_edge=0),
               DeviceNode(1, lam=1.0, lan_edge=1),
               DeviceNode(2, lam=1.0, lan_edge=2),
               DeviceNode(3, lam=1.0, lan_edge=3)]
    edges = [EdgeNode(j, capacity_rps=10.0) for j in range(4)]
    ctl = LearningController(inventory=Inventory(devices, edges), l=2)
    ctl.on_node_failure(1)
    lan = [d.lan_edge for d in ctl.inventory.devices]
    # edge 0 keeps id 0; edge 1 died; old edge 2 is now 1, old 3 is now 2
    assert lan == [0, None, 1, 2]
    assert [e.id for e in ctl.inventory.edges] == [0, 1, 2]


def test_node_failure_remap_preserves_zero_cost_link():
    """The device that pointed at old edge 3 must still get cost 0 to
    that same physical edge (new id 2) in the rebuilt instance."""
    devices = [DeviceNode(i, lam=0.5, lan_edge=3) for i in range(4)]
    edges = [EdgeNode(j, capacity_rps=5.0, cloud_cost=float(j))
             for j in range(4)]
    ctl = LearningController(inventory=Inventory(devices, edges), l=2)
    ctl.on_node_failure(1)
    inst = ctl.inventory.to_instance(l=2)
    # old edge 3 (cloud_cost 3.0) is now index 2
    assert ctl.inventory.edges[2].cloud_cost == 3.0
    assert np.all(inst.c_d[:, 2] == 0.0)
    assert np.all(inst.c_d[:, :2] == 1.0)


def test_node_failure_redeploys_feasible():
    ctl = _controller()
    dep = ctl.deploy()
    failed = dep.aggregator_nodes[0]
    dep2 = ctl.on_node_failure(failed)
    inst = ctl.inventory.to_instance(l=2)
    assert is_feasible(inst, dep2.topology.assign)
    assert len(ctl.inventory.edges) == 3


# ---------------------------------------------------------------------------
# on_capacity_change
# ---------------------------------------------------------------------------

def test_capacity_change_reclusters_feasibly():
    ctl = _controller()
    dep = ctl.deploy()
    victim = dep.aggregator_nodes[0]
    new_cap = ctl.inventory.edges[victim].capacity_rps * 0.5
    dep2 = ctl.on_capacity_change(victim, new_cap)
    assert ctl.inventory.edges[victim].capacity_rps == new_cap
    inst = ctl.inventory.to_instance(l=2)
    assert is_feasible(inst, dep2.topology.assign)
    # the degraded edge no longer carries more load than it can serve
    loads = dep2.topology.cluster_loads()
    if victim in loads:
        assert loads[victim] <= new_cap + 1e-9


# ---------------------------------------------------------------------------
# on_accuracy_alarm threshold semantics
# ---------------------------------------------------------------------------

def test_accuracy_alarm_is_strictly_above_threshold():
    ctl = LearningController(inventory=random_inventory(4, 2),
                             accuracy_threshold=0.06)
    assert not ctl.on_accuracy_alarm(0.05)
    assert not ctl.on_accuracy_alarm(0.06)       # at threshold: no alarm
    assert ctl.on_accuracy_alarm(0.06 + 1e-9)
    assert ctl.on_accuracy_alarm(1.0)


# ---------------------------------------------------------------------------
# recluster counting under repeated events
# ---------------------------------------------------------------------------

def test_recluster_count_accumulates():
    ctl = _controller(seed=1)
    dep = ctl.deploy()
    assert ctl.recluster_count == 0              # initial deploy is free
    victim = dep.aggregator_nodes[0]
    cap = ctl.inventory.edges[victim].capacity_rps
    ctl.on_capacity_change(victim, cap * 0.9)
    ctl.on_capacity_change(victim, cap * 0.8)
    dep = ctl.on_node_failure(ctl.deployment.aggregator_nodes[0])
    assert ctl.recluster_count == 3
    ctl.on_capacity_change(dep.aggregator_nodes[0],
                           ctl.inventory.edges[
                               dep.aggregator_nodes[0]].capacity_rps * 0.9)
    assert ctl.recluster_count == 4


# ---------------------------------------------------------------------------
# the reactive loop drives the hooks mid-simulation
# ---------------------------------------------------------------------------

def _scenario(seed=0, n=20, m=4, slack=1.35):
    rng = np.random.default_rng(seed)
    loc = np.repeat(np.arange(m), n // m)
    lam = rng.uniform(2.0, 4.0, n)
    lam[loc == 0] *= 3.0
    r = np.full(m, lam.sum() / m * slack)
    topo = ClusterTopology(assign=loc, n_devices=n, n_edges=m, lam=lam,
                           r=r, l=2)
    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=2)
    ctl.deployment = Deployment.from_topology(topo)
    return topo, ctl


def test_reactive_drift_triggers_retraining_burst():
    topo, ctl = _scenario()
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=1e9))                   # isolate the accuracy path
    loop.acc.base_mse = 0.03
    loop.acc.drift_mse = 0.5
    ctl.accuracy_threshold = 0.1
    cosim = CoSim(topo, CoSimConfig(duration_s=90.0, seed=0),
                  reactive=loop)
    cosim.schedule_drift(20.0)
    res = cosim.run()
    burst = [a for _, a in res.actions if "retraining burst" in a]
    assert len(burst) >= 1
    assert res.rounds_completed >= 1             # the burst actually ran
    assert res.mse_series[:, 1].max() > 0.1
    # MSE recovers as burst rounds complete
    assert res.mse_series[-1, 1] < res.mse_series[:, 1].max()


def test_reactive_node_failure_reclusters_mid_sim():
    # enough slack that the surviving 3 edges can absorb the 4th's load
    topo, ctl = _scenario(slack=1.8)
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(p95_threshold_ms=1e9))
    cosim = CoSim(topo, CoSimConfig(duration_s=40.0, seed=0),
                  reactive=loop)
    cosim.schedule_failure(15.0, edge_id=0)
    res = cosim.run()
    assert ctl.recluster_count == 1
    assert len(ctl.inventory.edges) == 3
    assert len(res.reconfig_times) == 1
    # the swapped-in topology routes over the surviving edges only
    assert len(cosim.proc.topo.open_edges) <= 3


def test_reactive_derate_does_not_compound_and_restores_when_idle():
    """Repeated latency alarms derate from the NOMINAL capacity (no
    ratchet toward zero), and once training goes idle the controller
    gets its nominal rates back."""
    topo, ctl = _scenario()
    nominal = [e.capacity_rps for e in ctl.inventory.edges]
    from repro.fl import round_schedule
    # training only in the first half of the horizon
    sched = round_schedule(rounds=3, l=2, local_epochs=5, epoch_s=3.5,
                           upload_s=2.0, gap_s=2.0)
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=20.0, cooldown_s=10.0, restore_idle_s=15.0))
    res = CoSim(topo, CoSimConfig(duration_s=160.0, seed=0),
                schedule=sched, reactive=loop).run()
    derate = loop.policy.capacity_derate
    floor = min(n * (1.0 - derate) for n in nominal) * 0.999
    for t, a in res.actions:
        if "effective capacity" in a:
            eff = float(a.split("effective capacity ")[1].split(" rps")[0])
            assert eff >= floor          # never compounds below one derate
    assert any("restored" in a for _, a in res.actions)
    after = [e.capacity_rps for e in ctl.inventory.edges]
    assert after == pytest.approx(nominal)


def test_from_arrays_treats_negative_lan_edge_as_none():
    inv = Inventory.from_arrays(np.array([1.0, 1.0, 1.0]),
                                np.array([5.0, 5.0]),
                                lan_edge=np.array([0, -1, 1]))
    assert [d.lan_edge for d in inv.devices] == [0, None, 1]
    inst = inv.to_instance(l=2)
    assert np.all(inst.c_d[1] == 1.0)    # no free link for the -1 device


def test_external_capacity_change_survives_restore():
    """A genuine hardware capacity change must not be reverted by the
    idle-time nominal-capacity restoration."""
    topo, ctl = _scenario()
    from repro.fl import round_schedule
    sched = round_schedule(rounds=3, l=2, local_epochs=5, epoch_s=3.5,
                           upload_s=2.0, gap_s=2.0)
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=20.0, cooldown_s=10.0, restore_idle_s=15.0))
    cosim = CoSim(topo, CoSimConfig(duration_s=160.0, seed=0),
                  schedule=sched, reactive=loop)
    new_rps = ctl.inventory.edges[1].capacity_rps * 0.7
    cosim.schedule_capacity_change(50.0, edge_id=1, new_rps=new_rps)
    res = cosim.run()
    assert any("restored" in a for _, a in res.actions)
    assert ctl.inventory.edges[1].capacity_rps == pytest.approx(new_rps)


# ---------------------------------------------------------------------------
# regression: every recluster path stamps the cooldown
# ---------------------------------------------------------------------------

def test_failure_recluster_stamps_cooldown():
    """A failure-driven recluster opens a migration window; the p95
    alarm must not fire a second recluster inside the cooldown and
    double-pay migration_share + reconfig_penalty_ms (regression: only
    the latency path used to stamp ``last_recluster_t``)."""
    topo, ctl = _scenario(slack=1.8)
    cooldown = 30.0
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=10.0, cooldown_s=cooldown))  # alarm-prone
    from repro.fl import round_schedule
    sched = round_schedule(rounds=3, l=2, local_epochs=5, epoch_s=3.5,
                           upload_s=2.0, gap_s=2.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=sched, reactive=loop)
    cosim.schedule_failure(15.0, edge_id=1)
    res = cosim.run()
    t_fail = next(t for t, a in res.actions if "failed" in a)
    latency_after = [t for t, a in res.actions
                     if "latency alarm" in a and "reclustered" in a
                     and t > t_fail]
    assert all(t >= t_fail + cooldown for t in latency_after)
    # the failure recluster itself is exempt (correctness), but no
    # *optional* swap lands inside its still-open migration window
    assert not any(t_fail < t < t_fail + cooldown
                   for t in res.reconfig_times)


def test_capacity_recluster_stamps_cooldown():
    topo, ctl = _scenario()
    cooldown = 25.0
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=10.0, cooldown_s=cooldown))
    from repro.fl import round_schedule
    sched = round_schedule(rounds=3, l=2, local_epochs=5, epoch_s=3.5,
                           upload_s=2.0, gap_s=2.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=sched, reactive=loop)
    new_rps = ctl.inventory.edges[1].capacity_rps * 0.8
    cosim.schedule_capacity_change(9.0, edge_id=1, new_rps=new_rps)
    res = cosim.run()
    t_cap = next(t for t, a in res.actions if "capacity ->" in a)
    assert t_cap == pytest.approx(9.0)
    # no optional swap inside the capacity recluster's cooldown
    assert not any(t_cap < t < t_cap + cooldown
                   for t in res.reconfig_times)


# ---------------------------------------------------------------------------
# regression: pre-drift rounds earn no recovery credit
# ---------------------------------------------------------------------------

def test_pre_drift_round_gets_no_recovery_credit():
    acc = AccuracyModel(base_mse=0.03, drift_mse=0.12, ramp_s=10.0,
                        recovery_per_round=0.5)
    acc.on_drift(t=100.0)
    acc.on_round_complete(round_start=60.0)      # trained pre-drift
    assert acc.gap_scale == pytest.approx(1.0)
    assert acc.mse(200.0) == pytest.approx(0.12)  # gap fully open
    acc.on_round_complete(round_start=105.0)     # trained post-drift
    assert acc.gap_scale == pytest.approx(0.5)
    assert acc.mse(200.0) == pytest.approx(0.075)


def test_round_straddling_drift_onset_gets_no_credit_in_cosim():
    """A training round already running when drift begins completes
    shortly after the onset, but its data is pre-drift: the modeled MSE
    must stay on the full ramp until a post-onset round completes."""
    topo, ctl = _scenario()
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(p95_threshold_ms=1e9))
    loop.acc.ramp_s = 10.0
    ctl.accuracy_threshold = 1e9                 # no burst: isolate credit
    from repro.fl import round_schedule
    # one round spanning the onset: [0, 17.5+2]; drift at 10
    sched = round_schedule(rounds=1, l=2, local_epochs=5, epoch_s=3.5,
                           upload_s=2.0)
    cosim = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0),
                  schedule=sched, reactive=loop)
    cosim.schedule_drift(10.0)
    res = cosim.run()
    assert res.rounds_completed == 1
    assert loop.acc.gap_scale == pytest.approx(1.0)
    assert res.mse_series[-1, 1] == pytest.approx(loop.acc.drift_mse)


# ---------------------------------------------------------------------------
# regression: bottleneck derate lands on the right physical host after
# a failure renumbers the inventory under a deferred re-deploy
# ---------------------------------------------------------------------------

def test_post_failure_bottleneck_derate_maps_to_inventory_edge():
    """Budget-deferred failure re-deploy: the inventory renumbers (old
    edges 1..3 -> 0..2) while the co-sim topology still counts 4 edges.
    A latency derate on topology edge 3 must land on inventory index 2
    — the silent ``bottleneck >= len(inv_edges)`` guard used to mask
    exactly this mismatch."""
    n, m = 8, 4
    assign = np.arange(n) % m
    lam = np.ones(n)
    lam[assign == 3] = 5.0                       # topology edge 3 is hot
    r = np.array([20.0, 21.0, 22.0, 23.0])      # distinct, identifiable
    topo = ClusterTopology(assign=assign, n_devices=n, n_edges=m,
                           lam=lam, r=r, l=2)
    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=assign), l=2)
    ctl.deployment = Deployment.from_topology(topo)
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=1e9, budget_exempt_failures=False))
    cosim = CoSim(topo, CoSimConfig(duration_s=30.0, seed=0),
                  reactive=loop, budget=ReconfigBudget(total=0.0))
    cosim.schedule_failure(5.0, edge_id=0)
    cosim.sim.run(until=8.0)
    # the re-deploy was vetoed: inventory renumbered, topology stale
    assert len(ctl.inventory.edges) == 3
    assert loop._edge_to_inv == {1: 0, 2: 1, 3: 2}
    assert cosim.proc.topo.n_edges == 4
    # now let a latency derate through and check where it lands
    cosim.budget = None
    before = [e.capacity_rps for e in ctl.inventory.edges]
    assert before == [21.0, 22.0, 23.0]
    loop._recluster_for_latency(8.0, p95=100.0)
    after = [e.capacity_rps for e in ctl.inventory.edges]
    derate = loop.policy.capacity_derate
    # the hot topology edge 3 is physical inventory index 2
    assert after[2] == pytest.approx(23.0 * (1.0 - derate))
    assert after[0] == before[0] and after[1] == before[1]
    # the applied deployment realigned the numbering
    assert loop._edge_to_inv == {0: 0, 1: 1, 2: 2}
    assert cosim.proc.topo.n_edges == 3


def test_reactive_repeated_runs_are_reproducible():
    def once():
        topo, ctl = _scenario()
        loop = ReactiveLoop(ctl, policy=ReactivePolicy(
            p95_threshold_ms=20.0))
        from repro.fl import round_schedule
        sched = round_schedule(rounds=3, l=2, local_epochs=5, epoch_s=3.5,
                               upload_s=2.0, gap_s=2.0)
        res = CoSim(topo, CoSimConfig(duration_s=50.0, seed=0),
                    schedule=sched, reactive=loop).run()
        return res, ctl
    a, ctl_a = once()
    b, ctl_b = once()
    assert a.trace == b.trace
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
    assert ctl_a.recluster_count == ctl_b.recluster_count
    assert a.actions == b.actions


# ---------------------------------------------------------------------------
# regression: a failure inside an open migration window folds into it
# ---------------------------------------------------------------------------

def test_failure_during_migration_window_not_double_charged():
    """A second failure landing inside the first recluster's open
    migration window must fold into that swap: the ReconfigBudget is
    charged once (the window already paid), and the re-solve runs
    against the post-swap inventory so the edge mapping stays
    consistent."""
    from repro.sim.budget import ReconfigBudget

    def run(fail_times):
        topo, ctl = _scenario(slack=2.5)
        loop = ReactiveLoop(ctl, policy=ReactivePolicy(
            p95_threshold_ms=1e9, budget_exempt_failures=False))
        budget = ReconfigBudget(total=1e9)       # never vetoes
        cosim = CoSim(topo, CoSimConfig(duration_s=40.0, seed=0),
                      reactive=loop, budget=budget)
        for t, j in fail_times:
            cosim.schedule_failure(t, edge_id=j)
        res = cosim.run()
        return topo, ctl, cosim, budget, res

    # reconfig_s defaults to 5.0: the t=17 failure lands inside the
    # window the t=15 recluster opened ([15, 20))
    topo, ctl, cosim, budget, res = run([(15.0, 0), (17.0, 1)])
    _, _, _, budget_one, _ = run([(15.0, 0)])

    assert ctl.recluster_count == 2
    assert len(ctl.inventory.edges) == 2         # both removals landed
    folded = [a for _, a in res.actions
              if "folded into in-flight migration" in a]
    assert len(folded) == 1
    # no double charge: the in-window recluster is absorbed at zero cost
    assert budget.spent == pytest.approx(budget_one.spent)
    assert budget.vetoes == 0
    # the absorbed swap restarts the migration clock on the new target
    # but does not pay for a second window
    assert res.reconfig_times == [15.0, 17.0]
    # edge mapping pinned: routing sees the twice-shrunk topology and
    # every device maps to a live edge of it
    assert cosim.proc.topo.n_edges == 2
    assert set(np.unique(cosim.proc.topo.assign)) <= set(
        cosim.proc.topo.open_edges)
    # requests keep flowing after both swaps (no orphaned edge ids)
    assert res.log.t.size > 0 and res.log.t.max() > 17.0
