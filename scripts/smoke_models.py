"""Quick manual smoke: every reduced arch does forward + loss + decode."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs
from repro.models import make_model

SEQ = 64


def batch_for(cfg, B=2, S=SEQ):
    m = cfg.model
    rng = np.random.default_rng(0)
    if m.family == "rnn":
        return {"windows": jnp.asarray(rng.normal(size=(B, 12, 1)),
                                       jnp.float32),
                "targets": jnp.asarray(rng.normal(size=(B, 1)), jnp.float32)}
    batch = {
        "tokens": jnp.asarray(rng.integers(0, m.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, m.vocab_size, (B, S)),
                              jnp.int32),
    }
    if m.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, m.frontend.num_positions, m.d_model)) * 0.02,
            jnp.bfloat16)
    if m.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, m.frontend.num_positions, m.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


def main():
    names = sys.argv[1:] or None
    for name, full in all_configs(include_paper_model=True).items():
        if names and name not in names:
            continue
        cfg = full.reduced()
        api = make_model(cfg)
        params, axes = api.init_params(jax.random.key(0))
        batch = batch_for(cfg)
        loss = api.loss(params, batch)
        assert jnp.isfinite(loss), (name, loss)
        line = f"{name:24s} loss={float(loss):8.4f}"
        if cfg.model.family != "rnn":
            cache = api.init_cache(2, 128)
            tok = batch["tokens"][:, :1]
            kw = {}
            if cfg.model.family == "vlm":
                kw["extra_embeds"] = None
            logits, cache = api.decode_step(params, tok, jnp.int32(0), cache)
            assert np.isfinite(np.asarray(logits, np.float32)).all(), name
            line += f" decode_logits={tuple(logits.shape)}"
        print(line)


if __name__ == "__main__":
    main()
