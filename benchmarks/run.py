"""Benchmark entry point — one section per paper table/figure, printing
``name,us_per_call,derived`` CSV lines.

Default mode is the fast sweep (minutes on this 2-core container); the
full-scale curves are behind per-module CLIs:

  python -m benchmarks.fig6_continual_fl --rounds 100    # full Fig. 6
  python -m benchmarks.fig2_solver_scaling --scale       # 10^5-10^6 curve
  python -m repro.launch.dryrun                          # 68-combo sweep
  python -m benchmarks.roofline_report                   # tables from it
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full-scale figure reproductions (slow)")
    ap.add_argument("--skip-fig6", action="store_true",
                    help="skip the training benchmark (longest section)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast co-sim smoke only (CI entry: exercises the "
                         "event core + reactive loop in seconds)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write every emitted row to PATH as JSON "
                         "(name -> us_per_call + derived fields, incl. "
                         "the event-engine requests/sec) — the perf "
                         "trajectory artifact CI uploads; rows come out "
                         "of the benchmark telemetry registry")
    ap.add_argument("--prom", metavar="PATH", default=None,
                    help="also write the benchmark telemetry registry "
                         "as Prometheus text exposition to PATH")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")

    if args.smoke:
        print("# --- co-sim interference smoke ---", file=sys.stderr)
        from benchmarks import perf_cosim_interference
        perf_cosim_interference.run(duration_s=60.0)
        print("# --- scenario suite smoke (stragglers / mobility / "
              "multi-tenant / budget), grid over 2 workers ---",
              file=sys.stderr)
        from benchmarks import perf_scenarios
        perf_scenarios.run(duration_s=60.0, jobs=2)
        print("# --- event-engine throughput smoke (batched vs heap, "
              "constant + calibrated) ---", file=sys.stderr)
        from benchmarks import perf_event_throughput
        perf_event_throughput.run(duration_s=240.0, parity_duration_s=45.0,
                                  calibrated_duration_s=60.0,
                                  calibrated_rate_scale=50.0)
        print("# --- decomposed-solver smoke (10^5 devices + exact-gap "
              "subsamples, BENCH_solver.json) ---", file=sys.stderr)
        from benchmarks import fig2_solver_scaling
        fig2_solver_scaling.run_decomposed(sizes=((100_000, 200),),
                                           sub_seeds=2)
        print("# --- paged-vs-dense serving smoke (concurrency at equal "
              "cache HBM + step time, BENCH_serving.json) ---",
              file=sys.stderr)
        from benchmarks import perf_decode_cache
        perf_decode_cache.run_paged(out="BENCH_serving.json")
        print("# --- fault-domain chaos smoke (availability, recovery, "
              "failover gate) ---", file=sys.stderr)
        from benchmarks import perf_faults
        perf_faults.run(duration_s=40.0)
        _maybe_write_json(args.json)
        _maybe_write_prom(args.prom)
        return

    print("# --- Fig. 2: HFLOP solver scaling ---", file=sys.stderr)
    from benchmarks import fig2_solver_scaling
    if args.full:
        fig2_solver_scaling.run()
    else:
        fig2_solver_scaling.run(sizes=((10, 3), (20, 4)), seeds=2,
                                time_limit=30.0,
                                heur_sizes=((500, 20), (10000, 100)))

    print("# --- Fig. 7: inference response times ---", file=sys.stderr)
    from benchmarks import fig7_inference_latency
    fig7_inference_latency.run(duration_s=240.0 if args.full else 120.0)

    print("# --- Fig. 8: latency vs compute speedup ---", file=sys.stderr)
    from benchmarks import fig8_speedup
    fig8_speedup.run(duration_s=120.0 if args.full else 45.0)

    print("# --- Fig. 9: communication-cost savings ---", file=sys.stderr)
    from benchmarks import fig9_cost_savings
    if args.full:
        fig9_cost_savings.run()
    else:
        fig9_cost_savings.run(n=100, densities=(2, 5, 10, 20), seeds=2)
    fig9_cost_savings.usecase_volumes()

    if not args.skip_fig6:
        print("# --- Fig. 6: continual hierarchical FL ---", file=sys.stderr)
        from benchmarks import fig6_continual_fl
        rounds = 40 if args.full else 6
        fig6_continual_fl.run(rounds=rounds, max_batches=20)
        fig6_continual_fl.run_continual_vs_static(
            rounds=12 if args.full else 4)

    print("# --- event-engine throughput (batched vs heap) ---",
          file=sys.stderr)
    from benchmarks import perf_event_throughput
    perf_event_throughput.run(duration_s=600.0 if args.full else 240.0)

    print("# --- co-sim: training-inference interference ---",
          file=sys.stderr)
    from benchmarks import perf_cosim_interference
    perf_cosim_interference.run(duration_s=240.0 if args.full else 90.0)

    print("# --- scenario suite: stragglers / mobility / multi-tenant / "
          "budget ---", file=sys.stderr)
    from benchmarks import perf_scenarios
    perf_scenarios.run(duration_s=120.0 if args.full else 60.0,
                       check_determinism=args.full)

    print("# --- fault-domain chaos: availability, recovery, failover "
          "gate ---", file=sys.stderr)
    from benchmarks import perf_faults
    perf_faults.run(duration_s=60.0 if args.full else 40.0)

    print("# --- tiered serving subsystem ---", file=sys.stderr)
    from benchmarks import perf_serving_scheduler
    perf_serving_scheduler.report(out="")

    print("# --- Pallas kernels (interpret mode) ---", file=sys.stderr)
    from benchmarks import kernels_bench
    kernels_bench.run()

    print("# --- Roofline summary (from dry-run artifacts) ---",
          file=sys.stderr)
    try:
        from benchmarks import roofline_report
        recs = roofline_report.load()
        s = roofline_report.summarize(recs)
        from benchmarks.common import emit
        emit("dryrun_combos_ok", s["ok"],
             f"ok={s['ok']}/{s['total']};dominant="
             + ";".join(f"{k}:{len(v)}" for k, v in s["dominant"].items()))
    except Exception as e:  # noqa: BLE001
        print(f"# roofline summary unavailable: {e}", file=sys.stderr)

    _maybe_write_json(args.json)
    _maybe_write_prom(args.prom)


def _maybe_write_json(path) -> None:
    if path:
        from benchmarks.common import write_json
        write_json(path)
        print(f"# wrote {path}", file=sys.stderr)


def _maybe_write_prom(path) -> None:
    if path:
        from benchmarks.common import TELEMETRY
        with open(path, "w") as f:
            f.write(TELEMETRY.to_prometheus())
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
