"""TPU-native hierarchical aggregation (DESIGN.md §3 mapping).

On the production mesh, an FL *cluster* is a pod (multi-pod mesh) or a
"cluster" sub-axis of the single-pod mesh.  Cluster-local model replicas
are expressed as a leading ``cluster`` dimension on every parameter,
sharded over that mesh axis; local training is ``vmap``-ed over it so XLA
emits NO cross-cluster collectives for local rounds.  The global
aggregation round is a mean over the leading dim — one all-reduce over
the expensive ("pod") axis, paid only every ``l`` rounds, exactly the
paper's cost amortization.

``hierarchical_allreduce`` additionally exposes the raw shard_map/psum
formulation used by the roofline benchmarks.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def stack_for_clusters(params: PyTree, n_clusters: int) -> PyTree:
    """Replicate params with a leading cluster dim (divergent replicas)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clusters,) + x.shape), params)


def cluster_slice(stacked: PyTree, k: int) -> PyTree:
    return jax.tree.map(lambda x: x[k], stacked)


def global_sync(stacked: PyTree,
                weights: Optional[jax.Array] = None) -> PyTree:
    """Global aggregation round: weighted mean over the cluster dim,
    broadcast back.  Under jit on the multi-pod mesh this lowers to ONE
    all-reduce over the "pod" axis per tensor."""
    def sync(x):
        if weights is None:
            m = jnp.mean(x.astype(jnp.float32), axis=0)
        else:
            w = (weights / jnp.sum(weights)).astype(jnp.float32)
            m = jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0))
        return jnp.broadcast_to(m.astype(x.dtype)[None], x.shape)

    return jax.tree.map(sync, stacked)


def cluster_divergence(stacked: PyTree) -> jax.Array:
    """Max abs deviation of any cluster replica from the mean — a
    monitoring metric for how far clusters drifted between global rounds."""
    def dev(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.max(jnp.abs(x.astype(jnp.float32) - m))

    leaves = [dev(x) for x in jax.tree.leaves(stacked)]
    return jnp.max(jnp.stack(leaves))


def global_sync_shardmap(stacked: PyTree, mesh, axis: str = "cluster"
                         ) -> PyTree:
    """global_sync with the cluster axis under *manual* partitioning
    (shard_map).  GSPMD under vmap may insert cross-cluster weight
    all-gathers (measured on the 2x16x16 mesh — see EXPERIMENTS.md §Perf
    exp. 3 iteration 1); manual mode makes cluster locality structural:
    the ONLY cross-cluster traffic is this psum."""
    n = mesh.shape[axis]

    def body(local):                     # leaves: (1, ...) local slices
        def one(x):
            s = jax.lax.psum(x.astype(jnp.float32), axis) / n
            return s.astype(x.dtype)
        return jax.tree.map(one, local)

    return jax.shard_map(body, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis), axis_names={axis},
                         check_vma=False)(stacked)


def make_hfl_local_step_shardmap(base_step, mesh, axis: str = "cluster"):
    """Wrap a (params, opt, batch) -> (params, opt, loss) step so each
    cluster runs it on its own replica with NO cross-cluster collectives
    (manual shard_map over the cluster axis; data/model stay auto)."""
    def stepped(stacked_params, stacked_opt, stacked_batch):
        def body(p, o, b):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            ex = lambda t: jax.tree.map(lambda x: x[None], t)
            np_, no_, loss = base_step(sq(p), sq(o), sq(b))
            return ex(np_), ex(no_), loss[None]

        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(axis)),
            axis_names={axis}, check_vma=False,
        )(stacked_params, stacked_opt, stacked_batch)

    return stepped


# ---------------------------------------------------------------------------
# raw shard_map formulation (roofline benchmarks, README examples)
# ---------------------------------------------------------------------------

def hierarchical_allreduce(x: jax.Array, mesh,
                           local_axis: str = "data",
                           global_axis: Optional[str] = "pod",
                           do_global: bool = True) -> jax.Array:
    """Mean-reduce ``x`` first over the cheap intra-pod axis, then
    (optionally) over the expensive cross-pod axis.  x must be sharded
    (local_axis?, ...) ; returns the reduced value replicated over the
    reduced axes."""
    axes = (local_axis,) + ((global_axis,) if (global_axis and do_global)
                            else ())

    def body(xs):
        total = jax.lax.psum(xs, local_axis)
        size = mesh.shape[local_axis]
        if global_axis and do_global:
            total = jax.lax.psum(total, global_axis)
            size *= mesh.shape[global_axis]
        return total / size

    in_spec = P(axes)          # dim 0 co-sharded over every reduce axis
    return jax.shard_map(body, mesh=mesh, in_specs=(in_spec,),
                         out_specs=P())(x)


def flat_allreduce(x: jax.Array, mesh) -> jax.Array:
    """The centralized-FL baseline: one flat reduction over every
    aggregation axis at once."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    def body(xs):
        total = xs
        for a in axes:
            total = jax.lax.psum(total, a)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return total / size

    return jax.shard_map(body, mesh=mesh, in_specs=(P(axes),),
                         out_specs=P())(x)
