"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, 2 shared + 64 routed top-6.

27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400.
[arXiv:2405.04434]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, MLAConfig,
                                ModelConfig, MoEConfig, RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        source="arXiv:2405.04434",
        num_layers=27,
        d_model=2048,
        d_ff=1408,                  # routed-expert FFN size
        vocab_size=102_400,
        attention=AttentionConfig(
            kind="mla",
            num_heads=16,
            num_kv_heads=16,
            head_dim=128,
            rope_theta=10_000.0,
            mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                          qk_nope_head_dim=128, qk_rope_head_dim=64,
                          v_head_dim=128),
        ),
        moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_expert=1408,
                      d_shared=2816, first_dense_layers=1, dense_d_ff=10_944,
                      aux_loss_coef=0.001),
    ),
    run=RunConfig(microbatches=2, remat="layer"),
)
