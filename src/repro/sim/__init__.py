"""Training–inference co-simulation subsystem.

The event core is imported eagerly; the co-sim engine and reactive loop
are lazy (PEP 562) because they import ``repro.routing.simulator``,
which itself builds on ``repro.sim.events`` — eager imports here would
close that cycle.
"""
import importlib

from repro.sim.events import (EVENT_EFFECTS, Event, EventEffect, EventKind,
                              EventQueue, Simulation, control_trace)

_LAZY = {
    "CoSim": "repro.sim.cosim",
    "CoSimConfig": "repro.sim.cosim",
    "CoSimResult": "repro.sim.cosim",
    "ColumnarLog": "repro.sim.request_plane",
    "bucket_admissions": "repro.sim.request_plane",
    "occupancy_replay": "repro.sim.request_plane",
    "InterferenceConfig": "repro.sim.interference",
    "InterferenceModel": "repro.sim.interference",
    "AccuracyModel": "repro.sim.reactive",
    "ReactiveLoop": "repro.sim.reactive",
    "ReactivePolicy": "repro.sim.reactive",
    "BudgetEntry": "repro.sim.budget",
    "ReconfigBudget": "repro.sim.budget",
    "SCENARIOS": "repro.sim.scenarios",
    "Scenario": "repro.sim.scenarios",
    "ScenarioResult": "repro.sim.scenarios",
    "run_scenario": "repro.sim.scenarios",
    "run_grid": "repro.sim.scenarios",
}

__all__ = ["EVENT_EFFECTS", "Event", "EventEffect", "EventKind",
           "EventQueue", "Simulation", "control_trace"] + list(_LAZY)


def __getattr__(name):
    module = _LAZY.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(module), name)
