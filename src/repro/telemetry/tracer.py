"""Span tracer over the control plane, exporting Chrome/Perfetto JSON.

Spans live in one of two clock domains:

- ``sim`` — simulated seconds (co-sim event times): rounds, epochs,
  aggregation windows, deployment-swap migration windows.  Opened and
  closed with explicit event times via :meth:`SpanTracer.open` /
  :meth:`SpanTracer.close` (keyed, so interleaved rounds across
  subtrees nest correctly), or recorded whole via
  :meth:`SpanTracer.complete` when the duration is known up front.
- ``wall`` — real ``time.perf_counter`` seconds: solver phases,
  serving-engine admit/measure.  Recorded with the
  :meth:`SpanTracer.wall` context manager.

Exports: :meth:`to_chrome` emits the Chrome trace-event format that
Perfetto / ``chrome://tracing`` load directly (complete events
``ph:"X"``, instants ``ph:"i"``, microsecond timestamps; the two clock
domains map to two pids with ``process_name`` metadata so they get
separate tracks).  :meth:`write_jsonl` dumps one span per line for
ad-hoc grepping.

Like the rest of `repro.telemetry`, the tracer never draws randomness
or schedules events — instrumented code calls it from inside existing
handlers only, so event ordering and control fingerprints are
bit-identical with tracing on or off.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Hashable, Iterator, List, Optional

_PID = {"sim": 1, "wall": 2}


def wall_clock() -> float:
    """The repo's one audited wall-clock read (``time.perf_counter``).

    Solver/control-path code that legitimately measures real elapsed
    time (``HFLOPSolution.wall_time_s``, the MILP time limit,
    ``Deployment.created_at``) calls this seam instead of the ``time``
    module directly: the determinism contract (DET002, see
    CONTRACTS.md) forbids raw wall-clock reads in sim/control/solver
    paths, so every remaining read is greppable here and never leaks
    into event ordering, routing decisions, or RNG streams."""
    return time.perf_counter()


@dataclass
class Span:
    """One closed interval.  ``t0``/``dur`` are seconds in the span's
    clock domain (sim time or wall time relative to tracer creation)."""

    name: str
    t0: float
    dur: float
    cat: str = ""
    tid: int = 0
    domain: str = "sim"
    args: Dict[str, object] = field(default_factory=dict)


@dataclass
class Instant:
    name: str
    t: float
    cat: str = ""
    tid: int = 0
    domain: str = "sim"
    args: Dict[str, object] = field(default_factory=dict)


class SpanTracer:
    def __init__(self) -> None:
        self.spans: List[Span] = []
        self.instants: List[Instant] = []
        self._open: Dict[Hashable, Span] = {}
        self._wall0 = time.perf_counter()

    # -- sim-time spans (explicit event times) -------------------------
    def open(self, key: Hashable, name: str, t: float, cat: str = "",
             tid: int = 0, **args) -> None:
        """Start a keyed sim-time span at event time ``t``.  Re-opening
        a live key abandons the previous (never-closed) span."""
        self._open[key] = Span(name=name, t0=float(t), dur=-1.0, cat=cat,
                               tid=tid, domain="sim", args=dict(args))

    def close(self, key: Hashable, t: float, **args) -> Optional[Span]:
        """Close a keyed span at event time ``t``; unknown keys are
        ignored (e.g. the epoch was cancelled before it started)."""
        sp = self._open.pop(key, None)
        if sp is None:
            return None
        sp.dur = float(t) - sp.t0
        if args:
            sp.args.update(args)
        self.spans.append(sp)
        return sp

    def complete(self, name: str, t: float, dur: float, cat: str = "",
                 tid: int = 0, domain: str = "sim", **args) -> Span:
        """Record a span whose duration is already known (e.g. a
        deployment-swap migration window of length ``reconfig_s``)."""
        sp = Span(name=name, t0=float(t), dur=float(dur), cat=cat,
                  tid=tid, domain=domain, args=dict(args))
        self.spans.append(sp)
        return sp

    def instant(self, name: str, t: float, cat: str = "", tid: int = 0,
                domain: str = "sim", **args) -> None:
        self.instants.append(Instant(name=name, t=float(t), cat=cat,
                                     tid=tid, domain=domain,
                                     args=dict(args)))

    # -- wall-time spans ------------------------------------------------
    @contextmanager
    def wall(self, name: str, cat: str = "", tid: int = 0,
             **args) -> Iterator[Span]:
        """Time a code block on the wall clock; yields the Span so the
        caller can read ``.dur`` afterwards (solver phase view)."""
        sp = Span(name=name, t0=time.perf_counter() - self._wall0,
                  dur=-1.0, cat=cat, tid=tid, domain="wall",
                  args=dict(args))
        try:
            yield sp
        finally:
            sp.dur = (time.perf_counter() - self._wall0) - sp.t0
            self.spans.append(sp)

    # -- queries ---------------------------------------------------------
    def durations(self, prefix: str = "") -> Dict[str, float]:
        """Total duration per span name, filtered by (and stripped of)
        ``prefix`` — e.g. ``durations("solve_decomposed.")`` returns
        ``{"partition": 0.12, ...}``."""
        out: Dict[str, float] = {}
        for sp in self.spans:
            if sp.name.startswith(prefix):
                k = sp.name[len(prefix):]
                out[k] = out.get(k, 0.0) + sp.dur
        return out

    def by_cat(self, cat: str) -> List[Span]:
        return [sp for sp in self.spans if sp.cat == cat]

    # -- exports ---------------------------------------------------------
    def to_chrome(self) -> List[Dict[str, object]]:
        """Chrome trace-event list (load the written file directly in
        Perfetto or chrome://tracing).  Sim time and wall time become
        separate processes; still-open spans are omitted."""
        events: List[Dict[str, object]] = [
            {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
             "args": {"name": f"{dom}-time"}}
            for dom, pid in _PID.items()]
        for sp in self.spans:
            events.append({
                "name": sp.name, "cat": sp.cat or "span", "ph": "X",
                "ts": sp.t0 * 1e6, "dur": max(sp.dur, 0.0) * 1e6,
                "pid": _PID[sp.domain], "tid": sp.tid,
                "args": dict(sp.args)})
        for ins in self.instants:
            events.append({
                "name": ins.name, "cat": ins.cat or "event", "ph": "i",
                "ts": ins.t * 1e6, "pid": _PID[ins.domain],
                "tid": ins.tid, "s": "t", "args": dict(ins.args)})
        events.sort(key=lambda e: (e["ph"] == "M" and -1.0 or e["ts"],
                                   e["pid"], e["tid"]))
        return events

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.to_chrome(),
                       "displayTimeUnit": "ms"}, f)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for sp in self.spans:
                f.write(json.dumps({
                    "kind": "span", "name": sp.name, "cat": sp.cat,
                    "t0": sp.t0, "dur": sp.dur, "tid": sp.tid,
                    "domain": sp.domain, "args": sp.args}) + "\n")
            for ins in self.instants:
                f.write(json.dumps({
                    "kind": "instant", "name": ins.name, "cat": ins.cat,
                    "t": ins.t, "tid": ins.tid, "domain": ins.domain,
                    "args": ins.args}) + "\n")
