"""§Perf hillclimb 2 (most collective-bound pair): xlstm-125m x
prefill_32k — the ONLY combo whose dominant roofline term is the
collective one (66M params: per-layer FSDP all-gathers + TP all-reduces
cost more than the compute they enable).

  it0  baseline: FSDP over "data" + TP over "model"
  it1  kill FSDP: replicate weights over "data" (132 MB/device is cheap)
  it2  kill TP too: pure data-parallel — batch over data x model,
       weights fully replicated; prefill has no grad sync, so the
       collective term should approach ZERO.

Validated by the HLO collective schedule of each lowering."""
from __future__ import annotations

import argparse
import json
import os


import repro.launch.dryrun  # noqa: F401
from repro.configs import INPUT_SHAPES, get_config
from repro.launch import shardings as sh
from repro.launch.analytic import analytic_roofline
from repro.launch.dryrun import build_programs
from repro.launch.mesh import ICI_BW_PER_LINK, make_production_mesh
from repro.launch.roofline import collective_stats


def lower_with(arch: str, shape: str, overrides):
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(arch)
    rules = sh.rules_for(cfg, mesh, overrides=overrides)
    fn, inputs = build_programs(arch, shape, mesh, rules)
    compiled = fn.lower(*inputs).compile()
    return collective_stats(compiled.as_text())


def measured_prefill(arch: str, prompt_len: int = 128) -> dict:
    """Wall-clock prefill timings from the tiered ReplicaPool on the CPU
    host (reduced config) — the measured counterpart of the analytic
    roofline above, and the calibration source for the routing
    simulator's calibrated latency mode."""
    from repro.serving import ReplicaPool, lm_tiers
    pool = ReplicaPool(lm_tiers(arch, max_len=2 * prompt_len))
    meas = pool.measure(prompt_len=prompt_len, decode_steps=2)
    out = {}
    for tier, m in meas.items():
        print(f"measured[{tier:6s}]: prefill={m.prefill_ms:8.2f} ms "
              f"({prompt_len} tokens, one-shot)  slots={m.batch_size}")
        out[tier] = {"prefill_ms": m.prefill_ms,
                     "batch_size": m.batch_size}
    return out


def report(arch="xlstm-125m", shape="prefill_32k", out="", measure=False):
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape]
    ana = analytic_roofline(cfg, shp, mesh)
    print(f"=== {arch} x {shape} on 16x16 ===")
    print(f"analytic baseline: compute={ana.compute_s:.2e} "
          f"collective={ana.collective_s:.2e} dominant={ana.dominant}")
    results = {}
    iterations = [
        ("it0_fsdp_tp", ()),
        ("it1_replicated_weights", (("embed", ()),)),
        ("it2_pure_dp", (("embed", ()), ("mlp", ()), ("heads", ()),
                         ("kv_heads", ()), ("vocab", ()),
                         ("mlp_act", ()), ("embed_act", ()),
                         ("heads_act", ()), ("vocab_act", ()),
                         ("batch", ("data", "model")))),
    ]
    prev = None
    for name, ov in iterations:
        st = lower_with(arch, shape, ov)
        coll_s = st.total_bytes / ICI_BW_PER_LINK
        line = (f"{name:24s}: coll_bytes/dev={st.total_bytes:.3e} "
                f"(~{coll_s:.2e}s)  ops={st.count_by_kind}")
        if prev:
            line += f"  [{prev / max(st.total_bytes, 1):.1f}x fewer bytes]"
        print(line)
        results[name] = {"bytes": st.total_bytes, "counts": st.count_by_kind,
                         "coll_s": coll_s}
        prev = st.total_bytes
    if measure:
        results["measured"] = measured_prefill(arch)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--shape", default="prefill_32k")
    ap.add_argument("--out", default="results/perf_prefill_sharding.json")
    ap.add_argument("--measure", action="store_true",
                    help="also time the real tiered engines (ReplicaPool)")
    a = ap.parse_args()
    report(a.arch, a.shape, a.out, measure=a.measure)
