"""Availability under chaos: the fault-domain benchmark.

Runs the outage and domain-outage chaos scenarios against a fault-free
baseline on the same seeded workload and reports, per scenario:

  availability        fraction of arrivals served by the horizon
                      (retries still pending when the run ends are the
                      only requests ever unserved — nothing is lost)
  p95_fault_ms        overall p95 with the chaos plan live
  p95_clean_ms        same workload, no faults
  recovery_s          time from the last crash/partition clearing to
                      the 1 s-windowed p95 re-entering 1.2x the clean
                      p95 (NaN-safe; capped at the horizon)
  retry_amplification serve attempts per arrival (1.0 = no retries)

and the **failover gate**: with tier failover ON (the default
``RetryPolicy``: bounded attempts, then the cloud replica serves under
``R4-failover``) at least half of the p95 degradation a *no-failover*
policy suffers (requests back off until the fault clears) must be
recovered:

  recovered = p95_nofailover - p95_failover
  gate:       recovered >= 0.5 * (p95_nofailover - p95_clean)

  python -m benchmarks.perf_faults             # full (60 s horizon)
  python -m benchmarks.perf_faults --smoke     # fast CI cell (40 s)
"""
from __future__ import annotations

import argparse
import math
from typing import Dict, Optional

import numpy as np

from repro.sim.faults import DOWN_KINDS
from repro.sim.scenarios import SCENARIOS, Scenario, run_scenario
# after scenarios: request_plane is circular when imported first
from repro.sim.request_plane import RetryPolicy

from benchmarks.common import emit

#: never fails over: requests retry with capped backoff until the
#: fault clears — the degradation ceiling the gate measures against
NO_FAILOVER = RetryPolicy(timeout_s=1e9, base_backoff_s=0.05,
                          backoff_cap_s=0.8, max_attempts=1_000_000,
                          jitter=0.5)

RECOVERY_WINDOW_S = 1.0
RECOVERY_CEIL = 1.2                # recovered when p95 <= ceil * clean
GATE_FRACTION = 0.5


def _with_retry(name: str, retry: Optional[RetryPolicy]) -> Scenario:
    """The named chaos scenario with its plan intact but the request
    plane's retry policy overridden (None keeps the default)."""
    base = SCENARIOS[name]()

    def inject(cosim):
        orig = cosim.schedule_faults

        def patched(plan, retry_arg=None, **kw):
            return orig(plan, retry=retry, **kw)

        cosim.schedule_faults = patched
        try:
            base.inject(cosim)
        finally:
            cosim.schedule_faults = orig

    return Scenario(base.name, base.description, inject)


def _capture(scenario: Scenario):
    box: Dict[str, object] = {}

    def inject(cosim):
        box["cosim"] = cosim
        scenario.inject(cosim)

    return Scenario(scenario.name, scenario.description, inject), box


def _down_windows(cosim):
    """(start, end) spans of the crash/partition windows that ran."""
    starts = {}
    spans = []
    for t, what, kind, edges in cosim.fault_log:
        if kind not in DOWN_KINDS:
            continue
        if what == "start":
            starts[(kind, edges)] = t
        else:
            t0 = starts.pop((kind, edges), None)
            if t0 is not None:
                spans.append((t0, t))
    return spans


def _p95_in_windows(log, spans) -> float:
    """p95 latency over requests *in flight during a down window* —
    the p95-under-failure metric.  The log records each request at its
    final serve instant with the backoff wait folded into the latency,
    so a request's span is ``[t - latency, t]``; masking on span
    overlap charges a stranded request to the outage that stranded it
    even though it logs only after the fault clears.  NaN when nothing
    overlapped any window."""
    if not spans:
        return math.nan
    t = np.asarray(log.t)
    lat = np.asarray(log.latency_ms)
    start = t - lat / 1000.0
    mask = np.zeros(t.size, dtype=bool)
    for t0, t1 in spans:
        mask |= (start < t1) & (t >= t0)
    if not mask.any():
        return math.nan
    return float(np.percentile(lat[mask], 95.0))


def _peak_windowed_p95(log) -> float:
    """Worst 1 s-windowed p95 of the run — the operational
    worst-case service level.  Stranded requests all log in a burst
    when their fault clears, so the dump dominates one window no
    matter how small its share of overall traffic: robust where the
    overall p95 dilutes a short outage below the percentile cut."""
    series = log.windowed_percentile(RECOVERY_WINDOW_S, 95.0)
    if series.size == 0:
        return math.nan
    return float(np.nanmax(series[:, 1]))


def _recovery_s(res, cosim, clean_p95: float,
                duration_s: float) -> float:
    """Seconds from the last crash/partition window clearing until the
    windowed p95 re-enters ``RECOVERY_CEIL`` x the clean p95."""
    ends = [t for t, what, kind, _ in cosim.fault_log
            if what == "end" and kind in DOWN_KINDS]
    if not ends:
        return 0.0
    te = max(ends)
    series = res.log.windowed_percentile(RECOVERY_WINDOW_S, 95.0)
    after = series[series[:, 0] >= te]
    good = after[~np.isnan(after[:, 1])]
    good = good[good[:, 1] <= RECOVERY_CEIL * clean_p95]
    if good.size == 0:
        return float(duration_s - te)
    return float(good[0, 0] - te)


def run(duration_s: float = 60.0, seed: int = 0,
        engine: str = "batched") -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    clean = run_scenario(SCENARIOS["baseline"](), policy="static",
                         seed=seed, duration_s=duration_s, engine=engine)
    arrivals = clean.n_requests
    for name in ("outage", "domain_outage"):
        sc, box = _capture(SCENARIOS[name]())
        res = run_scenario(sc, policy="static", seed=seed,
                           duration_s=duration_s, engine=engine)
        cosim = box["cosim"]
        p = cosim.proc
        pending = p.retries_scheduled - p.retries_dispatched
        availability = res.n_requests / arrivals
        amp = (arrivals + p.retries_dispatched) / arrivals
        rec = _recovery_s(res, cosim, clean.p95, duration_s)
        spans = _down_windows(cosim)
        row = dict(availability=availability,
                   p95_fault_ms=res.p95, p95_clean_ms=clean.p95,
                   p95_under_failure_ms=_p95_in_windows(res.log, spans),
                   recovery_s=rec, retry_amplification=amp,
                   fault_attempts=float(p.fault_attempts),
                   drops=float(p.fault_drops),
                   failovers=float(p.failovers),
                   retries_pending=float(pending),
                   standby_promotions=float(cosim.standby_promotions))
        out[name] = row
        emit(f"faults_{name}", res.p95 * 1000,
             ";".join(f"{k}={v:.4g}" for k, v in row.items()))
        # accounting identity (the CI hard gate re-checks this)
        assert res.n_requests + pending == arrivals
        assert p.fault_attempts == p.retries_scheduled + p.failovers

    # failover gate on the outage scenario, measured where it hurts:
    # p95 over requests arriving inside a down window.  Whole-run p95
    # dilutes a few-second outage below the percentile cut.
    sc_nf, box_nf = _capture(_with_retry("outage", NO_FAILOVER))
    nofail = run_scenario(sc_nf, policy="static", seed=seed,
                          duration_s=duration_s, engine=engine)
    sc_f, box_f = _capture(_with_retry("outage", None))
    fail = run_scenario(sc_f, policy="static", seed=seed,
                        duration_s=duration_s, engine=engine)
    p95_nf = _peak_windowed_p95(nofail.log)
    p95_f = _peak_windowed_p95(fail.log)
    p95_c = _peak_windowed_p95(clean.log)
    degradation = p95_nf - p95_c
    recovered = p95_nf - p95_f
    frac = recovered / degradation if degradation > 0 else math.nan
    gate_ok = (not math.isfinite(frac)) or frac >= GATE_FRACTION
    out["failover_gate"] = dict(
        peak_p95_clean_ms=p95_c, peak_p95_nofailover_ms=p95_nf,
        peak_p95_failover_ms=p95_f, recovered_frac=frac,
        gate=1.0 if gate_ok else 0.0)
    emit("faults_failover_gate", frac * 1e6,
         f"recovered_frac={frac:.3f};peak_p95_clean={p95_c:.2f};"
         f"peak_p95_nofailover={p95_nf:.2f};"
         f"peak_p95_failover={p95_f:.2f};"
         f"gate={'pass' if gate_ok else 'FAIL'}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="batched",
                    choices=("batched", "heap"))
    args = ap.parse_args()
    dur = args.duration if args.duration is not None else (
        40.0 if args.smoke else 60.0)
    run(duration_s=dur, seed=args.seed, engine=args.engine)


if __name__ == "__main__":
    main()
