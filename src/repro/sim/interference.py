"""Training–inference interference: per-node compute shared between
training FLOPs and in-flight requests.

Every continuum node (device i, edge j, the cloud) has one normalized
unit of compute.  Training phases claim a share of it — a device
mid-epoch spends ``device_train_share`` on gradient steps, an edge
mid-aggregation spends ``edge_agg_share`` averaging models, the cloud
spends ``cloud_agg_share`` during global rounds — and whatever serving
the node still does time-shares the remainder, so service times stretch
by ``1 / (1 - demand)``.

The base per-tier service time comes from any ``LatencyModel``,
including a :class:`~repro.routing.latency.CalibratedLatencyModel`
built from real engine timings (``ReplicaPool.measure()``), whose
occupancy-dependent slowdown composes multiplicatively with the
training stretch: an edge that is both oversubscribed *and* aggregating
is slow for both reasons.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from repro.routing.latency import LatencyModel
from repro.routing.rules import RouteDecision

NodeKey = Tuple[str, int]            # ("device", i) | ("edge", j) | ("cloud", 0)


@dataclass(frozen=True)
class InterferenceConfig:
    device_train_share: float = 0.85   # compute share of a local epoch
    device_residual_share: float = 0.35  # post-epoch round work (ckpt/prep)
    edge_agg_share: float = 0.6        # share while aggregating uploads
    cloud_agg_share: float = 0.3       # share during a global aggregation
    migration_share: float = 0.5       # share while replicas migrate
    handover_share: float = 0.25       # share on the receiving edge while a
    #                                    moving device hands over
    floor: float = 0.05                # serving never starves below this


class InterferenceModel:
    """Tracks per-node training demand as named components (so an edge
    can simultaneously aggregate *and* host a replica migration) and
    stretches the latency model's service times accordingly."""

    def __init__(self, latency: Optional[LatencyModel] = None,
                 cfg: InterferenceConfig = InterferenceConfig()):
        self.lat = latency if latency is not None else LatencyModel()
        self.cfg = cfg
        self._demand: Dict[NodeKey, Dict[str, float]] = {}

    # -- demand bookkeeping -------------------------------------------------

    def set_demand(self, node: NodeKey, source: str, share: float) -> None:
        comp = self._demand.setdefault(node, {})
        if share <= 0.0:
            comp.pop(source, None)
        else:
            comp[source] = float(share)

    def clear_tier(self, tier: str, source: Optional[str] = None,
                   keep_prefixes: Tuple[str, ...] = ()) -> None:
        """Drop a tier's demand: one named ``source`` everywhere, or all
        sources — except those whose name starts with a ``keep_prefixes``
        entry (external demand like tenant jobs survives a re-deploy
        that rebuilds the training-side components)."""
        for node, comp in self._demand.items():
            if node[0] != tier:
                continue
            if source is not None:
                comp.pop(source, None)
            elif keep_prefixes:
                for k in [k for k in comp if not k.startswith(keep_prefixes)]:
                    comp.pop(k)
            else:
                comp.clear()

    def remap_tier(self, tier: str,
                   remap: Callable[[int], Optional[int]]) -> None:
        """Re-key one tier's demand through ``remap`` (old node id ->
        new id; None drops the node) — used when a re-clustered
        deployment renumbers edges, so demand keeps following its
        physical host."""
        moved: Dict[NodeKey, Dict[str, float]] = {}
        for node in [n for n in self._demand if n[0] == tier]:
            comp = self._demand.pop(node)
            new = remap(node[1])
            if new is None or not comp:
                continue
            moved.setdefault((tier, int(new)), {}).update(comp)
        for node, comp in moved.items():
            self._demand.setdefault(node, {}).update(comp)

    def demand(self, node: NodeKey) -> float:
        total = sum(self._demand.get(node, {}).values())
        return min(total, 1.0 - self.cfg.floor)

    # -- service times ------------------------------------------------------

    def stretch(self, node: NodeKey) -> float:
        """Service-time multiplier from compute time-sharing."""
        return 1.0 / max(1.0 - self.demand(node), self.cfg.floor)

    def stretch_array(self, tier: str, ids: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`stretch` over node ids of one tier — the
        batched request plane's per-window lookup.  Demand components
        live in per-node dicts, so the per-*unique*-node stretch is
        gathered once and broadcast over the (typically much larger)
        request batch."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.ones(0)
        u, inv = np.unique(ids, return_inverse=True)
        vals = np.array([self.stretch((tier, int(k))) for k in u])
        return vals[inv]

    def service_ms_array(self, tier: str, ids: np.ndarray,
                         occupancy=0.0) -> np.ndarray:
        """Vectorized :meth:`service_ms` for one tier: the latency
        model's (possibly occupancy-dependent) base service stretched
        by each serving node's current training demand."""
        ids = np.asarray(ids, dtype=np.int64)
        occupancy = np.broadcast_to(
            np.asarray(occupancy, dtype=np.float64), ids.shape)
        base = self.lat.infer_ms_array(tier, occupancy)
        return base * self.stretch_array(tier, ids)

    def service_ms(self, device: int, dec: RouteDecision,
                   occupancy: int = 0) -> float:
        """Drop-in ``service_fn`` for the request processor: base
        per-tier service (occupancy-aware when calibrated) stretched by
        the serving node's current training demand."""
        base = self.lat.infer_ms(dec.tier, occupancy=occupancy)
        if dec.tier == "edge":
            node: NodeKey = ("edge", int(dec.edge))
        elif dec.tier == "cloud":
            node = ("cloud", 0)
        else:
            node = ("device", int(device))
        return base * self.stretch(node)

    # -- construction from real engine timings ------------------------------

    @classmethod
    def from_measurements(cls, measurements: Mapping[str, object],
                          cfg: InterferenceConfig = InterferenceConfig(),
                          decode_tokens: int = 0,
                          **kwargs) -> "InterferenceModel":
        """Calibrate from ``ReplicaPool.measure()`` output via the
        existing ``LatencyModel.from_measurements`` bridge."""
        lat = LatencyModel.from_measurements(
            measurements, decode_tokens=decode_tokens, **kwargs)
        return cls(latency=lat, cfg=cfg)
