"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q/k/v (BH, T, D) -> (BH, T, Dv)."""
    T = q.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    d = jnp.arange(T)[:, None] - jnp.arange(T)[None, :]
    mask = jnp.ones((T, T), bool)
    if causal:
        mask &= d >= 0
    if window > 0:
        mask &= d < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, valid):
    """q (B,H,D); k/v (B,C,Hkv,D); valid (B,C) -> (B,H,Dv)."""
    B, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bchd->bhgc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, v.shape[-1]).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               *, soft_cap=0.0, window=None):
    """q (B,H,D); k/v_pages (P, ps, Hkv, D); block_tables (B, Pseq) i32;
    lengths (B,) -> (B,H,Dv).  Gathers pages into a contiguous view and
    masks logical token index against length (and the sliding window)."""
    B, H, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Pseq = block_tables.shape[1]
    C = Pseq * ps
    k = k_pages[block_tables].reshape(B, C, Hkv, D)
    v = v_pages[block_tables].reshape(B, C, Hkv, v_pages.shape[-1])
    tok = jnp.arange(C)[None, :]
    valid = tok < lengths[:, None]
    if window is not None:
        valid &= (lengths[:, None] - 1 - tok) < window
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bchd->bhgc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if soft_cap:
        s = jnp.tanh(s / soft_cap) * soft_cap
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, v.shape[-1]).astype(q.dtype)


def paged_mla_decode_attention_ref(q_c, q_rope, ckv_pages, krope_pages,
                                   block_tables, lengths, *, scale):
    """Absorbed-MLA oracle: q_c (B,H,R); q_rope (B,H,Dr); ckv/krope_pages
    (P, ps, R|Dr); -> latent context (B,H,R)."""
    B, H, R = q_c.shape
    ps = ckv_pages.shape[1]
    C = block_tables.shape[1] * ps
    ckv = ckv_pages[block_tables].reshape(B, C, R)
    kr = krope_pages[block_tables].reshape(B, C, krope_pages.shape[-1])
    valid = jnp.arange(C)[None, :] < lengths[:, None]
    s = (jnp.einsum("bhr,bcr->bhc", q_c.astype(jnp.float32),
                    ckv.astype(jnp.float32))
         + jnp.einsum("bhd,bcd->bhc", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhc,bcr->bhr", p,
                      ckv.astype(jnp.float32)).astype(q_c.dtype)


def gru_seq_ref(xw, h0, w_h):
    """Fused-gate GRU over time: xw (B,T,3h) = x@w_x+b precomputed;
    h0 (B,h); w_h (h,3h).  Returns (B,T,h)."""
    def step(h, xt):
        hw = h @ w_h
        xr, xz, xn = jnp.split(xt, 3, axis=-1)
        hr, hz, hn = jnp.split(hw, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        h2 = (1 - z) * n + z * h
        return h2, h2

    _, hs = jax.lax.scan(step, h0, xw.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def mamba_chunk_ref(x, dt, A, Bm, Cm, chunk):
    """Delegates to the model's SSD implementation (the oracle *is* the
    XLA path used by the models)."""
    from repro.models.ssm import ssd_chunked
    y, state = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    return y, state


def fedavg_reduce_ref(stacked, weights):
    """stacked (C, N); weights (C,) -> (N,) weighted average."""
    w = weights / jnp.sum(weights)
    return jnp.einsum("c,cn->n", w.astype(jnp.float32),
                      stacked.astype(jnp.float32)).astype(stacked.dtype)


def topk_router_ref(logits, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (T,E) -> (weights (T,k), idx (T,k)) from softmax probs."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, i = jax.lax.top_k(probs, k)
    return w, i.astype(jnp.int32)
