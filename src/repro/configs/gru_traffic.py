"""gru-traffic — the paper's own model (§V-B1): 2-layer GRU, hidden 128,
univariate traffic-speed regression on METR-LA-style windows.

Serialized size ~594 KB (the paper's communication-cost payload).
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="gru-traffic",
        family="rnn",
        source="paper §V-B (Lackinger et al. 2024)",
        num_layers=0,
        d_model=128,
        d_ff=0,
        vocab_size=0,
        rnn_hidden=128,
        rnn_layers=2,
        attention=AttentionConfig(kind="none"),
        dtype="float32",
        param_dtype="float32",
    ),
    run=RunConfig(microbatches=1, remat="none", scan_layers=False,
                  learning_rate=1e-4, local_rounds_per_global=2,
                  local_epochs=5),
)
