"""Checkpointing: flat-key npz save/restore for arbitrary pytrees.

No orbax in the container; this is a self-contained sharding-oblivious
host checkpointer (arrays are gathered to host before saving)."""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((k,))) for k in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: PyTree) -> None:
    flat, _ = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **flat)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_like, treedef = _flatten(like)
    if set(data.files) != set(flat_like):
        missing = set(flat_like) - set(data.files)
        extra = set(data.files) - set(flat_like)
        raise ValueError(f"checkpoint mismatch: missing={missing} "
                         f"extra={extra}")
    leaves_like, td = jax.tree_util.tree_flatten(like)
    paths, _ = zip(*jax.tree_util.tree_flatten_with_path(like)[0])
    keys = [_SEP.join(str(jax.tree_util.keystr((k,))) for k in p)
            for p in paths]
    new_leaves = [jax.numpy.asarray(data[k]).astype(l.dtype)
                  for k, l in zip(keys, leaves_like)]
    return jax.tree_util.tree_unflatten(td, new_leaves)
