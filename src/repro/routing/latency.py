"""Latency model for inference serving (paper §V-C1).

The paper measured HTTP round-trip times: cloud 50-100 ms, edge 8-10 ms.
Processing time is the model's inference time, scaled per serving tier:
Fig. 8 sweeps a "theoretical speedup of up to 95%" of cloud vs edge
compute, i.e. cloud_infer = edge_infer * (1 - speedup)."""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LatencyModel:
    edge_rtt_ms: tuple = (8.0, 10.0)       # uniform, paper §V-C1
    cloud_rtt_ms: tuple = (50.0, 100.0)    # uniform, paper §V-C1
    device_rtt_ms: tuple = (0.0, 0.0)      # on-device serving: no network
    base_infer_ms: float = 2.0             # GRU forward on an edge host
    cloud_speedup: float = 0.0             # Fig. 8: 0..0.95
    device_slowdown: float = 2.0           # devices slower than edge hosts

    def rtt(self, tier: str, rng: np.random.Generator,
            size=None) -> np.ndarray:
        lo, hi = {"device": self.device_rtt_ms,
                  "edge": self.edge_rtt_ms,
                  "cloud": self.cloud_rtt_ms}[tier]
        return rng.uniform(lo, hi, size)

    def infer_ms(self, tier: str) -> float:
        if tier == "cloud":
            return self.base_infer_ms * (1.0 - self.cloud_speedup)
        if tier == "device":
            return self.base_infer_ms * self.device_slowdown
        return self.base_infer_ms

    def forward_hop_ms(self, rng: np.random.Generator) -> float:
        """Edge->cloud forwarding hop (R3 overflow): the request pays the
        edge leg plus the cloud leg."""
        return float(self.rtt("cloud", rng))
