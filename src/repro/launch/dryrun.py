import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: ``.lower().compile()`` every (architecture x input
shape) on the production meshes with 512 placeholder host devices, then
record memory_analysis / cost_analysis / collective schedule for the
roofline report (EXPERIMENTS.md §Dry-run / §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch llama3-405b --shape train_4k --mesh single,multi

Results are cached as JSON under --out (default results/dryrun); reruns
skip cached combos unless --force.
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import INPUT_SHAPES, applicable_shapes, get_config
from repro.configs.registry import ASSIGNED
from repro.launch import shardings as sh
from repro.launch.analytic import activation_peak_bytes, analytic_roofline
from repro.launch.mesh import HBM_BYTES, make_production_mesh
from repro.launch.roofline import analyze, model_flops_for
from repro.launch.specs import (cache_specs, decode_token_specs,
                                model_batch_specs, param_specs_and_axes)
from repro.models import make_model
from repro.models.common import logical_sharding
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def _replicated(mesh):
    return sh.replicated(mesh)


def build_programs(arch: str, shape_name: str, mesh, rules,
                   mode_override: Optional[str] = None):
    """Returns (jitted fn, example inputs tuple) for the combo."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mode = mode_override or shape.mode
    api = make_model(cfg)
    p_struct, axes = param_specs_and_axes(api)
    p_sh = sh.params_shardings(axes, p_struct, mesh, rules)

    if mode == "train":
        opt = AdamW(lr=cfg.run.learning_rate,
                    state_dtype=cfg.run.opt_state_dtype)
        opt_struct = jax.eval_shape(opt.init, p_struct)
        opt_sh = type(opt_struct)(step=_replicated(mesh), m=p_sh, v=p_sh)
        batch = model_batch_specs(cfg, shape, with_labels=True)
        b_sh = sh.batch_shardings(batch, mesh, rules)
        step = make_train_step(api, cfg, opt)

        def wrapped(params, opt_state, b):
            with logical_sharding(mesh, rules):
                return step(params, opt_state, b)

        fn = jax.jit(wrapped,
                     in_shardings=(p_sh, opt_sh, b_sh),
                     out_shardings=(p_sh, opt_sh, _replicated(mesh)),
                     donate_argnums=(0, 1))
        return fn, (p_struct, opt_struct, batch)

    if mode == "prefill":
        batch = model_batch_specs(cfg, shape, with_labels=False)
        b_sh = sh.batch_shardings(batch, mesh, rules)

        def prefill(params, b):
            with logical_sharding(mesh, rules):
                logits, _ = api.forward(params, b)
                return logits

        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        return fn, (p_struct, batch)

    # decode
    cache_struct = cache_specs(api, shape.global_batch, shape.seq_len)
    c_sh = sh.cache_shardings(cache_struct, mesh, rules)
    tok, pos = decode_token_specs(cfg, shape)
    t_sh = sh.batch_shardings({"tokens": tok}, mesh, rules)["tokens"]

    def decode(params, tokens, p, cache):
        with logical_sharding(mesh, rules):
            return api.decode_step(params, tokens, p, cache)

    fn = jax.jit(decode, in_shardings=(p_sh, t_sh, _replicated(mesh), c_sh),
                 out_shardings=(None, c_sh), donate_argnums=(3,))
    return fn, (p_struct, tok, pos, cache_struct)


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              rules_overrides=()) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    rules = sh.rules_for(cfg, mesh, overrides=rules_overrides
                         or cfg.run.sharding_overrides)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(mesh.devices.size),
    }
    t0 = time.perf_counter()
    fn, inputs = build_programs(arch, shape_name, mesh, rules)
    lowered = fn.lower(*inputs)
    rec["lower_s"] = time.perf_counter() - t0
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = time.perf_counter() - t1
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or
                              getattr(ma, "temp_size_in_bytes", 0)),
        }
        # Per-device live footprint: resident arguments (params/opt/cache;
        # outputs alias them via donate_argnums) + analytic activation
        # high-water mark.  XLA-CPU's temp_size is arena-total without
        # liveness and its peak metric mirrors argument size, so the
        # activation transient is estimated analytically (analytic.py).
        args_b = rec["memory"]["argument_bytes"]
        act_b = activation_peak_bytes(get_config(arch),
                                      INPUT_SHAPES[shape_name], mesh)
        rec["memory"]["activation_peak_bytes_analytic"] = act_b
        live = args_b + act_b
        rec["memory"]["fits_hbm"] = bool(live <= HBM_BYTES)
        rec["memory"]["hbm_fraction"] = live / HBM_BYTES
    except Exception as e:  # noqa: BLE001
        rec["memory"] = {"error": repr(e)}
    shape = INPUT_SHAPES[shape_name]
    roof = analyze(compiled, mesh, model_flops_for(cfg, shape),
                   multi_pod=multi_pod)
    rec["roofline"] = roof.as_dict()
    ana = analytic_roofline(cfg, shape, mesh)
    rec["analytic"] = ana.as_dict()
    rec["analytic"]["mfu_upper_bound"] = ana.mfu(
        model_flops_for(cfg, shape) / mesh.devices.size)
    rec["ok"] = True
    return rec


def combos(arch_filter=None, shape_filter=None):
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            if arch_filter and arch not in arch_filter:
                continue
            if shape_filter and shape.name not in shape_filter:
                continue
            yield arch, shape.name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="", help="comma-separated filter")
    ap.add_argument("--shape", default="", help="comma-separated filter")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    arch_f = set(args.arch.split(",")) if args.arch else None
    shape_f = set(args.shape.split(",")) if args.shape else None
    meshes = args.mesh.split(",")

    results = []
    for arch, shape in combos(arch_f, shape_f):
        for mesh_kind in meshes:
            multi = mesh_kind == "multi"
            tag = f"{arch}__{shape}__{'2x16x16' if multi else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    results.append(json.load(f))
                print(f"[cached] {tag}")
                continue
            print(f"[lower+compile] {tag} ...", flush=True)
            try:
                rec = run_combo(arch, shape, multi)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if multi else "16x16",
                       "ok": False, "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(traceback.format_exc())
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            results.append(rec)
            status = "OK" if rec.get("ok") else "FAIL"
            r = rec.get("roofline", {})
            print(f"  {status} compile={rec.get('compile_s', 0):.1f}s "
                  f"dominant={r.get('dominant', '?')} "
                  f"compute={r.get('compute_s', 0):.2e}s "
                  f"coll={r.get('collective_s', 0):.2e}s")
    ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{ok}/{len(results)} combos lowered+compiled")


if __name__ == "__main__":
    main()
