"""DET003 suppressed fixture: sanctioned fresh stream."""
import numpy as np


def windows(mttf_s, duration_s, seed=0):
    rng = np.random.default_rng(seed)  # contract: ok DET003
    return [float(rng.exponential(mttf_s))]
