"""DET002 bad fixture: wall-clock reads in a sim path."""
import time
from dataclasses import dataclass, field
from datetime import datetime


def stamp():
    t0 = time.perf_counter()
    now = datetime.now()
    return t0, now, time.time()


@dataclass
class Record:
    # passes the function without calling it here — still wall time
    created_at: float = field(default_factory=time.monotonic)
