#!/usr/bin/env bash
# CI entry point: the repo's tier-1 verification in one command.
#   scripts/ci.sh            # tier-1 test suite + fast co-sim smoke
#   scripts/ci.sh -k serving # pass extra pytest args through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# contract gate (hard): AST-checked invariants — import layering,
# determinism, telemetry non-perturbation, EVENT_EFFECTS completeness
# (rules + sanctioned suppression sites documented in CONTRACTS.md)
mkdir -p results
python -m repro.analysis --json results/contracts.json

# lint (hard when ruff is available; the container image may not ship
# it — config lives in pyproject.toml [tool.ruff])
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks examples
else
    echo "ruff not installed; skipping lint (config in pyproject.toml)"
fi

python -m pytest -x -q "$@"

# fast co-sim smoke: exercises the event core, interference model,
# reactive loop and the batched request engine end-to-end on every CI
# run (seconds, CSV to stdout, JSON perf record to BENCH_cosim.json).
# The smoke runs the scenario x policy grid over a 2-worker process
# pool (--jobs 2 path) and measures both the constant and the
# calibrated (occupancy-coupled) engine.
python -m benchmarks.run --smoke --json BENCH_cosim.json

# soft events-per-second floors on the batched engine (constant and
# calibrated paths): a regression below a floor prints a loud warning
# (and shows up in the uploaded BENCH_cosim.json trajectory) but does
# not fail CI — shared runners are too noisy for a hard perf gate.
python - <<'EOF'
import json

FLOOR_REQ_PER_S = 300_000.0        # batched engine, Fig. 7 smoke config
FLOOR_CALIBRATED_REQ_PER_S = 800_000.0  # occupancy-coupled fast path,
#                                    provisioned smoke config (engine-only)
data = json.load(open("BENCH_cosim.json"))
for row_name, floor in (("event_engine_batched", FLOOR_REQ_PER_S),
                        ("event_engine_batched_calibrated",
                         FLOOR_CALIBRATED_REQ_PER_S)):
    rps = data.get(row_name, {}).get("requests_per_s")
    if rps is None:
        print(f"WARNING: no {row_name} throughput in BENCH_cosim.json")
    elif rps < floor:
        print(f"WARNING: {row_name} at {rps:,.0f} simulated req/s — "
              f"below the soft floor of {floor:,.0f}")
    else:
        print(f"{row_name} OK: {rps:,.0f} simulated req/s >= "
              f"soft floor {floor:,.0f}")
speedup = data.get("event_engine_speedup", {}).get("speedup")
if speedup is not None:
    print(f"batched/heap speedup: {speedup:.1f}x")
ratio = data.get("event_engine_batched_calibrated", {}).get("vs_constant")
if ratio is not None:
    print(f"calibrated path within {ratio:.2f}x of the constant model "
          f"(target: ~3x)")

# telemetry-overhead gate: metrics recording on the batched request
# plane must hold >= 90% of disabled-mode throughput (soft, like the
# other perf floors — shared runners are noisy)
TELEMETRY_FLOOR = 0.90
row = data.get("event_engine_batched_telemetry", {})
vs = row.get("vs_disabled")
if vs is None:
    print("WARNING: no telemetry-overhead row in BENCH_cosim.json")
elif vs < TELEMETRY_FLOOR:
    print(f"WARNING: telemetry-enabled engine at {vs:.1%} of "
          f"disabled-mode throughput — below the {TELEMETRY_FLOOR:.0%} "
          f"floor ({row.get('requests_per_s', 0):,.0f} req/s)")
else:
    print(f"telemetry overhead OK: enabled mode holds {vs:.1%} of "
          f"disabled-mode throughput (floor {TELEMETRY_FLOOR:.0%})")
EOF

# paged-serving record (written by the smoke above): the paged engines
# must keep their capacity win (>= 4x concurrent sequences at equal
# cache HBM) without giving the step time back (<= 1.5x dense at
# matched occupancy).  Soft, like the other perf floors — shared
# runners are too noisy for a hard wall-clock gate.
python - <<'EOF'
import json

CONCURRENCY_FLOOR = 4.0            # paged/dense admitted sequences
STEP_TIME_CEIL = 1.5               # paged/dense decode step time
data = json.load(open("BENCH_serving.json"))
conc = data.get("serving_paged_concurrency", {})
ratio = conc.get("concurrency_ratio")
if ratio is None:
    print("WARNING: no paged-concurrency row in BENCH_serving.json")
elif ratio < CONCURRENCY_FLOOR:
    print(f"WARNING: paged engine admits only {ratio:.1f}x the dense "
          f"sequences at equal cache HBM — below the soft floor of "
          f"{CONCURRENCY_FLOOR:.0f}x")
else:
    print(f"paged concurrency OK: {conc.get('paged_max_seqs', 0):.0f} vs "
          f"{conc.get('dense_max_seqs', 0):.0f} dense sequences "
          f"({ratio:.1f}x >= {CONCURRENCY_FLOOR:.0f}x) at "
          f"{conc.get('cache_tokens', 0):.0f} cache tokens")
step = data.get("serving_paged_step_time", {})
sratio = step.get("step_time_ratio")
if sratio is None:
    print("WARNING: no paged step-time row in BENCH_serving.json")
elif sratio > STEP_TIME_CEIL:
    print(f"WARNING: paged decode step at {sratio:.2f}x dense at matched "
          f"occupancy — above the soft ceiling of {STEP_TIME_CEIL:.1f}x")
else:
    print(f"paged step time OK: {sratio:.2f}x dense at occupancy "
          f"{step.get('occupancy', 0):.0f} (ceiling {STEP_TIME_CEIL:.1f}x)")
EOF

# decomposed-solver record (written by the smoke above): feasibility
# and the exact-gap bound are hard requirements; wall time gets a soft
# floor like the engine throughput (shared runners are noisy).
python - <<'EOF'
import json, sys

SOFT_WALL_S = 10.0                 # 10^5-device smoke instance
GAP_BOUND = 0.05                   # vs exact B&B on subsamples (hard)
data = json.load(open("BENCH_solver.json"))
for row in data["sizes"]:
    if not row["feasible"]:
        sys.exit(f"decomposed solve infeasible at n={row['n']}")
    tag = f"decomposed n={row['n']:,} m={row['m']:,}"
    if row["wall_s"] > SOFT_WALL_S:
        print(f"WARNING: {tag} took {row['wall_s']:.1f}s — above the "
              f"soft floor of {SOFT_WALL_S:.0f}s")
    else:
        print(f"{tag} OK: {row['wall_s']:.2f}s "
              f"({row['devices_per_s']:,.0f} devices/s), cost "
              f"{row['cost_vs_greedy']:+.0%} vs greedy")
gap = data.get("max_subsample_gap")
if gap is None:
    print("WARNING: no exact-gap subsamples in BENCH_solver.json")
elif gap > GAP_BOUND:
    sys.exit(f"decomposed subsample gap {gap:.3f} > {GAP_BOUND}")
else:
    print(f"decomposed exact-gap OK: {gap:.4f} <= {GAP_BOUND} over "
          f"{len(data['subsample_gaps'])} subsamples")
EOF

# fault-domain chaos record (written by the smoke above):
# - hard: availability accounting — chaos may strand only retries
#   still backing off at the horizon, nothing is silently lost (the
#   benchmark asserts the exact identity in-run; this gate re-checks
#   the recorded floor)
# - hard: the tier-failover gate — failing over to the cloud after
#   bounded retries must recover >= half of the peak windowed-p95
#   degradation a never-fail-over policy suffers
# - soft: post-recovery p95 — the 1 s-windowed p95 should re-enter
#   1.2x the clean p95 soon after the last outage clears
python - <<'EOF'
import json, sys

AVAILABILITY_FLOOR = 0.99          # hard (seeded run: deterministic)
RECOVERY_SOFT_S = 5.0              # soft: shared runners are noisy
data = json.load(open("BENCH_cosim.json"))
for name in ("faults_outage", "faults_domain_outage"):
    row = data.get(name)
    if row is None:
        sys.exit(f"no {name} row in BENCH_cosim.json")
    av, pend = row.get("availability"), row.get("retries_pending")
    if av is None or pend is None:
        sys.exit(f"{name}: availability accounting fields missing")
    if av < AVAILABILITY_FLOOR:
        sys.exit(f"{name}: availability {av:.4f} below the hard floor "
                 f"{AVAILABILITY_FLOOR}")
    print(f"{name} OK: availability {av:.4f} ({pend:.0f} retries "
          f"pending at horizon), amplification "
          f"{row.get('retry_amplification', 0):.3f}, "
          f"{row.get('failovers', 0):.0f} failovers, "
          f"{row.get('drops', 0):.0f} drops")
    rec = row.get("recovery_s", 0.0)
    if rec > RECOVERY_SOFT_S:
        print(f"WARNING: {name} windowed p95 took {rec:.1f}s to re-enter "
              f"1.2x clean after the last outage — above the soft "
              f"{RECOVERY_SOFT_S:.0f}s bound")
gate = data.get("faults_failover_gate", {})
if gate.get("gate") != "pass":
    sys.exit(f"failover gate FAILED: {gate}")
print(f"failover gate OK: tier failover recovered "
      f"{gate['recovered_frac']:.0%} of the no-failover peak-p95 "
      f"degradation ({gate['peak_p95_failover']:.0f} ms vs "
      f"{gate['peak_p95_nofailover']:.0f} ms stranded, clean "
      f"{gate['peak_p95_clean']:.0f} ms)")
EOF

# chaos determinism (hard): with the outage plan live — retries,
# backoff draws, failovers and standby promotions all engaged — the
# heap and batched engines must agree bit-for-bit on the control trace
python - <<'EOF'
from repro.sim.scenarios import SCENARIOS, run_scenario

fps = {e: run_scenario(SCENARIOS["outage"](), policy="reactive", seed=0,
                       duration_s=30.0, engine=e).control_fingerprint()
       for e in ("heap", "batched")}
assert fps["heap"] == fps["batched"], fps
print(f"chaos determinism OK: heap == batched ({fps['heap'][:16]}…)")
EOF

# observability artifacts: a sample Perfetto trace + decision audit
# from one instrumented reactive cell (uploaded by CI), and the
# dry-run roofline sweep summary (one small combo keeps this fast).
mkdir -p results
python examples/trace_reactive_run.py --out results --duration 60 \
    > results/trace_reactive_summary.txt \
    || echo "WARNING: sample trace generation failed"
python -m repro.launch.dryrun --arch xlstm-125m --shape decode_32k \
    --mesh single --out results/dryrun \
    || echo "WARNING: dry-run roofline sweep failed"
python - <<'EOF' || echo "WARNING: roofline summary failed"
import json
from benchmarks import roofline_report

recs = roofline_report.load("results/dryrun")
s = roofline_report.summarize(recs)
with open("results/roofline_summary.json", "w") as f:
    json.dump({"ok": s["ok"], "total": s["total"],
               "dominant": {k: len(v) for k, v in s["dominant"].items()}},
              f, indent=2)
    f.write("\n")
print(f"roofline summary: {s['ok']}/{s['total']} combos ok")
EOF
