"""Heap-vs-batched request-engine parity + the vectorized request
plane's building blocks: exact leaky-bucket replay, columnar log,
incremental telemetry percentiles, window-flush semantics, and the
bincount-vectorized HFLOP accessors."""
import time

import numpy as np
import pytest

from repro.core import hflop
from repro.core.topology import ClusterTopology
from repro.fl import round_schedule
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.routing import LatencyModel, SimConfig, simulate
from repro.routing.rules import EdgeState
from repro.routing.simulator import RequestProcessor
from repro.serving.workload import poisson_request_arrays, poisson_requests
from repro.sim import CoSim, CoSimConfig, EventKind, ReactiveLoop, \
    ReactivePolicy, Simulation, control_trace
from repro.sim.request_plane import ColumnarLog, bucket_admissions
from repro.sim.scenarios import SCENARIOS, run_scenario


# ---------------------------------------------------------------------------
# workload arrays
# ---------------------------------------------------------------------------

def test_poisson_arrays_match_event_list():
    lam = np.array([3.0, 0.0, 5.0, 1.5])
    t, d = poisson_request_arrays(lam, 20.0, seed=11)
    events = poisson_requests(lam, 20.0, seed=11)
    assert np.array_equal(t, [e.t for e in events])
    assert np.array_equal(d, [e.device for e in events])
    assert np.all(np.diff(t) >= 0)           # time-sorted
    assert t.size > 100 and np.all(t <= 20.0)


# ---------------------------------------------------------------------------
# exact leaky-bucket replay
# ---------------------------------------------------------------------------

def _sequential_reference(t, st):
    """The heap path's per-request admission, verbatim."""
    out = np.zeros(t.size, dtype=bool)
    for k, tk in enumerate(t):
        if st.has_room(priority=True, now=tk):
            st.admit(tk)
            out[k] = True
    return out


@pytest.mark.parametrize("cap,rate_mult,seed", [
    (8.0, 0.5, 0),     # underloaded: single bulk pass
    (8.0, 2.0, 1),     # overloaded: saturation alternation
    (8.0, 20.0, 2),    # heavily overloaded: long rejection runs
    (0.7, 2.0, 3),     # cap < 1 token: nothing ever admitted
    (0.0, 1.0, 4),     # dead edge
    (3.0, 1.05, 5),    # near-critically loaded: boundary-dense
])
def test_bucket_admissions_bit_exact(cap, rate_mult, seed):
    rng = np.random.default_rng(seed)
    rate = cap * rate_mult if cap > 0 else 5.0
    t = np.cumsum(rng.exponential(1.0 / max(rate, 1e-3), size=4000))
    a = EdgeState(capacity_rps=cap)
    b = EdgeState(capacity_rps=cap)
    got = bucket_admissions(t, a)
    want = _sequential_reference(t, b)
    assert np.array_equal(got, want)
    # token state may carry ~1e-15 cumsum-vs-sequential rounding; the
    # 1e-6 boundary guard keeps it from ever flipping a decision
    assert a.tokens == pytest.approx(b.tokens, abs=1e-9)
    assert a.last_t == b.last_t


def test_bucket_admissions_infinite_capacity():
    st = EdgeState(capacity_rps=np.inf)
    t = np.linspace(0.1, 5.0, 50)
    assert bucket_admissions(t, st).all()


def test_bucket_starved_edge_keeps_refilling():
    """Regression: a derated (cap < 1 token) bucket admits nothing,
    but its tokens must keep refilling toward cap exactly like the
    heap path — once capacity is restored, admissions resume at the
    same arrivals in both engines."""
    t1 = np.cumsum(np.full(20, 0.4)) + 0.1
    t2 = t1[-1] + np.cumsum(np.full(20, 0.4))
    a = EdgeState(capacity_rps=0.8)
    b = EdgeState(capacity_rps=0.8)
    a.tokens = b.tokens = 0.1          # CAPACITY_CHANGE clamp leftover
    got1 = bucket_admissions(t1, a)
    want1 = _sequential_reference(t1, b)
    assert not got1.any() and not want1.any()
    assert a.tokens == pytest.approx(b.tokens, abs=1e-9)
    for st in (a, b):                  # capacity restored mid-run
        st.capacity_rps = 2.0
    assert np.array_equal(bucket_admissions(t2, a),
                          _sequential_reference(t2, b))


def test_bucket_admissions_resumes_across_windows():
    """State carried across flush windows equals one long replay."""
    rng = np.random.default_rng(7)
    t = np.cumsum(rng.exponential(0.08, size=3000))
    whole = EdgeState(capacity_rps=6.0)
    want = _sequential_reference(t, whole)
    st = EdgeState(capacity_rps=6.0)
    got = np.concatenate([bucket_admissions(part, st)
                          for part in np.array_split(t, 13)])
    assert np.array_equal(got, want)
    assert st.tokens == pytest.approx(whole.tokens, abs=1e-9)
    assert st.last_t == whole.last_t


# ---------------------------------------------------------------------------
# columnar log + incremental telemetry
# ---------------------------------------------------------------------------

def test_columnar_log_mixed_append_extend():
    log = ColumnarLog(capacity=4)
    log.append(0.5, 3, 1, 0, 12.0)
    log.extend(np.array([1.0, 2.0]), np.array([1, 2]),
               np.array([0, 2], np.int8), np.array([2, 5], np.int8),
               np.array([7.0, 90.0]))
    log.append(3.0, 0, 0, 2, 8.0)
    assert log.n == 4
    assert np.array_equal(log.t[:4], [0.5, 1.0, 2.0, 3.0])
    assert np.array_equal(log.latency_ms[:4], [12.0, 7.0, 90.0, 8.0])
    assert np.array_equal(log.rule[:4], [0, 2, 5, 2])


def test_recent_percentile_matches_naive():
    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0, 100, 5000))
    lat = rng.exponential(10.0, 5000)
    log = ColumnarLog()
    log.extend(t, np.zeros(5000, np.int64), np.zeros(5000, np.int8),
               np.zeros(5000, np.int8), lat)
    for now in (10.0, 35.0, 35.0, 80.0, 100.0):     # monotone + repeat
        m = (t >= now - 12.0) & (t <= now)          # the documented window
        want = float(np.percentile(lat[m], 95))
        assert log.recent_percentile(now, 12.0, 95) == pytest.approx(want)
    # moving the window backward resets the cursor instead of lying
    m = (t >= 20.0 - 12.0) & (t <= 20.0)
    assert log.recent_percentile(20.0, 12.0, 95) == pytest.approx(
        float(np.percentile(lat[m], 95)))
    assert log.recent_percentile(200.0, 1e-6, 95, min_requests=1) is None


def test_recent_percentile_tick_cost_independent_of_history():
    """Satellite regression: telemetry ticks must not rescan the whole
    request history.  With a 100x longer history and the same window,
    the per-tick cost stays flat (generous 10x bound; a full rescan
    would be ~100x)."""
    def build(n):
        t = np.linspace(0.0, n / 100.0, n)
        log = ColumnarLog()
        log.extend(t, np.zeros(n, np.int64), np.zeros(n, np.int8),
                   np.zeros(n, np.int8), np.ones(n))
        return log, float(t[-1])

    def tick_cost(log, now):
        log.recent_percentile(now, 10.0, 95)     # warm the cursor
        best = np.inf
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(20):
                log.recent_percentile(now, 10.0, 95)
            best = min(best, time.perf_counter() - t0)
        return best

    small, now_s = build(20_000)
    big, now_b = build(2_000_000)
    assert tick_cost(big, now_b) < 10.0 * tick_cost(small, now_s)


# ---------------------------------------------------------------------------
# window-flush semantics
# ---------------------------------------------------------------------------

def test_flush_windows_split_at_control_events():
    """An arrival at exactly a control event's timestamp observes the
    control change (arrivals order after same-instant control events),
    and both engines agree on it."""
    def run(engine):
        topo = ClusterTopology(assign=np.zeros(1, int), n_devices=1,
                               n_edges=1, lam=np.ones(1),
                               r=np.full(1, 100.0), l=2)
        rng = np.random.default_rng(0)
        sim = Simulation()
        proc = RequestProcessor(
            topo, rng, engine=engine,
            busy_fn=lambda i, t: True,
            busy_mask_fn=lambda d, t: np.ones(d.size, bool))
        proc.bind(sim)
        t_arr = np.array([1.0, 2.0, 3.0])
        if engine == "heap":
            for t in t_arr:
                sim.schedule(t, EventKind.REQUEST_ARRIVAL, node=0)
        else:
            proc.add_arrivals(t_arr, np.zeros(3, np.int64))
        sim.on(EventKind.NODE_FAILURE,
               lambda s, e: proc.fail_edge(0))
        sim.schedule(2.0, EventKind.NODE_FAILURE, node=0)
        sim.run(until=3.0)
        return proc.log()

    for engine in ("heap", "batched"):
        log = run(engine)
        assert log.rule == ["R1", "R3-overflow", "R3-overflow"], engine


def test_run_until_flushes_inclusive_tail():
    topo = ClusterTopology(assign=np.zeros(2, int), n_devices=2, n_edges=1,
                           lam=np.ones(2), r=np.full(1, 10.0), l=2)
    sim = Simulation()
    proc = RequestProcessor(topo, np.random.default_rng(0),
                            engine="batched")
    proc.bind(sim)
    proc.add_arrivals(np.array([0.5, 2.0, 2.5]),
                      np.array([0, 1, 0], np.int64))
    sim.run(until=2.0)                 # no control events at all
    assert proc.log().t.size == 2      # t <= until flushed, 2.5 pending
    sim.run(until=3.0)
    assert proc.log().t.size == 3


# ---------------------------------------------------------------------------
# engine parity: co-simulation (bit-exact)
# ---------------------------------------------------------------------------

def _hot_zone(seed=0):
    # the canonical Fig. 7 hot-zone recipe — the exact configuration
    # the scenario engine and figure benchmarks run
    from repro.sim.scenarios import hot_zone_topology
    return hot_zone_topology(seed=seed)


def _training(duration):
    rounds = max(int(duration / 20.0), 1)
    return round_schedule(rounds=rounds, l=2, local_epochs=5, epoch_s=3.5,
                          upload_s=2.0, gap_s=2.0)


def test_cosim_batched_bit_identical_to_heap():
    for seed in (0, 3):
        runs = {}
        for engine in ("heap", "batched"):
            topo, *_ = _hot_zone(seed)
            cfg = CoSimConfig(duration_s=45.0, seed=seed, engine=engine)
            runs[engine] = CoSim(topo, cfg, schedule=_training(45.0)).run()
        a, b = runs["heap"], runs["batched"]
        assert np.array_equal(a.log.t, b.log.t)
        assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
        assert np.array_equal(a.log.tier, b.log.tier)
        assert a.log.rule == b.log.rule
        assert control_trace(a.trace) == control_trace(b.trace)
        assert a.rounds_completed == b.rounds_completed


def test_cosim_reactive_bit_identical_to_heap():
    """The strong guarantee: with the reactive loop closing the
    monitor -> recluster cycle, both engines still take identical
    decisions at identical times."""
    runs = {}
    for engine in ("heap", "batched"):
        topo, loc, lam, r = _hot_zone()
        cfg = CoSimConfig(duration_s=60.0, seed=0, engine=engine)
        ctl = LearningController(
            inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=2)
        ctl.deployment = Deployment.from_topology(topo)
        loop = ReactiveLoop(ctl,
                            policy=ReactivePolicy(p95_threshold_ms=20.0))
        runs[engine] = CoSim(topo, cfg, schedule=_training(60.0),
                             reactive=loop).run()
    a, b = runs["heap"], runs["batched"]
    assert a.actions and a.actions == b.actions
    assert a.reconfig_times == b.reconfig_times
    assert np.array_equal(a.log.latency_ms, b.log.latency_ms)
    assert control_trace(a.trace) == control_trace(b.trace)


@pytest.mark.parametrize("sc_name,policy", [
    ("straggler", "reactive"), ("mobility", "budgeted"),
    ("multi_tenant", "reactive"), ("churn", "budgeted")])
def test_scenario_control_fingerprints_identical(sc_name, policy):
    rb = run_scenario(SCENARIOS[sc_name](), policy=policy, seed=0,
                      duration_s=60.0, engine="batched")
    rh = run_scenario(SCENARIOS[sc_name](), policy=policy, seed=0,
                      duration_s=60.0, engine="heap")
    assert rb.control_fingerprint() == rh.control_fingerprint()
    assert np.array_equal(rb.log.latency_ms, rh.log.latency_ms)
    assert rb.actions == rh.actions


# ---------------------------------------------------------------------------
# engine parity: inference-only simulate (distributional)
# ---------------------------------------------------------------------------

def _fig7_logs(cfg):
    from repro.core import solve_heuristic
    from repro.routing import compare_methods
    from repro.sim.scenarios import hot_zone_topology
    _, loc, lam, r = hot_zone_topology(seed=0)
    n, m = lam.size, r.size
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    inst = hflop.HFLOPInstance(c_d, np.ones(m), lam, r, l=2)
    sol = solve_heuristic(inst)
    return compare_methods(inst, {"flat": None, "hier": loc,
                                  "hflop": sol.assign}, cfg)


@pytest.mark.parametrize("rate_scale,speedup", [(1.0, 0.0),  # Fig. 7
                                                (10.0, 0.5)])  # Fig. 8b
def test_simulate_parity_fig7_fig8(rate_scale, speedup):
    """Same-seed heap and batched runs agree on p50/p95 within 1% and
    on tier fractions exactly (busy draws are interleaved differently,
    so only the RTT noise differs — routing is identical under
    continual training)."""
    lat = LatencyModel(cloud_speedup=speedup)
    logs = {}
    for engine in ("heap", "batched"):
        cfg = SimConfig(duration_s=60.0, seed=0, engine=engine,
                        rate_scale=rate_scale, latency=lat)
        logs[engine] = _fig7_logs(cfg)
    for name in ("flat", "hier", "hflop"):
        lh, lb = logs["heap"][name], logs["batched"][name]
        assert np.array_equal(lh.t, lb.t)
        assert np.array_equal(lh.tier, lb.tier)
        assert lh.tier_fractions() == lb.tier_fractions()
        for p in (50, 95):
            ph = lh.percentile_latency(p)
            pb = lb.percentile_latency(p)
            assert abs(ph - pb) <= 0.01 * ph, (name, p)


def test_simulate_busy_fraction_parity():
    """With a fractional busy coin flip the routing itself is random,
    so parity is distributional: tier fractions within a few percent,
    percentiles within 5%."""
    topo = ClusterTopology(assign=np.arange(12) % 3, n_devices=12,
                           n_edges=3, lam=np.full(12, 4.0),
                           r=np.full(3, 18.0), l=2)
    lh = simulate(topo, SimConfig(duration_s=60.0, seed=1,
                                  busy_fraction=0.5, engine="heap"))
    lb = simulate(topo, SimConfig(duration_s=60.0, seed=1,
                                  busy_fraction=0.5, engine="batched"))
    fh, fb = lh.tier_fractions(), lb.tier_fractions()
    for tier in ("device", "edge", "cloud"):
        assert abs(fh[tier] - fb[tier]) < 0.05
    for p in (50, 95):
        ph, pb = lh.percentile_latency(p), lb.percentile_latency(p)
        assert abs(ph - pb) <= 0.05 * max(ph, 1.0)


def test_unknown_engine_rejected():
    topo = ClusterTopology(assign=np.zeros(1, int), n_devices=1,
                           n_edges=1, lam=np.ones(1), r=np.ones(1), l=2)
    with pytest.raises(ValueError):
        simulate(topo, SimConfig(duration_s=1.0, engine="bogus"))


def test_batched_engine_rejects_scalar_only_policies():
    """A scalar-only caller on the batched engine would silently get
    default routing — it must raise instead."""
    topo = ClusterTopology(assign=np.zeros(1, int), n_devices=1,
                           n_edges=1, lam=np.ones(1), r=np.ones(1), l=2)
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="busy_fn"):
        RequestProcessor(topo, rng, engine="batched",
                         busy_fn=lambda i, t: True)
    with pytest.raises(ValueError, match="service_fn"):
        RequestProcessor(topo, rng, engine="batched",
                         service_fn=lambda i, d, o: 1.0)
    # paired policies are fine, as is scalar-only on the heap engine
    RequestProcessor(topo, rng, engine="batched",
                     busy_fn=lambda i, t: True,
                     busy_mask_fn=lambda d, t: np.ones(d.size, bool))
    RequestProcessor(topo, rng, engine="heap",
                     busy_fn=lambda i, t: True)


def test_calibrated_occupancy_parity():
    """Occupancy-dependent (calibrated) edge service takes the
    per-edge sequential fallback in the batched engine — still
    bit-identical to the heap."""
    from repro.routing import CalibratedLatencyModel
    lat = CalibratedLatencyModel(tier_service_ms={"edge": 40.0},
                                 tier_slots={"edge": 2})
    logs = {}
    for engine in ("heap", "batched"):
        topo, *_ = _hot_zone()
        cfg = CoSimConfig(duration_s=30.0, seed=0, engine=engine,
                          latency=lat)
        logs[engine] = CoSim(topo, cfg, schedule=_training(30.0)).run().log
    assert np.array_equal(logs["heap"].latency_ms,
                          logs["batched"].latency_ms)
    assert logs["heap"].rule == logs["batched"].rule


# ---------------------------------------------------------------------------
# vectorized latency / interference APIs match their scalar twins
# ---------------------------------------------------------------------------

def test_infer_ms_array_matches_scalar():
    from repro.routing import CalibratedLatencyModel
    occ = np.array([0.0, 1.0, 3.0, 7.0])
    const = LatencyModel(cloud_speedup=0.4)
    calib = CalibratedLatencyModel(tier_service_ms={"edge": 10.0},
                                   tier_slots={"edge": 2})
    for lat in (const, calib):
        for tier in ("device", "edge", "cloud"):
            want = [lat.infer_ms(tier, occupancy=o) for o in occ]
            assert np.allclose(lat.infer_ms_array(tier, occ), want)
    assert not const.occupancy_dependent("edge")
    assert calib.occupancy_dependent("edge")
    assert not calib.occupancy_dependent("cloud")


def test_service_ms_array_matches_scalar():
    from repro.routing.rules import RouteDecision
    from repro.sim import InterferenceModel
    m = InterferenceModel()
    m.set_demand(("edge", 1), "agg", 0.5)
    m.set_demand(("device", 2), "epoch", 0.4)
    ids = np.array([0, 1, 1, 3])
    got = m.service_ms_array("edge", ids)
    want = [m.service_ms(0, RouteDecision("edge", int(j))) for j in ids]
    assert np.allclose(got, want)
    dev = np.array([2, 0, 2])
    got_d = m.service_ms_array("device", dev)
    want_d = [m.service_ms(int(i), RouteDecision("device", None))
              for i in dev]
    assert np.allclose(got_d, want_d)
    assert np.allclose(m.stretch_array("edge", ids),
                       [m.stretch(("edge", int(j))) for j in ids])


# ---------------------------------------------------------------------------
# HFLOP bincount satellites
# ---------------------------------------------------------------------------

def test_hflop_y_matches_loop_reference():
    for assign in (np.array([0, 2, 2, -1, 4]), np.array([-1, -1]),
                   np.zeros(0, int), np.array([1, 1, 1])):
        sol = hflop.HFLOPSolution(assign=assign, cost=0.0)
        m = 1 + (int(assign.max()) if assign.size else -1)
        want = np.asarray([np.any(assign == j) for j in range(m)], bool)
        assert np.array_equal(sol.y, want)


def test_hflop_violations_matches_loop_reference():
    rng = np.random.default_rng(0)
    inst = hflop.random_instance(40, 6, seed=1, capacity_slack=0.9)
    for _ in range(10):
        assign = rng.integers(-1, inst.m, inst.n)
        got = hflop.violations(inst, assign)
        want = []
        if np.any(assign >= inst.m):
            want.append("assignment to nonexistent edge")
        participating = int(np.sum(assign >= 0))
        if participating < inst.T:
            want.append(f"participation {participating} < T={inst.T}")
        for j in range(inst.m):
            load = float(np.sum(inst.lam[assign == j]))
            if load > inst.r[j] + 1e-9:
                want.append(f"edge {j}: load {load:.3f} "
                            f"> r={inst.r[j]:.3f}")
        assert got == want
