"""Serving driver: batched decode over a Poisson inference workload with
R1-R3 routing between replica tiers — the TPU-side realization of the
paper's inference path.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
      --requests 32 --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import make_model
from repro.routing import LatencyModel, SimConfig
from repro.serving import ServeEngine, batched_arrivals, poisson_requests


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--rate", type=float, default=20.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    engine = ServeEngine(cfg, params, batch_size=args.batch, max_len=256)

    lam = np.full(args.batch, args.rate / args.batch)
    events = poisson_requests(lam, duration_s=args.requests / args.rate,
                              seed=0)
    print(f"{len(events)} requests over {args.requests / args.rate:.1f}s "
          f"(batch={args.batch})")
    served = 0
    t_start = time.perf_counter()
    rng = np.random.default_rng(0)
    for t_arr, devices in batched_arrivals(events, args.batch):
        B = args.batch
        prompt = jnp.asarray(
            rng.integers(0, max(cfg.model.vocab_size, 2), (B, 4)), jnp.int32)
        toks = engine.generate(prompt, steps=args.decode_steps)
        served += len(devices)
        print(f"  t={t_arr:6.3f}s batch={len(devices):2d} "
              f"out_shape={tuple(toks.shape)} sample={toks[0, :4].tolist()}")
    dt = time.perf_counter() - t_start
    print(f"served {served} requests in {dt:.1f}s wall "
          f"({served / dt:.1f} req/s on this CPU host)")


if __name__ == "__main__":
    main()
