"""Shared utilities for the model zoo: parameter construction with logical
sharding axes, sharding-constraint context, dtype helpers.

Params are plain nested dicts of jnp arrays (no flax).  Every parameter is
created through :class:`ParamBuilder`, which simultaneously records the
parameter's *logical axes* (e.g. ``("embed", "mlp")``).  The launch layer
maps logical axes to mesh axes (see ``repro/launch/shardings.py``).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# ---------------------------------------------------------------------------
# Logical-axis sharding context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def logical_sharding(mesh, rules: Dict[str, Tuple[str, ...]]):
    """Within this context, :func:`shard` applies with_sharding_constraint
    using ``rules`` (logical axis -> mesh axes).  Outside it, shard() is a
    no-op so all model code runs unchanged on a single CPU device."""
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules)
    try:
        yield
    finally:
        _CTX.state = prev


def _mesh_axes_for(logical: Sequence[Optional[str]], shape=None):
    mesh, rules = _CTX.state
    out, used = [], set()
    for i, ax in enumerate(logical):
        if ax is None:
            out.append(None)
            continue
        cand = rules.get(ax, ())
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        if not cand:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in cand]))
        if shape is not None and shape[i] % size != 0:
            # divisibility fallback: try progressively smaller prefixes
            ok = ()
            for k in range(len(cand), 0, -1):
                sz = int(np.prod([mesh.shape[a] for a in cand[:k]]))
                if shape[i] % sz == 0:
                    ok = cand[:k]
                    break
            cand = ok
        if not cand:
            out.append(None)
        else:
            used.update(cand)
            out.append(cand if len(cand) > 1 else cand[0])
    return out


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint to an activation (no-op outside
    a :func:`logical_sharding` context)."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, _ = state
    axes = _mesh_axes_for(logical, shape=x.shape)
    spec = jax.sharding.PartitionSpec(*axes)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def named_sharding_for(mesh, rules, logical: Sequence[Optional[str]],
                       shape: Sequence[int]):
    """NamedSharding for a tensor of ``shape`` with ``logical`` axes."""
    token = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules)
    try:
        axes = _mesh_axes_for(logical, shape=tuple(shape))
    finally:
        _CTX.state = token
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec(*axes))


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

class ParamBuilder:
    """Builds a nested param dict and a parallel tree of logical axes.

    >>> pb = ParamBuilder(rng, dtype=jnp.bfloat16)
    >>> w = pb.param("attn/wq", (d, H, hd), ("embed", "heads", "head_dim"))
    """

    def __init__(self, rng: jax.Array, dtype=jnp.bfloat16):
        self.rng = rng
        self.dtype = dtype
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}
        self._counter = 0

    def _next_rng(self) -> jax.Array:
        self._counter += 1
        return jax.random.fold_in(self.rng, self._counter)

    def _insert(self, tree: Dict[str, Any], path: str, value: Any) -> None:
        parts = path.split("/")
        for p in parts[:-1]:
            tree = tree.setdefault(p, {})
        if parts[-1] in tree:
            raise ValueError(f"duplicate param {path}")
        tree[parts[-1]] = value

    def param(self, path: str, shape: Tuple[int, ...],
              axes: Tuple[Optional[str], ...],
              init: str = "fan_in", scale: float = 1.0,
              dtype=None) -> jax.Array:
        assert len(shape) == len(axes), (path, shape, axes)
        dtype = dtype or self.dtype
        if init == "zeros":
            val = jnp.zeros(shape, dtype)
        elif init == "ones":
            val = jnp.ones(shape, dtype)
        elif init == "normal":
            val = (jax.random.normal(self._next_rng(), shape, jnp.float32)
                   * scale).astype(dtype)
        else:  # fan_in
            fan_in = shape[0] if len(shape) >= 1 else 1
            if len(shape) >= 2:
                fan_in = int(np.prod(shape[:-1])) // int(np.prod(shape[:-2])) \
                    if len(shape) > 2 else shape[0]
            std = scale / np.sqrt(max(fan_in, 1))
            val = (jax.random.normal(self._next_rng(), shape, jnp.float32)
                   * std).astype(dtype)
        self._insert(self.params, path, val)
        self._insert(self.axes, path, tuple(axes))
        return val

    def subtree(self, prefix: str, params: Dict[str, Any],
                axes: Dict[str, Any]) -> None:
        """Graft an externally built (params, axes) pair under ``prefix``."""
        self._insert(self.params, prefix, params)
        self._insert(self.axes, prefix, axes)

    def build(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        return self.params, self.axes


def stack_params(trees: Sequence[PyTree]) -> PyTree:
    """Stack a list of identically-structured param trees along axis 0
    (for scan-over-layers)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_axes(axes_tree: PyTree, layer_axis: str = "layers") -> PyTree:
    """Prepend the layer logical axis to every axes tuple."""
    return jax.tree.map(
        lambda a: (layer_axis,) + tuple(a),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


# ---------------------------------------------------------------------------
# dtype / numerics helpers
# ---------------------------------------------------------------------------

def to_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def count_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(tree))
