"""§Perf hillclimb 1 (worst roofline fraction): stablelm-1.6b x decode_32k.

stablelm-2 is full MHA (kv_heads = 32), so its 32k cache is the largest
per-parameter of any assigned arch; decode is deeply memory-bound
(MFU bound ~0.003).  Iterations:

  it0  baseline                       (bf16 cache, modelled read+rewrite)
  it1  in-place donated cache updates (write only the new slot)
  it2  f8 (float8_e4m3fn) cache       (halves cache bytes; beyond paper)

Each iteration is re-lowered; HLO argument bytes validate the cache-size
hypotheses; the analytic memory term gives the step-time effect."""
from __future__ import annotations

import argparse
import dataclasses
import json
import os


import repro.launch.dryrun  # noqa: F401  (512-device flag)
from repro.configs import INPUT_SHAPES, get_config
from repro.launch import shardings as sh
from repro.launch.analytic import analytic_roofline
from repro.launch.dryrun import build_programs
from repro.launch.mesh import HBM_BW, make_production_mesh
from repro.launch.roofline import collective_stats


def lower_decode(arch: str, shape: str, cache_dtype: str = ""):
    mesh = make_production_mesh(multi_pod=False)
    cfg = get_config(arch)
    if cache_dtype:
        cfg = dataclasses.replace(
            cfg, run=dataclasses.replace(cfg.run, cache_dtype=cache_dtype))
        import repro.configs.registry as reg
        # route the modified config through build_programs
        orig = reg.get_config
        reg_get = lambda name: cfg if name == arch else orig(name)
        import repro.launch.dryrun as dr
        dr.get_config, saved = reg_get, dr.get_config
    rules = sh.rules_for(cfg, mesh)
    try:
        fn, inputs = build_programs(arch, shape, mesh, rules)
        compiled = fn.lower(*inputs).compile()
    finally:
        if cache_dtype:
            import repro.launch.dryrun as dr
            dr.get_config = saved
    ma = compiled.memory_analysis()
    args_b = int(ma.argument_size_in_bytes)
    return cfg, compiled, args_b


def measured_decode(arch: str, decode_steps: int = 16) -> dict:
    """Wall-clock continuous-batching decode step times from the tiered
    ReplicaPool (reduced config on this host): the measured counterpart
    of the analytic memory term, and the TPOT source for
    ``LatencyModel.from_measurements``."""
    from repro.serving import ReplicaPool, lm_tiers
    pool = ReplicaPool(lm_tiers(arch))
    meas = pool.measure(prompt_len=32, decode_steps=decode_steps)
    out = {}
    for tier, m in meas.items():
        print(f"measured[{tier:6s}]: decode={m.decode_ms_per_token:7.2f} "
              f"ms/token @ {m.batch_size} slots")
        out[tier] = {"decode_ms_per_token": m.decode_ms_per_token,
                     "batch_size": m.batch_size}
    return out


def run_paged(arch="stablelm-1.6b", page_size=16, max_len=256,
              dense_batch=4, prompt_len=16, new_tokens=16,
              decode_steps=8, out="BENCH_serving.json") -> dict:
    """§Perf hillclimb: paged-vs-dense serving rows (BENCH_serving.json).

    Same cache HBM on both sides — the dense engine reserves
    ``dense_batch`` full ``max_len`` rows, the paged engine gets exactly
    that many tokens as a shared :class:`PagePool` — then:

      serving_paged_concurrency  max concurrent sequences until
                                 ``can_admit`` refuses (prompt_len +
                                 new_tokens reservation per request);
      serving_paged_step_time    batched decode step wall time at
                                 matched occupancy (dense_batch active
                                 rows on both engines).

    Rows land in the telemetry-backed registry and are exported with the
    ``serving_`` prefix filter so the artifact stays self-contained."""
    import time

    import jax
    import numpy as np

    from benchmarks.common import emit, write_json
    from repro.models import make_model
    from repro.serving import PagedServeEngine, ServeEngine

    cfg = get_config(arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))

    pages_dense = -(-max_len // page_size)
    num_pages = dense_batch * pages_dense        # == dense cache tokens
    cache_tokens = num_pages * page_size
    seq_budget = prompt_len + new_tokens
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, max(cfg.model.vocab_size, 2), (prompt_len,))

    def fill(engine) -> tuple:
        """Admit prompt+reservation requests until the engine refuses;
        returns (count, mean admit ms)."""
        n = 0
        t0 = time.perf_counter()
        while engine.can_admit(prompt_len, new_tokens):
            slot = engine.acquire_slot()
            if slot is None:
                break
            engine.admit(prompt, slot=slot, reserve_tokens=new_tokens)
            n += 1
        return n, (time.perf_counter() - t0) * 1e3 / max(n, 1)

    def step_ms(engine, active: int, steps: int) -> float:
        for _ in range(min(active, 2)):          # warmup covers compile
            engine.decode()
        t0 = time.perf_counter()
        for _ in range(steps):
            engine.decode()
        return (time.perf_counter() - t0) * 1e3 / steps

    # -- capacity at equal cache HBM ---------------------------------------
    dense = ServeEngine(cfg, params, batch_size=dense_batch,
                        max_len=max_len)
    # row cap sized by the token budget, not by dense slots
    paged = PagedServeEngine(cfg, params,
                             max_seqs=cache_tokens // seq_budget,
                             page_size=page_size, num_pages=num_pages,
                             max_len=max_len)
    n_dense, admit_dense_ms = fill(dense)
    n_paged, admit_paged_ms = fill(paged)
    ratio = n_paged / max(n_dense, 1)
    print(f"concurrency @ {cache_tokens} cache tokens: dense={n_dense} "
          f"paged={n_paged} ({ratio:.1f}x)")
    emit("serving_paged_concurrency", admit_paged_ms * 1e3,
         f"dense_max_seqs={n_dense};paged_max_seqs={n_paged};"
         f"concurrency_ratio={ratio};cache_tokens={cache_tokens};"
         f"page_size={page_size};dense_admit_us={admit_dense_ms * 1e3:.1f}")

    # -- decode step time at matched occupancy -----------------------------
    # a fresh paged engine with dense-equal rows: both engines now decode
    # a dense_batch-row program with dense_batch active sequences
    paged_eq = PagedServeEngine(cfg, params, max_seqs=dense_batch,
                                page_size=page_size, num_pages=num_pages,
                                max_len=max_len)
    fill(paged_eq)
    dense_ms = step_ms(dense, n_dense, decode_steps)
    paged_ms = step_ms(paged_eq, dense_batch, decode_steps)
    step_ratio = paged_ms / max(dense_ms, 1e-9)
    print(f"decode step @ occupancy {dense_batch}: dense={dense_ms:.1f}ms "
          f"paged={paged_ms:.1f}ms ({step_ratio:.2f}x)")
    emit("serving_paged_step_time", paged_ms * 1e3,
         f"dense_step_us={dense_ms * 1e3:.1f};step_time_ratio={step_ratio};"
         f"occupancy={dense_batch};decode_steps={decode_steps}")

    res = {"dense_max_seqs": n_dense, "paged_max_seqs": n_paged,
           "concurrency_ratio": ratio, "cache_tokens": cache_tokens,
           "dense_step_ms": dense_ms, "paged_step_ms": paged_ms,
           "step_time_ratio": step_ratio}
    if out:
        write_json(out, prefix="serving_")
        print(f"# wrote {out}")
    return res


def report(arch="stablelm-1.6b", shape="decode_32k", out="",
           measure=False):
    mesh = make_production_mesh(multi_pod=False)
    shp = INPUT_SHAPES[shape]
    res = {}
    print(f"=== {arch} x {shape} on 16x16 ===")

    # it0: baseline (analytic assumes read + full rewrite of the cache)
    cfg0, c0, args0 = lower_decode(arch, shape)
    ana0 = analytic_roofline(cfg0, shp, mesh)
    print(f"it0 baseline      : args/dev={args0 / 1e9:.2f} GB  "
          f"memory_s={ana0.memory_s:.2e}  dominant={ana0.dominant}")
    res["it0"] = {"args_bytes": args0, "memory_s": ana0.memory_s}

    # it1: donated in-place update -> per-step cache traffic = 1x read +
    # slot write (the rewrite term in the baseline model was refuted by
    # the donation aliasing in the compiled module)
    from repro.launch.analytic import _cache_bytes_per_seq
    cache_dev = _cache_bytes_per_seq(cfg0, shp.seq_len) * shp.global_batch \
        / mesh.devices.size
    p_dev = cfg0.model.param_count() * 2 / mesh.devices.size
    mem_it1 = (p_dev + cache_dev) / HBM_BW
    print(f"it1 in-place write: memory_s={mem_it1:.2e} "
          f"({ana0.memory_s / mem_it1:.2f}x better)")
    res["it1"] = {"memory_s": mem_it1}

    # it2: f8 cache
    cfg2, c2, args2 = lower_decode(arch, shape, "float8_e4m3fn")
    mem_it2 = (p_dev + cache_dev / 2) / HBM_BW
    print(f"it2 f8 cache      : args/dev={args2 / 1e9:.2f} GB "
          f"(HLO confirms {args0 / max(args2, 1):.2f}x smaller args)  "
          f"memory_s={mem_it2:.2e} ({mem_it1 / mem_it2:.2f}x better)")
    res["it2"] = {"args_bytes": args2, "memory_s": mem_it2}
    res["total_gain"] = ana0.memory_s / mem_it2
    print(f"total: {res['total_gain']:.2f}x on the dominant (memory) term")
    if measure:
        res["measured"] = measured_decode(arch)
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=2)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--out", default="results/perf_decode_cache.json")
    ap.add_argument("--measure", action="store_true",
                    help="also time the real tiered engines (ReplicaPool)")
    ap.add_argument("--paged", action="store_true",
                    help="only the paged-vs-dense serving rows "
                         "(BENCH_serving.json)")
    a = ap.parse_args()
    if a.paged:
        run_paged(a.arch)
    else:
        report(a.arch, a.shape, a.out, measure=a.measure)
