import os
import sys

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benchmarks must see 1 device (the 512-device flag belongs
# to repro.launch.dryrun only).  Multi-device collective tests spawn
# subprocesses with their own XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
