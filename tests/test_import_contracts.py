"""Runtime proof of the LAYER001/LAYER002 contracts: import the
protected stack in a subprocess where jax is *blocked* (a meta-path
finder that raises on any attempt), and separately assert that
importing it the normal way never pulls jax into sys.modules.  The
static rule catches the import graph; this catches dynamic imports the
AST walk can't see."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

BLOCKER = textwrap.dedent("""
    import sys

    BLOCKED = ("jax", "jaxlib", "flax", "optax")

    class _Blocker:
        def find_module(self, name, path=None):
            return self.find_spec(name, path)

        def find_spec(self, name, path=None, target=None):
            root = name.split(".")[0]
            if root in BLOCKED:
                raise ImportError(
                    f"contract LAYER001: {name} imported while blocked")
            return None

    sys.meta_path.insert(0, _Blocker())
""")

PROTECTED = ["repro.routing", "repro.sim", "repro.core",
             "repro.telemetry", "repro.configs", "repro.fl.schedule"]

#: importing the lazy facades must also stay jax-free (LAYER002) —
#: only *attribute access* on them may pay the jax import
FACADES = ["repro.serving", "repro.fl"]


def run_with_blocker(body):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-c", BLOCKER + body],
                          capture_output=True, text=True, env=env)


def test_blocker_actually_blocks():
    proc = run_with_blocker("import jax\n")
    assert proc.returncode != 0
    assert "contract LAYER001" in proc.stderr


def test_protected_stack_imports_with_jax_blocked():
    body = "".join(f"import {m}\n" for m in PROTECTED + FACADES)
    body += "print('imported-ok')\n"
    proc = run_with_blocker(body)
    assert proc.returncode == 0, proc.stderr
    assert "imported-ok" in proc.stdout


def test_protected_stack_usable_with_jax_blocked():
    """Not just importable: the numpy sim stack runs end to end."""
    body = textwrap.dedent("""
        from repro.fl.schedule import round_schedule
        from repro.sim.scenarios import random_waypoint_moves
        windows = round_schedule(rounds=2, l=2)
        moves = random_waypoint_moves(8, 4, 30.0, seed=3)
        assert windows and isinstance(moves, list)
        print("ran-ok", len(windows), len(moves))
    """)
    proc = run_with_blocker(body)
    assert proc.returncode == 0, proc.stderr
    assert "ran-ok" in proc.stdout


def test_normal_import_keeps_jax_out_of_sys_modules():
    body = "".join(f"import {m}\n" for m in PROTECTED + FACADES)
    body += ("import sys\n"
             "bad = sorted(m for m in sys.modules\n"
             "             if m.split('.')[0] in ('jax', 'jaxlib',\n"
             "                                    'flax', 'optax'))\n"
             "assert not bad, f'jax leaked in: {bad}'\n"
             "print('no-jax-ok')\n")
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "no-jax-ok" in proc.stdout
