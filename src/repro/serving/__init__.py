from repro.serving.engine import EngineMeasurement, ServeEngine, bucket_len
from repro.serving.replica import (DEFAULT_TIERS, ReplicaPool, TierSpec,
                                   lm_tiers)
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     ScheduleStats, requests_from_events)
from repro.serving.workload import (RequestEvent, batched_arrivals,
                                    poisson_requests)

__all__ = ["EngineMeasurement", "ServeEngine", "bucket_len",
           "DEFAULT_TIERS", "ReplicaPool", "TierSpec", "lm_tiers",
           "ContinuousBatchingScheduler", "Request", "ScheduleStats",
           "requests_from_events", "RequestEvent", "batched_arrivals",
           "poisson_requests"]
