"""Paper Fig. 6 + §V-B1: continual (hierarchical) federated learning on
METR-LA-style traffic data.

(a) non-hierarchical, (b) hierarchical by location, (c) HFLOP — 20
clients, 5 epochs/round, l=2 local rounds per global round; per-client
validation MSE recorded right after model receipt.  Also the §V-B1
continual-vs-static comparison (paper: 0.04470 one-shot vs 0.04284
continually retrained).

Full paper scale is 100 rounds; default here is 40 (convergence happens
by ~20 in the paper and here) — pass --rounds 100 for the full curve."""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.configs import get_config
from repro.core import HFLOPInstance, solve_heuristic
from repro.core.topology import ClusterTopology
from repro.data.traffic import generate, select_fl_sensors
from repro.fl.hierarchy import (ContinualHFL, HFLRunConfig,
                                continuous_vs_static)
from benchmarks.common import emit


def build(seed=0, n_days=None, rounds=40):
    need_days = 22 + 7 + (rounds * 36) // 288 + 2
    ds = generate(num_days=n_days or need_days, seed=seed)
    sensors = select_fl_sensors(ds, per_cluster=5, seed=seed)
    n, m = len(sensors), 4
    rng = np.random.default_rng(seed)
    lam = rng.uniform(2.0, 6.0, n)
    loc = ds.cluster_of[sensors]
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    r = np.full(m, lam.sum() / m * 1.3)
    inst = HFLOPInstance(c_d, np.ones(m), lam, r, l=2)
    return ds, sensors, inst, loc


def run(rounds=40, max_batches=25, seed=0, out_json=""):
    ds, sensors, inst, loc = build(seed, rounds=rounds)
    cfg = get_config("gru-traffic")
    runcfg = HFLRunConfig(rounds=rounds, max_batches=max_batches, seed=seed)
    hflop_sol = solve_heuristic(inst)

    topos = {
        "flat": ("flat", ClusterTopology.flat(len(sensors), inst.lam)),
        "hier_location": ("hier", ClusterTopology(
            assign=loc, n_devices=inst.n, n_edges=inst.m, lam=inst.lam,
            r=inst.r, l=2)),
        "hflop": ("hier", ClusterTopology.from_solution(inst, hflop_sol)),
    }
    curves = {}
    for name, (mode, topo) in topos.items():
        runner = ContinualHFL(cfg, ds, sensors, topo, runcfg, mode=mode)
        res = runner.run_rounds(progress=True)
        conv = res.converged_round()
        final = float(res.mse.mean(axis=1)[-5:].mean())
        emit(f"fig6_{name}", final * 1e6,
             f"final_mse={final:.5f};converged_round={conv}")
        curves[name] = res.mse.mean(axis=1).tolist()
    if out_json:
        os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
        with open(out_json, "w") as f:
            json.dump(curves, f)
    return curves


def run_continual_vs_static(rounds=12, seed=0):
    ds, sensors, inst, loc = build(seed, rounds=rounds)
    cfg = get_config("gru-traffic")
    runcfg = HFLRunConfig(max_batches=25, seed=seed)
    res = continuous_vs_static(cfg, ds, int(sensors[0]), runcfg,
                               rounds=rounds)
    emit("fig6_static_mse", res["static_mse"] * 1e6,
         f"mse={res['static_mse']:.5f}")
    emit("fig6_continual_mse", res["continual_mse"] * 1e6,
         f"mse={res['continual_mse']:.5f};"
         f"improves={res['continual_mse'] < res['static_mse']}")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--out", default="results/fig6_curves.json")
    args = ap.parse_args()
    run(rounds=args.rounds, out_json=args.out)
    run_continual_vs_static()
