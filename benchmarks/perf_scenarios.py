"""Scenario × policy grid on the co-simulation scenario engine.

Runs every scenario (stragglers, device mobility, multi-tenant edges,
combined churn) under three policies on the same seeded workload:

  static    no reactive loop — the initial HFLOP deployment rides out
            every perturbation
  reactive  unconstrained reactive loop (reclusters whenever alarms say)
  budgeted  the same loop metered by a ``ReconfigBudget`` — optional
            reclusterings are deferred once the migration spend hits
            the cap

Per cell it reports p95 / rounds-completed / reclusters / budget spend,
re-runs the cell with the same seed and checks the event-trace
fingerprints match (``det=ok``), and per scenario summarizes how much
of the unconstrained policy's p95 gain the budget-capped policy
recovers and what it spent doing so.

Cells of the grid are independent by construction, so ``--jobs N``
fans them out over a process pool (``repro.sim.scenarios.run_grid``)
— the full grid drops to wall-clock seconds; output order and every
reported number are identical to the serial run.

  python -m benchmarks.perf_scenarios            # full grid (120 s)
  python -m benchmarks.perf_scenarios --smoke    # fast CI grid (60 s)
  python -m benchmarks.perf_scenarios --jobs 4   # grid over 4 workers
  python -m benchmarks.perf_scenarios --scenario mobility --budget 15
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Dict, Optional, Sequence, Tuple

from repro.sim.scenarios import (POLICIES, SCENARIOS, ScenarioResult,
                                 default_budget_total, run_grid,
                                 run_scenario)
from repro.telemetry import Telemetry

from benchmarks.common import emit

DEFAULT_SCENARIOS = ("straggler", "mobility", "multi_tenant", "churn")


def run(duration_s: float = 120.0, seed: int = 0,
        budget_total: Optional[float] = None,
        scenarios: Sequence[str] = DEFAULT_SCENARIOS,
        check_determinism: bool = True, jobs: int = 1,
        ) -> Dict[Tuple[str, str], ScenarioResult]:
    budget = (budget_total if budget_total is not None
              else default_budget_total())
    grid = run_grid(scenarios, POLICIES, jobs=jobs,
                    check_determinism=check_determinism, seed=seed,
                    duration_s=duration_s, budget_total=budget)
    cells: Dict[Tuple[str, str], ScenarioResult] = {}
    for sc_name in scenarios:
        for policy in POLICIES:
            res, det_ok = grid[(sc_name, policy)]
            det = "" if det_ok is None else (";det=ok" if det_ok
                                             else ";det=FAIL")
            cells[(sc_name, policy)] = res
            spent = ("" if policy != "budgeted" else
                     f";budget_spent={res.budget_spent:.1f}"
                     f"/{res.budget_total:.1f};vetoes={res.budget_vetoes}")
            emit(f"scenario_{sc_name}_{policy}", res.p95 * 1000,
                 f"p95={res.p95:.2f};p50={res.p50:.2f};"
                 f"rounds={res.rounds_completed};"
                 f"reclusters={res.reclusters};drops={res.drops};"
                 f"moves={res.moves}{spent}{det}")
    for sc_name in scenarios:
        st = cells[(sc_name, "static")]
        rx = cells[(sc_name, "reactive")]
        bd = cells[(sc_name, "budgeted")]
        gain = st.p95 - rx.p95
        frac = (st.p95 - bd.p95) / gain if gain > 0 else math.nan
        within = bd.budget_spent <= bd.budget_total + 1e-9
        emit(f"scenario_{sc_name}_budget_summary", frac * 1e6,
             f"recovered_frac={frac:.2f};gain_ms={gain:.2f};"
             f"spent={bd.budget_spent:.1f}/{bd.budget_total:.1f};"
             f"within_budget={'yes' if within else 'NO'}")
        if not within:
            print(f"# WARNING: {sc_name} budgeted policy overspent "
                  f"({bd.budget_spent:.1f} > {bd.budget_total:.1f})",
                  file=sys.stderr)

    # one instrumented budgeted cell (serial): surface the decision
    # audit + ReconfigBudget ledger through the telemetry registry so
    # the BENCH artifact records spend / deferral / overrun counts
    sc_audit = scenarios[-1]
    tel = Telemetry()
    res = run_scenario(SCENARIOS[sc_audit](), policy="budgeted",
                       seed=seed, duration_s=duration_s,
                       budget_total=budget, telemetry=tel)
    m = tel.metrics
    audit = tel.audit.counts()
    emit(f"scenario_{sc_audit}_budgeted_audit", len(tel.audit) * 1.0,
         f"applied={audit['applied']};forced={audit['forced']};"
         f"deferred={audit['deferred']};vetoed={audit['vetoed']};"
         f"noted={audit['noted']};"
         f"attempts={m.value('reconfig.attempts'):.0f};"
         f"cost_spent={m.value('reconfig.cost_spent'):.1f};"
         f"budget_spent={m.value('reconfig.budget_spent'):.1f};"
         f"overrun={m.value('reconfig.budget_overrun'):.1f};"
         f"spans={len(tel.tracer.spans)}")
    if abs(m.value("reconfig.budget_spent") - res.budget_spent) > 1e-9:
        print(f"# WARNING: registry budget_spent "
              f"{m.value('reconfig.budget_spent'):.1f} != scenario "
              f"{res.budget_spent:.1f}", file=sys.stderr)
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget", type=float, default=None,
                    help="reconfig budget for the 'budgeted' policy "
                         "(edge-compute-seconds; default: 2 migrations)")
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="restrict the grid (repeatable)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI grid (short horizon)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="process-pool workers for the grid cells "
                         "(cells are independent; 1 = serial)")
    ap.add_argument("--no-determinism-check", action="store_true")
    args = ap.parse_args()
    duration = 60.0 if args.smoke else args.duration
    print("name,us_per_call,derived")
    cells = run(duration_s=duration, seed=args.seed,
                budget_total=args.budget,
                scenarios=tuple(args.scenario) if args.scenario
                else DEFAULT_SCENARIOS,
                check_determinism=not args.no_determinism_check,
                jobs=args.jobs)
    print("\nscenario      policy    p95 ms  rounds  reclusters  "
          "budget", file=sys.stderr)
    for (sc, pol), res in cells.items():
        b = ("-" if pol != "budgeted"
             else f"{res.budget_spent:.0f}/{res.budget_total:.0f}"
             + (f" ({res.budget_vetoes} vetoed)" if res.budget_vetoes
                else ""))
        print(f"{sc:13s} {pol:9s} {res.p95:7.2f} {res.rounds_completed:6d} "
              f"{res.reclusters:10d}  {b}", file=sys.stderr)


if __name__ == "__main__":
    main()
