"""HFLOP solvers.

  - ``solve_bruteforce``   exact enumeration (tiny instances; test oracle)
  - ``solve_bnb``          exact LP-relaxation branch & bound (own simplex)
  - ``solve_greedy``       capacity-aware greedy + edge-closing pass
  - ``local_search``       vectorized move/close/open improvement loop
  - ``solve_heuristic``    greedy + local search (the scalable path)
  - ``solve_uncapacitated``paper's Fig. 9 lower-bound variant
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

from repro.core.hflop import (HFLOPInstance, HFLOPSolution, build_ilp,
                              is_feasible, objective)
from repro.core.milp import solve_milp


# ---------------------------------------------------------------------------
# exact: brute force (oracle)
# ---------------------------------------------------------------------------

def solve_bruteforce(inst: HFLOPInstance) -> HFLOPSolution:
    t0 = time.perf_counter()
    n, m = inst.n, inst.m
    if (m + 1) ** n > 5_000_000:
        raise ValueError("instance too large for brute force")
    best = None
    best_cost = np.inf
    assign = np.full(n, -1, int)
    load = np.zeros(m)

    def rec(i: int, partial_local: float):
        nonlocal best, best_cost
        if partial_local >= best_cost:
            return
        if i == n:
            if int(np.sum(assign >= 0)) < inst.T:
                return
            cost = objective(inst, assign)
            if cost < best_cost:
                best_cost = cost
                best = assign.copy()
            return
        # option: skip device (only useful if enough devices remain)
        if (n - i - 1) + int(np.sum(assign[:i] >= 0)) >= inst.T:
            assign[i] = -1
            rec(i + 1, partial_local)
        for j in range(m):
            if load[j] + inst.lam[i] <= inst.r[j] + 1e-12:
                assign[i] = j
                load[j] += inst.lam[i]
                rec(i + 1, partial_local + inst.c_d[i, j] * inst.l)
                load[j] -= inst.lam[i]
        assign[i] = -1

    rec(0, 0.0)
    if best is None:
        return HFLOPSolution(np.full(n, -1), np.inf, optimal=False,
                             solver="bruteforce",
                             wall_time_s=time.perf_counter() - t0)
    return HFLOPSolution(best, best_cost, optimal=True, solver="bruteforce",
                         wall_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# greedy + local search
# ---------------------------------------------------------------------------

def _assignment_cost_components(inst, assign):
    ok = assign >= 0
    local = np.zeros(inst.n)
    local[ok] = inst.c_d[np.arange(inst.n)[ok], assign[ok]] * inst.l
    return local


def solve_greedy(inst: HFLOPInstance) -> HFLOPSolution:
    """Capacity-aware greedy: place hard-to-fit devices first at their
    cheapest feasible edge (open cost amortized), then close unprofitable
    edges, then drop surplus devices if T < n."""
    t0 = time.perf_counter()
    n, m = inst.n, inst.m
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    opened = np.zeros(m, bool)
    order = np.argsort(-inst.lam)                      # big consumers first
    for i in order:
        costs = inst.c_d[i] * inst.l + np.where(opened, 0.0, inst.c_e)
        feas = load + inst.lam[i] <= inst.r + 1e-12
        costs = np.where(feas, costs, np.inf)
        j = int(np.argmin(costs))
        if np.isfinite(costs[j]):
            assign[i] = j
            load[j] += inst.lam[i]
            opened[j] = True
    # close-edge pass: move everyone off an edge if it saves cost
    for j in np.argsort(np.bincount(assign[assign >= 0] + 0,
                                    minlength=m))[:m]:
        if not opened[j]:
            continue
        members = np.nonzero(assign == j)[0]
        if members.size == 0:
            opened[j] = False
            continue
        # cheapest feasible relocation per member (to other open edges)
        delta = 0.0
        moves = {}
        load2 = load.copy()
        ok = True
        for i in members[np.argsort(-inst.lam[members])]:
            costs = inst.c_d[i] * inst.l
            feas = (load2 + inst.lam[i] <= inst.r + 1e-12) & opened
            feas[j] = False
            costs = np.where(feas, costs, np.inf)
            k = int(np.argmin(costs))
            if not np.isfinite(costs[k]):
                ok = False
                break
            moves[i] = k
            load2[k] += inst.lam[i]
            delta += (inst.c_d[i, k] - inst.c_d[i, j]) * inst.l
        if ok and delta < inst.c_e[j] - 1e-12:
            for i, k in moves.items():
                assign[i] = k
            load = load2
            load[j] = 0.0
            opened[j] = False
    # participation trimming (T < n): dropping a device always saves >= 0
    surplus = int(np.sum(assign >= 0)) - inst.T
    if surplus > 0:
        local = _assignment_cost_components(inst, assign)
        for i in np.argsort(-local):
            if surplus <= 0 or assign[i] < 0:
                break
            if local[i] <= 0:
                break
            load[assign[i]] -= inst.lam[i]
            assign[i] = -1
            surplus -= 1
    cost = objective(inst, assign) if np.sum(assign >= 0) >= inst.T else np.inf
    return HFLOPSolution(assign, cost, optimal=False, solver="greedy",
                         wall_time_s=time.perf_counter() - t0)


def local_search(inst: HFLOPInstance, sol: HFLOPSolution,
                 max_iters: int = 10_000) -> HFLOPSolution:
    """Vectorized best-improvement: single-device relocations (with edge
    open/close bookkeeping) until no move improves."""
    t0 = time.perf_counter()
    n, m = inst.n, inst.m
    if not np.isfinite(sol.cost) or not is_feasible(inst, sol.assign):
        return sol                      # nothing feasible to improve
    assign = sol.assign.copy()
    for _ in range(max_iters):
        ok = assign >= 0
        load = np.zeros(m)
        np.add.at(load, assign[ok], inst.lam[ok])
        counts = np.zeros(m, int)
        np.add.at(counts, assign[ok], 1)
        opened = counts > 0
        cur_local = np.where(ok, inst.c_d[np.arange(n),
                                          np.clip(assign, 0, m - 1)], 0.0)
        cur_local = cur_local * inst.l * ok
        # delta[i, j] = cost change of moving device i to edge j
        open_cost = np.where(opened, 0.0, inst.c_e)[None, :]
        close_save = np.where(ok & (counts[np.clip(assign, 0, m - 1)] == 1),
                              inst.c_e[np.clip(assign, 0, m - 1)], 0.0)
        delta = (inst.c_d * inst.l + open_cost
                 - cur_local[:, None] - close_save[:, None])
        feas = load[None, :] + inst.lam[:, None] <= inst.r[None, :] + 1e-12
        same = np.zeros((n, m), bool)
        same[np.arange(n)[ok], assign[ok]] = True
        delta = np.where(feas & ~same, delta, np.inf)
        i, j = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[i, j] >= -1e-12:
            break
        assign[i] = j
    cost = objective(inst, assign)
    return HFLOPSolution(assign, cost, optimal=False,
                         solver=sol.solver + "+ls",
                         wall_time_s=sol.wall_time_s
                         + time.perf_counter() - t0)


def solve_heuristic(inst: HFLOPInstance) -> HFLOPSolution:
    return local_search(inst, solve_greedy(inst))


# ---------------------------------------------------------------------------
# exact: LP-relaxation branch & bound
# ---------------------------------------------------------------------------

def _round_lp(inst: HFLOPInstance, xfrac: np.ndarray) -> Optional[np.ndarray]:
    """Rounding heuristic fed to the B&B: assign each device to its
    largest-x edge if capacity admits (greedy by fractional mass)."""
    n, m = inst.n, inst.m
    xm = xfrac[:n * m].reshape(n, m)
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    order = np.argsort(-np.max(xm, axis=1))
    for i in order:
        for j in np.argsort(-xm[i]):
            if xm[i, j] < 1e-9:
                break
            if load[j] + inst.lam[i] <= inst.r[j] + 1e-12:
                assign[i] = j
                load[j] += inst.lam[i]
                break
    if int(np.sum(assign >= 0)) < inst.T:
        return None
    v = np.zeros(n * m + m)
    for i in range(n):
        if assign[i] >= 0:
            v[i * m + assign[i]] = 1.0
    for j in np.unique(assign[assign >= 0]):
        v[n * m + j] = 1.0
    return v


def solve_bnb(inst: HFLOPInstance, time_limit_s: float = 600.0,
              max_nodes: int = 200_000) -> HFLOPSolution:
    t0 = time.perf_counter()
    ilp = build_ilp(inst)
    warm = solve_heuristic(inst)
    inc = None
    if np.isfinite(warm.cost):
        inc = np.zeros(ilp.c.shape[0])
        for i in range(inst.n):
            if warm.assign[i] >= 0:
                inc[ilp.x_index(i, warm.assign[i])] = 1.0
        for j in np.unique(warm.assign[warm.assign >= 0]):
            inc[ilp.y_index(j)] = 1.0
    prio = np.zeros(ilp.c.shape[0])
    prio[inst.n * inst.m:] = 1.0                      # branch y first
    res = solve_milp(ilp.c, ilp.A, ilp.b, incumbent_x=inc,
                     branch_priority=prio,
                     rounding=lambda xf: _round_lp(inst, xf),
                     max_nodes=max_nodes, time_limit_s=time_limit_s)
    if res.x is None:
        return HFLOPSolution(np.full(inst.n, -1), np.inf, optimal=False,
                             solver="bnb", nodes_explored=res.nodes,
                             wall_time_s=time.perf_counter() - t0)
    xm = res.x[:inst.n * inst.m].reshape(inst.n, inst.m)
    assign = np.where(xm.max(axis=1) > 0.5, np.argmax(xm, axis=1), -1)
    return HFLOPSolution(assign, objective(inst, assign),
                         optimal=res.status == "optimal", solver="bnb",
                         nodes_explored=res.nodes,
                         wall_time_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# uncapacitated variant (paper Fig. 9 lower bound)
# ---------------------------------------------------------------------------

def solve_uncapacitated(inst: HFLOPInstance,
                        exact: bool = False) -> HFLOPSolution:
    """With r_j = inf the problem is classic UFL.  Greedy+LS by default;
    ``exact=True`` routes through the B&B."""
    un = inst.uncapacitated()
    if exact:
        sol = solve_bnb(un)
    else:
        sol = solve_heuristic(un)
    sol.solver = "uncap-" + sol.solver
    return sol
