"""Decision audit log for the orchestration control loop.

Every orchestration action taken (or declined) by `ReactiveLoop` /
`LearningController` / `CoSim.apply_deployment` records *why*: the
trigger that fired (drift alarm, windowed-p95 breach, NODE_FAILURE,
unreliable-device mark, ...), the evidence values behind it (measured
p95 vs threshold, drift MSE, dropped-epoch counts), the budget charge,
and the outcome:

- ``applied``  — the action went through (budget charged if metered)
- ``forced``   — applied despite an exhausted budget (visible overrun)
- ``deferred`` — the loop wanted to act but the budget said no
- ``vetoed``   — `apply_deployment` itself refused the charge
- ``noted``    — an observation that informed later decisions
                 (failure seen, straggler drops, device move)

The audit log is additive observation only: it never mutates the
`actions` list, the budget ledger, or any simulation state, so control
fingerprints stay bit-identical with auditing on or off.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional

OUTCOMES = ("applied", "forced", "deferred", "vetoed", "noted")


@dataclass(frozen=True)
class AuditRecord:
    t: float
    action: str
    trigger: str
    outcome: str
    evidence: Mapping[str, object] = field(default_factory=dict)
    cost: float = 0.0
    charged: bool = False
    forced: bool = False

    def as_dict(self) -> Dict[str, object]:
        return {"t": self.t, "action": self.action,
                "trigger": self.trigger, "outcome": self.outcome,
                "evidence": dict(self.evidence), "cost": self.cost,
                "charged": self.charged, "forced": self.forced}


class DecisionAudit:
    def __init__(self) -> None:
        self.records: List[AuditRecord] = []

    def record(self, t: float, action: str, trigger: str, outcome: str,
               evidence: Optional[Mapping[str, object]] = None,
               cost: float = 0.0, charged: bool = False,
               forced: bool = False) -> AuditRecord:
        if outcome not in OUTCOMES:
            raise ValueError(f"unknown outcome {outcome!r}; "
                             f"expected one of {OUTCOMES}")
        rec = AuditRecord(t=float(t), action=action, trigger=trigger,
                          outcome=outcome, evidence=dict(evidence or {}),
                          cost=float(cost), charged=charged,
                          forced=forced)
        self.records.append(rec)
        return rec

    def by_action(self, action: str) -> List[AuditRecord]:
        return [r for r in self.records if r.action == action]

    def by_outcome(self, outcome: str) -> List[AuditRecord]:
        return [r for r in self.records if r.outcome == outcome]

    def counts(self) -> Dict[str, int]:
        """Record count per outcome (zero-filled over OUTCOMES)."""
        out = {o: 0 for o in OUTCOMES}
        for r in self.records:
            out[r.outcome] += 1
        return out

    def as_dicts(self) -> List[Dict[str, object]]:
        return [r.as_dict() for r in self.records]

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.as_dict()) + "\n")

    def __len__(self) -> int:
        return len(self.records)
