"""Block-table page pool for the paged KV/latent cache.

The pool owns a fixed budget of ``num_pages`` pages of ``page_size``
tokens each and hands them out to sequences on demand: a sequence's
tokens ``[0, L)`` live at logical slots — token ``t`` in page
``block_table[t // page_size]``, offset ``t % page_size`` — so per-
sequence cache footprint is ``ceil(L / page_size)`` pages instead of a
dense ``max_len`` reservation.  That is the whole concurrency lever:
at fixed cache HBM a replica admits as many sequences as *actual*
tokens fit, not as many worst-case reservations fit.

Bookkeeping is numpy/stdlib-only (the jax page *arrays* live in the
engine; the pool only manages page ids).  Allocation is a FIFO free
list — deterministic, O(1) per page — and every mutation keeps three
invariants the property tests pin:

  * no double allocation: a page id is in at most one block table,
    and never both allocated and free;
  * conservation: ``free_pages + allocated_pages == num_pages``;
  * block-table consistency: ``len(block_table(seq)) ==
    pages_for(length(seq))`` after any admit/extend/release churn.

Occupancy and internal fragmentation (allocated-but-unused token
slack) are exposed as telemetry gauges when a :class:`Telemetry`
facade is attached.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Set

from repro.telemetry import Telemetry, maybe as _maybe_tel


class PagesExhausted(RuntimeError):
    """Raised when an allocation/extension exceeds the free-page budget."""


class PagePool:
    def __init__(self, num_pages: int, page_size: int,
                 telemetry: Optional[Telemetry] = None):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self._free: Deque[int] = deque(range(num_pages))
        self._free_set: Set[int] = set(range(num_pages))
        self._tables: Dict[int, List[int]] = {}     # seq -> page ids
        self._lengths: Dict[int, int] = {}          # seq -> token count
        self._tel = _maybe_tel(telemetry)
        self._publish()

    # -- sizing -------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens (>= 1 token -> >= 1
        page; 0 tokens -> 0 pages)."""
        return -(-max(int(n_tokens), 0) // self.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        """Fraction of the page budget currently allocated."""
        return self.allocated_pages / self.num_pages

    @property
    def internal_fragmentation(self) -> float:
        """Allocated-but-unused token slack: 1 - used/capacity over the
        allocated pages (0.0 when nothing is allocated)."""
        cap = self.allocated_pages * self.page_size
        if cap == 0:
            return 0.0
        used = sum(self._lengths.values())
        return 1.0 - used / cap

    def can_allocate(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    # -- sequence lifecycle -------------------------------------------------

    def allocate(self, seq: int, n_tokens: int) -> List[int]:
        """Open ``seq`` with pages for ``n_tokens`` tokens.  Returns the
        block table (page ids in logical order)."""
        if seq in self._tables:
            raise ValueError(f"sequence {seq} already has an allocation")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            raise PagesExhausted(
                f"need {need} pages for {n_tokens} tokens, "
                f"{len(self._free)} free")
        table = [self._take() for _ in range(need)]
        self._tables[seq] = table
        self._lengths[seq] = int(n_tokens)
        self._publish()
        return list(table)

    def extend(self, seq: int, n_tokens: int) -> List[int]:
        """Grow ``seq`` to ``n_tokens`` *total* tokens, allocating pages
        as logical length crosses page boundaries.  Returns the newly
        allocated page ids (often empty: within-page growth is free)."""
        table = self._tables.get(seq)
        if table is None:
            raise KeyError(f"sequence {seq} has no allocation")
        if n_tokens < self._lengths[seq]:
            raise ValueError("extend cannot shrink a sequence")
        need = self.pages_for(n_tokens) - len(table)
        if need > len(self._free):
            raise PagesExhausted(
                f"need {need} more pages for sequence {seq}, "
                f"{len(self._free)} free")
        new = [self._take() for _ in range(need)]
        table.extend(new)
        self._lengths[seq] = int(n_tokens)
        self._publish()
        return new

    def release(self, seq: int) -> int:
        """Return ``seq``'s pages to the free list.  Raises ``KeyError``
        on double release.  Returns the number of pages freed."""
        table = self._tables.pop(seq)       # KeyError on double release
        del self._lengths[seq]
        for pid in table:
            self._free.append(pid)
            self._free_set.add(pid)
        self._publish()
        return len(table)

    # -- views --------------------------------------------------------------

    def block_table(self, seq: int) -> List[int]:
        return list(self._tables[seq])

    def length(self, seq: int) -> int:
        return self._lengths[seq]

    @property
    def sequences(self) -> List[int]:
        return sorted(self._tables)

    # -- snapshot (engine.measure state save/restore) -----------------------

    def snapshot(self) -> dict:
        return {"free": list(self._free),
                "tables": {s: list(t) for s, t in self._tables.items()},
                "lengths": dict(self._lengths)}

    def restore(self, state: dict) -> None:
        self._free = deque(state["free"])
        self._free_set = set(state["free"])
        self._tables = {s: list(t) for s, t in state["tables"].items()}
        self._lengths = dict(state["lengths"])
        self._publish()

    # -- internals ----------------------------------------------------------

    def _take(self) -> int:
        pid = self._free.popleft()
        self._free_set.discard(pid)
        return pid

    def _publish(self) -> None:
        if self._tel is not None:
            m = self._tel.metrics
            m.gauge("page_pool.free_pages").set(float(len(self._free)))
            m.gauge("page_pool.allocated_pages").set(
                float(self.allocated_pages))
            m.gauge("page_pool.occupancy").set(self.occupancy)
            m.gauge("page_pool.internal_fragmentation").set(
                self.internal_fragmentation)
            m.gauge("page_pool.sequences").set(float(len(self._tables)))

    def check_invariants(self) -> None:
        """Assert the pool invariants (used by the property tests)."""
        allocated = [p for t in self._tables.values() for p in t]
        assert len(allocated) == len(set(allocated)), "double allocation"
        assert len(self._free) == len(self._free_set)
        assert not (set(allocated) & self._free_set), "page both states"
        assert len(allocated) + len(self._free) == self.num_pages
        for s, t in self._tables.items():
            assert len(t) == self.pages_for(self._lengths[s])
