"""HFLOP — the inference-aware Hierarchical FL Orchestration Problem
(paper §IV).

    minimize   sum_ij x_ij c^d_ij l  +  sum_j y_j c^e_j            (1)
    subject to x_ij <= y_j                                          (2)
               y_j <= sum_i x_ij                                    (3)
               sum_i x_ij * lambda_i <= r_j                         (4)
               sum_j x_ij <= 1                                      (5)
               sum_ij x_ij >= T                                     (6)
               x, y binary                                          (7)

A solution assigns device i to edge aggregator j (``assign[i] = j``) or
leaves it unassigned (``assign[i] = -1``; only allowed when T < n).
HFLOP generalizes capacitated facility location with unsplittable flows
(NP-hard), so the package ships an exact branch-and-bound solver for
small/medium instances plus greedy + local-search heuristics for scale.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class HFLOPInstance:
    """Problem data.  Shapes: c_d (n,m), c_e (m,), lam (n,), r (m,)."""
    c_d: np.ndarray
    c_e: np.ndarray
    lam: np.ndarray
    r: np.ndarray
    l: int = 2                      # local aggregation rounds per global
    T: Optional[int] = None         # min participating devices (None -> n)

    def __post_init__(self):
        object.__setattr__(self, "c_d", np.asarray(self.c_d, np.float64))
        object.__setattr__(self, "c_e", np.asarray(self.c_e, np.float64))
        object.__setattr__(self, "lam", np.asarray(self.lam, np.float64))
        object.__setattr__(self, "r", np.asarray(self.r, np.float64))
        if self.T is None:
            object.__setattr__(self, "T", self.n)
        if self.c_d.shape != (self.n, self.m):
            raise ValueError("c_d must be (n, m)")

    @property
    def n(self) -> int:
        return self.c_d.shape[0]

    @property
    def m(self) -> int:
        return self.c_d.shape[1]

    def uncapacitated(self) -> "HFLOPInstance":
        """The paper's Fig. 9 lower-bound variant: infinite r_j."""
        return HFLOPInstance(self.c_d, self.c_e, self.lam,
                             np.full(self.m, np.inf), self.l, self.T)


@dataclass
class HFLOPSolution:
    assign: np.ndarray              # (n,) int, -1 = not participating
    cost: float
    optimal: bool = False
    solver: str = ""
    nodes_explored: int = 0
    wall_time_s: float = 0.0
    #: solver-specific diagnostics — the decomposed solver records
    #: per-phase wall times, region counts, repair statistics and a
    #: cheap lower bound here (``meta["phase_s"]``, ``meta["gap_vs_lb"]``)
    meta: Dict[str, object] = field(default_factory=dict)

    @property
    def y(self) -> np.ndarray:
        """Open-edge indicator, vectorized — this runs inside every
        reactive recluster, so no per-edge Python loop."""
        m = 1 + (int(self.assign.max()) if self.assign.size else -1)
        if m <= 0:
            return np.zeros(0, dtype=bool)
        ok = self.assign >= 0
        return np.bincount(self.assign[ok], minlength=m).astype(bool)

    def x_matrix(self, m: int) -> np.ndarray:
        n = self.assign.shape[0]
        x = np.zeros((n, m), bool)
        ok = self.assign >= 0
        x[np.arange(n)[ok], self.assign[ok]] = True
        return x


def objective(inst: HFLOPInstance, assign: np.ndarray) -> float:
    """Objective (1) for an assignment vector."""
    assign = np.asarray(assign)
    ok = assign >= 0
    local = float(np.sum(inst.c_d[np.arange(inst.n)[ok], assign[ok]])) * inst.l
    open_edges = np.unique(assign[ok])
    return local + float(np.sum(inst.c_e[open_edges]))


def violations(inst: HFLOPInstance, assign: np.ndarray) -> List[str]:
    """Empty list iff ``assign`` is feasible.  Per-edge loads come from
    one ``np.bincount`` instead of an m-pass scan — this is on the
    reactive-recluster hot path."""
    out = []
    assign = np.asarray(assign)
    if assign.shape != (inst.n,):
        return [f"assign shape {assign.shape} != ({inst.n},)"]
    if np.any(assign >= inst.m):
        out.append("assignment to nonexistent edge")
    participating = int(np.sum(assign >= 0))
    if participating < inst.T:
        out.append(f"participation {participating} < T={inst.T}")
    valid = (assign >= 0) & (assign < inst.m)
    loads = np.bincount(assign[valid], weights=inst.lam[valid],
                        minlength=inst.m)
    for j in np.nonzero(loads > inst.r + 1e-9)[0]:
        out.append(f"edge {j}: load {loads[j]:.3f} > r={inst.r[j]:.3f}")
    return out


def is_feasible(inst: HFLOPInstance, assign: np.ndarray) -> bool:
    return not violations(inst, assign)


# ---------------------------------------------------------------------------
# ILP matrix construction (used by the LP-relaxation branch & bound)
# ---------------------------------------------------------------------------

@dataclass
class ILP:
    """min c.v  s.t.  A v <= b,  0 <= v <= 1,  v binary.
    Variable layout: v = [x_00..x_0m-1, x_10.., ..., y_0..y_m-1]."""
    c: np.ndarray
    A: np.ndarray
    b: np.ndarray
    n: int
    m: int

    def x_index(self, i: int, j: int) -> int:
        return i * self.m + j

    def y_index(self, j: int) -> int:
        return self.n * self.m + j


def build_ilp(inst: HFLOPInstance) -> ILP:
    """Constraint-matrix assembly with index arithmetic: every block is
    written into one preallocated ``(n_rows, nv)`` array through fancy
    indexing (no per-row Python loops, no list of dense rows), so the
    MILP baseline survives the larger subsample sizes the decomposed
    solver is benchmarked against.  Row order matches the original
    loop construction exactly: (2) i-major, (3), (4) finite-capacity
    edges in index order, (5), (6)."""
    n, m = inst.n, inst.m
    nv = n * m + m
    c = np.concatenate([(inst.c_d * inst.l).reshape(-1), inst.c_e])
    fin = np.nonzero(np.isfinite(inst.r))[0]       # edges with a cap row
    n_rows = n * m + m + fin.size + n + 1
    A = np.zeros((n_rows, nv))
    b = np.zeros(n_rows)
    xi = np.arange(n * m)                           # x_ij column ids
    # (2) x_ij - y_j <= 0 — row i*m+j touches columns (i*m+j, n*m+j)
    A[xi, xi] = 1.0
    A[xi, n * m + xi % m] = -1.0
    # (3) y_j - sum_i x_ij <= 0
    r3 = n * m + np.arange(m)
    A[r3, n * m + np.arange(m)] = 1.0
    A[r3[:, None], np.arange(m)[:, None] + m * np.arange(n)[None, :]] = -1.0
    # (4) sum_i lam_i x_ij <= r_j   (skip infinite capacities)
    r4 = n * m + m + np.arange(fin.size)
    A[r4[:, None], fin[:, None] + m * np.arange(n)[None, :]] = inst.lam
    b[r4] = inst.r[fin]
    # (5) sum_j x_ij <= 1
    r5 = n * m + m + fin.size + np.arange(n)
    A[r5[:, None], m * np.arange(n)[:, None] + np.arange(m)[None, :]] = 1.0
    b[r5] = 1.0
    # (6) -sum x_ij <= -T
    A[-1, :n * m] = -1.0
    b[-1] = -float(inst.T)
    return ILP(c=c, A=A, b=b, n=n, m=m)


# ---------------------------------------------------------------------------
# Random instance generators (Fig. 2 / Fig. 9 setups)
# ---------------------------------------------------------------------------

def random_instance(n: int, m: int, seed: int = 0, l: int = 2,
                    T: Optional[int] = None,
                    capacity_slack: float = 1.5) -> HFLOPInstance:
    """Generic random instance: uniform costs, uniform rates, capacities
    scaled so total capacity = slack * total load (paper §V-D draws
    workloads and capacities uniformly at random)."""
    rng = np.random.default_rng(seed)
    c_d = rng.uniform(0.0, 1.0, (n, m))
    c_e = rng.uniform(0.5, 1.5, m)
    lam = rng.uniform(0.1, 1.0, n)
    raw = rng.uniform(0.5, 1.5, m)
    r = raw / raw.sum() * lam.sum() * capacity_slack
    return HFLOPInstance(c_d, c_e, lam, r, l=l, T=T)


def paper_cost_instance(n: int, m: int, seed: int = 0, l: int = 2,
                        capacity_slack: float = 1.5) -> HFLOPInstance:
    """The Fig. 9 setup: each device has exactly one zero-cost edge (its
    LAN host), every other edge costs 1; edge-cloud cost 1; all devices
    must participate; workloads/capacities uniform at random."""
    rng = np.random.default_rng(seed)
    c_d = np.ones((n, m))
    free = rng.integers(0, m, n)
    c_d[np.arange(n), free] = 0.0
    c_e = np.ones(m)
    lam = rng.uniform(0.1, 1.0, n)
    raw = rng.uniform(0.5, 1.5, m)
    r = raw / raw.sum() * lam.sum() * capacity_slack
    return HFLOPInstance(c_d, c_e, lam, r, l=l, T=n)
