"""HFLOP solvers.

  - ``solve_bruteforce``   exact enumeration (tiny instances; test oracle)
  - ``solve_bnb``          exact LP-relaxation branch & bound (own simplex)
  - ``solve_greedy``       capacity-aware greedy + edge-closing pass
  - ``local_search``       vectorized move/close/open improvement loop
  - ``solve_heuristic``    greedy + local search (the scalable path)
  - ``solve_decomposed``   hierarchically decomposed solver (10^5-10^6
                           devices: partition -> per-region sub-solve ->
                           stitch -> polish)
  - ``solve_uncapacitated``paper's Fig. 9 lower-bound variant

The greedy / rounding passes are *sequential* heuristics (each device's
choice depends on the loads left by every earlier device), vectorized
here by chunked speculation: evaluate a whole chunk of devices against
the chunk-start state in one ``(chunk, m)`` NumPy pass, then commit the
longest prefix whose picks provably match the sequential replay.  The
two regime changes that can invalidate a speculated pick are (a) an
earlier in-chunk pick *opening* a new edge — which lowers that edge's
cost for everyone after it — and (b) an edge *filling up* mid-chunk.
Feasibility only ever shrinks as devices commit, so until one of those
events the batch argmin and the sequential argmin coincide (the
sequential feasible set is a superset-masked view of the same cost row,
and ``np.argmin``'s lowest-index tie-break is identical).  Chunks whose
running loads graze a capacity bound within float noise are replayed
scalar so summation-order ULPs can never flip a decision: the
vectorized solvers are bit-compatible with the original per-device
loops (pinned by ``tests/test_solver_scale.py``).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.hflop import (HFLOPInstance, HFLOPSolution, build_ilp,
                              is_feasible, objective)
from repro.core.milp import solve_milp
from repro.core.partition import (AnyInstance, LanHFLOPInstance,
                                  partition_instance, sub_instance)
from repro.telemetry import (SpanTracer, Telemetry,
                             maybe as _maybe_tel)
from repro.telemetry.tracer import wall_clock

_CHUNK0 = 256                 # speculation chunk start size
_CHUNK_CELLS = 4_000_000      # cap chunk_rows * m (bounded memory)


def _chunk_cap(m: int) -> int:
    return max(_CHUNK0, _CHUNK_CELLS // max(m, 1))


def _cost_rows_fn(inst: AnyInstance) -> Callable[[np.ndarray], np.ndarray]:
    """Batch accessor for c_d rows — dense slice or implicit LAN rows."""
    if isinstance(inst, LanHFLOPInstance):
        return inst.cost_rows
    c_d = inst.c_d
    return lambda ids: c_d[ids]


def _objective_any(inst: AnyInstance, assign: np.ndarray) -> float:
    if isinstance(inst, LanHFLOPInstance):
        return inst.objective(assign)
    return objective(inst, assign)


def _local_costs_any(inst: AnyInstance, assign: np.ndarray) -> np.ndarray:
    if isinstance(inst, LanHFLOPInstance):
        return inst.local_costs(assign)
    return _assignment_cost_components(inst, assign)


# ---------------------------------------------------------------------------
# exact: brute force (oracle)
# ---------------------------------------------------------------------------

def solve_bruteforce(inst: HFLOPInstance) -> HFLOPSolution:
    t0 = wall_clock()
    n, m = inst.n, inst.m
    if (m + 1) ** n > 5_000_000:
        raise ValueError("instance too large for brute force")
    best = None
    best_cost = np.inf
    assign = np.full(n, -1, int)
    load = np.zeros(m)

    def rec(i: int, partial_local: float):
        nonlocal best, best_cost
        if partial_local >= best_cost:
            return
        if i == n:
            if int(np.sum(assign >= 0)) < inst.T:
                return
            cost = objective(inst, assign)
            if cost < best_cost:
                best_cost = cost
                best = assign.copy()
            return
        # option: skip device (only useful if enough devices remain)
        if (n - i - 1) + int(np.sum(assign[:i] >= 0)) >= inst.T:
            assign[i] = -1
            rec(i + 1, partial_local)
        for j in range(m):
            if load[j] + inst.lam[i] <= inst.r[j] + 1e-12:
                assign[i] = j
                load[j] += inst.lam[i]
                rec(i + 1, partial_local + inst.c_d[i, j] * inst.l)
                load[j] -= inst.lam[i]
        assign[i] = -1

    rec(0, 0.0)
    if best is None:
        return HFLOPSolution(np.full(n, -1), np.inf, optimal=False,
                             solver="bruteforce",
                             wall_time_s=wall_clock() - t0)
    return HFLOPSolution(best, best_cost, optimal=True, solver="bruteforce",
                         wall_time_s=wall_clock() - t0)


# ---------------------------------------------------------------------------
# chunked-speculation primitives (shared by greedy / close / rounding)
# ---------------------------------------------------------------------------

def _capacity_limit(picks, okmask, w, load, r):
    """Longest commit-safe prefix under capacity, assuming every earlier
    in-chunk pick lands.  Running per-edge loads come from a grouped
    cumsum (stable sort by edge keeps in-chunk order within each group).
    Returns ``(cut, guard)``: ``cut`` leading positions are safe;
    ``guard`` means some running load is within float noise of its bound
    and the caller must replay the chunk scalar to stay bit-exact."""
    sel = np.nonzero(okmask)[0]
    if sel.size == 0:
        return picks.shape[0], False
    srt = np.argsort(picks[sel], kind="stable")
    ps = picks[sel][srt]
    ws = w[sel][srt]
    cw = np.cumsum(ws)
    first = np.searchsorted(ps, ps, side="left")
    run = load[ps] + (cw - (cw[first] - ws[first]))
    margin = (r[ps] + 1e-12) - run
    if np.any(np.abs(margin) < 1e-9):
        return picks.shape[0], True
    bad = margin < 0.0
    if not bad.any():
        return picks.shape[0], False
    return int(sel[srt[bad]].min()), False


def _scalar_insert_chunk(rows, ids, lam, r, c_e, l, load, opened, assign):
    """Verbatim sequential insertion for one chunk (guard fallback)."""
    for k in range(ids.size):
        i = ids[k]
        costs = rows[k] * l + np.where(opened, 0.0, c_e)
        feas = load + lam[i] <= r + 1e-12
        costs = np.where(feas, costs, np.inf)
        j = int(np.argmin(costs))
        if np.isfinite(costs[j]):
            assign[i] = j
            load[j] += lam[i]
            opened[j] = True


def _greedy_insert(cost_rows, order, lam, r, c_e, l, load, opened, assign):
    """Chunk-speculated replay of the sequential cheapest-feasible-edge
    insertion.  Commits cut at the first in-chunk edge *open* (that pick
    is itself valid — commit through it) and before the first capacity
    overflow.  Mutates ``load`` / ``opened`` / ``assign`` in place."""
    m = r.shape[0]
    cap = _chunk_cap(m)
    pos, chunk = 0, _CHUNK0
    n_ord = order.shape[0]
    while pos < n_ord:
        ids = order[pos:pos + chunk]
        rows = cost_rows(ids)
        C = rows * l + np.where(opened, 0.0, c_e)[None, :]
        feas = load[None, :] + lam[ids][:, None] <= r[None, :] + 1e-12
        C = np.where(feas, C, np.inf)
        picks = np.argmin(C, axis=1)
        okm = np.isfinite(C[np.arange(ids.size), picks])
        cut = ids.size
        vo = np.nonzero(okm & ~opened[picks])[0]
        if vo.size:
            cut = int(vo[0]) + 1
        cap_cut, guard = _capacity_limit(picks[:cut], okm[:cut],
                                         lam[ids[:cut]], load, r)
        if guard:
            _scalar_insert_chunk(rows, ids, lam, r, c_e, l,
                                 load, opened, assign)
            pos += ids.size
            chunk = _CHUNK0
            continue
        cut = min(cut, cap_cut)
        com = okm[:cut]
        ci = ids[:cut][com]
        cp = picks[:cut][com]
        assign[ci] = cp
        np.add.at(load, cp, lam[ci])           # in-order adds, as sequential
        opened[cp] = True
        good = cut == ids.size
        pos += cut
        chunk = min(chunk * 4, cap) if good else _CHUNK0


def _relocation_trial(cost_rows, mem, j, lam, r, l, load, opened):
    """Trial relocation of every member of edge ``j`` onto other open
    edges (cheapest first per member, capacity-aware), chunk-speculated.
    Returns ``(moves, load2, delta)`` with ``delta`` accumulated in the
    exact sequential order (cumsum == repeated binary adds), or ``None``
    if some member cannot be relocated."""
    load2 = load.copy()
    moves = np.empty(mem.size, np.int64)
    deltas = np.empty(mem.size)
    cap = _chunk_cap(r.shape[0])
    pos, chunk = 0, _CHUNK0
    while pos < mem.size:
        ids = mem[pos:pos + chunk]
        rows = cost_rows(ids)
        C = rows * l
        feas = ((load2[None, :] + lam[ids][:, None] <= r[None, :] + 1e-12)
                & opened[None, :])
        feas[:, j] = False
        C = np.where(feas, C, np.inf)
        picks = np.argmin(C, axis=1)
        okm = np.isfinite(C[np.arange(ids.size), picks])
        cut = ids.size
        fail = False
        vb = np.nonzero(~okm)[0]
        if vb.size:
            cut = int(vb[0])
            fail = True
        cap_cut, guard = _capacity_limit(picks[:cut], np.ones(cut, bool),
                                         lam[ids[:cut]], load2, r)
        if guard:                               # scalar replay, bit-exact
            for k in range(ids.size):
                i = ids[k]
                costs = rows[k] * l
                f = (load2 + lam[i] <= r + 1e-12) & opened
                f[j] = False
                costs = np.where(f, costs, np.inf)
                kk = int(np.argmin(costs))
                if not np.isfinite(costs[kk]):
                    return None
                moves[pos + k] = kk
                deltas[pos + k] = (rows[k, kk] - rows[k, j]) * l
                load2[kk] += lam[i]
            pos += ids.size
            chunk = _CHUNK0
            continue
        if cap_cut < cut:
            cut = cap_cut
            fail = False
        cp = picks[:cut]
        moves[pos:pos + cut] = cp
        deltas[pos:pos + cut] = (rows[np.arange(cut), cp]
                                 - rows[:cut, j]) * l
        np.add.at(load2, cp, lam[ids[:cut]])
        good = cut == ids.size
        pos += cut
        if fail:
            return None
        chunk = min(chunk * 4, cap) if good else _CHUNK0
    delta = float(np.cumsum(deltas)[-1]) if mem.size else 0.0
    return moves, load2, delta


def _close_edges(cost_rows, lam, r, c_e, l, m, assign, load, opened):
    """Close-edge pass: for each open edge (fewest members first), move
    every member elsewhere if the relocation total beats the open cost.
    Mutates ``assign`` / ``load`` / ``opened`` in place."""
    for j in np.argsort(np.bincount(assign[assign >= 0] + 0,
                                    minlength=m))[:m]:
        if not opened[j]:
            continue
        members = np.nonzero(assign == j)[0]
        if members.size == 0:
            opened[j] = False
            continue
        mem = members[np.argsort(-lam[members])]
        res = _relocation_trial(cost_rows, mem, j, lam, r, l, load, opened)
        if res is None:
            continue
        moves, load2, delta = res
        if delta < c_e[j] - 1e-12:
            assign[mem] = moves
            load[:] = load2
            load[j] = 0.0
            opened[j] = False


# ---------------------------------------------------------------------------
# greedy + local search
# ---------------------------------------------------------------------------

def _assignment_cost_components(inst, assign):
    ok = assign >= 0
    local = np.zeros(inst.n)
    local[ok] = inst.c_d[np.arange(inst.n)[ok], assign[ok]] * inst.l
    return local


def solve_greedy(inst: AnyInstance) -> HFLOPSolution:
    """Capacity-aware greedy: place hard-to-fit devices first at their
    cheapest feasible edge (open cost amortized), then close unprofitable
    edges, then drop surplus devices if T < n.  Accepts dense or
    structured (LAN) instances; all passes are chunk-vectorized."""
    t0 = wall_clock()
    n, m = inst.n, inst.m
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    opened = np.zeros(m, bool)
    order = np.argsort(-inst.lam)                      # big consumers first
    rows_of = _cost_rows_fn(inst)
    _greedy_insert(rows_of, order, inst.lam, inst.r, inst.c_e, inst.l,
                   load, opened, assign)
    _close_edges(rows_of, inst.lam, inst.r, inst.c_e, inst.l, m,
                 assign, load, opened)
    # participation trimming (T < n): dropping a device always saves >= 0.
    # Sorted by descending local cost, the sequential loop stops at the
    # first non-positive entry — i.e. it drops the prefix of positive
    # local costs, capped at the surplus.
    surplus = int(np.sum(assign >= 0)) - inst.T
    if surplus > 0:
        local = _local_costs_any(inst, assign)
        ordt = np.argsort(-local)
        drop = ordt[:min(surplus, int(np.sum(local > 0)))]
        np.subtract.at(load, assign[drop], inst.lam[drop])
        assign[drop] = -1
    cost = (_objective_any(inst, assign)
            if np.sum(assign >= 0) >= inst.T else np.inf)
    return HFLOPSolution(assign, cost, optimal=False, solver="greedy",
                         wall_time_s=wall_clock() - t0)


def local_search(inst: HFLOPInstance, sol: HFLOPSolution,
                 max_iters: int = 10_000) -> HFLOPSolution:
    """Vectorized best-improvement: all single-device relocation deltas
    (with edge open/close bookkeeping) are evaluated in one ``(n, m)``
    matrix pass per iteration; the best move commits and the state is
    rebuilt from scratch (keeps float accumulation order canonical)."""
    t0 = wall_clock()
    n, m = inst.n, inst.m
    if not np.isfinite(sol.cost) or not is_feasible(inst, sol.assign):
        return sol                      # nothing feasible to improve
    assign = sol.assign.copy()
    for _ in range(max_iters):
        ok = assign >= 0
        load = np.zeros(m)
        np.add.at(load, assign[ok], inst.lam[ok])
        counts = np.zeros(m, int)
        np.add.at(counts, assign[ok], 1)
        opened = counts > 0
        cur_local = np.where(ok, inst.c_d[np.arange(n),
                                          np.clip(assign, 0, m - 1)], 0.0)
        cur_local = cur_local * inst.l * ok
        # delta[i, j] = cost change of moving device i to edge j
        open_cost = np.where(opened, 0.0, inst.c_e)[None, :]
        close_save = np.where(ok & (counts[np.clip(assign, 0, m - 1)] == 1),
                              inst.c_e[np.clip(assign, 0, m - 1)], 0.0)
        delta = (inst.c_d * inst.l + open_cost
                 - cur_local[:, None] - close_save[:, None])
        feas = load[None, :] + inst.lam[:, None] <= inst.r[None, :] + 1e-12
        same = np.zeros((n, m), bool)
        same[np.arange(n)[ok], assign[ok]] = True
        delta = np.where(feas & ~same, delta, np.inf)
        i, j = np.unravel_index(np.argmin(delta), delta.shape)
        if delta[i, j] >= -1e-12:
            break
        assign[i] = j
    cost = objective(inst, assign)
    return HFLOPSolution(assign, cost, optimal=False,
                         solver=sol.solver + "+ls",
                         wall_time_s=sol.wall_time_s
                         + wall_clock() - t0)


def _batch_moves(inst: HFLOPInstance, assign: np.ndarray,
                 max_passes: int = 6) -> np.ndarray:
    """Bulk relocation accelerator for ``local_search``: commit *every*
    device's best improving move onto an already-open destination in one
    pass (destination capacities validated cumulatively in delta order).
    Each committed move's true saving is at least its computed delta —
    source-edge closures only add savings — so the objective strictly
    decreases; the single-move ``local_search`` afterwards keeps the
    classic best-improvement semantics for open/close moves."""
    n, m = inst.n, inst.m
    for _ in range(max_passes):
        ok = assign >= 0
        if not ok.any():
            break
        load = np.zeros(m)
        np.add.at(load, assign[ok], inst.lam[ok])
        opened = np.bincount(assign[ok], minlength=m) > 0
        cur = np.where(ok, inst.c_d[np.arange(n),
                                    np.clip(assign, 0, m - 1)],
                       0.0) * inst.l
        delta = inst.c_d * inst.l - cur[:, None]
        feas = ((load[None, :] + inst.lam[:, None]
                 <= inst.r[None, :] + 1e-12) & opened[None, :])
        same = np.zeros((n, m), bool)
        same[np.arange(n)[ok], assign[ok]] = True
        delta = np.where(feas & ~same, delta, np.inf)
        best_j = np.argmin(delta, axis=1)
        best_d = delta[np.arange(n), best_j]
        movers = np.nonzero(ok & (best_d < -1e-12))[0]
        if movers.size == 0:
            break
        ordm = movers[np.argsort(best_d[movers], kind="stable")]
        dest = best_j[ordm]
        w = inst.lam[ordm]
        srt = np.argsort(dest, kind="stable")
        ds, ws = dest[srt], w[srt]
        cw = np.cumsum(ws)
        first = np.searchsorted(ds, ds, side="left")
        run = load[ds] + (cw - (cw[first] - ws[first]))
        acc = srt[run <= inst.r[ds] + 1e-12]   # per-dest prefix (run grows)
        if acc.size == 0:
            break
        assign[ordm[acc]] = best_j[ordm[acc]]
    return assign


def _ejection_pass(inst: HFLOPInstance, assign: np.ndarray,
                   tries_per_edge: int = 4, max_rounds: int = 20,
                   cap_wait: int = 128) -> np.ndarray:
    """Ejection-chain neighborhood the single-move search cannot reach:
    evict one heavy member of an edge (a non-improving move on its own)
    to admit several waiting devices with positive savings into the
    freed capacity.  This is what closes the paper-cost gap — the
    optimum evicts one large-lam device from a full LAN edge so many
    small devices can come home, a length-k chain invisible to
    relocation/swap moves.  Commits only strictly improving chains."""
    n, m = inst.n, inst.m
    l = inst.l
    for _ in range(max_rounds):
        ok = assign >= 0
        load = np.zeros(m)
        np.add.at(load, assign[ok], inst.lam[ok])
        cur = np.where(ok, inst.c_d[np.arange(n),
                                    np.clip(assign, 0, m - 1)], 0.0) * l
        improved = False
        for j in range(m):
            sav = cur - inst.c_d[:, j] * l
            wait = np.nonzero(ok & (assign != j) & (sav > 1e-12))[0]
            if wait.size == 0:
                continue
            wait = wait[np.argsort(-sav[wait], kind="stable")][:cap_wait]
            members = np.nonzero(assign == j)[0]
            opened = np.bincount(assign[ok], minlength=m) > 0
            options = [(-1, 0.0, -1)]
            for e in members[np.argsort(-inst.lam[members])][:tries_per_edge]:
                feas = (load + inst.lam[e] <= inst.r + 1e-12) & opened
                feas[j] = False
                c = np.where(feas, inst.c_d[e] * l, np.inf)
                jp = int(np.argmin(c))
                if np.isfinite(c[jp]):
                    options.append((int(e),
                                    (inst.c_d[e, jp] - inst.c_d[e, j]) * l,
                                    jp))
            best = None
            for e, cost0, jp in options:
                room = inst.r[j] - load[j] + (inst.lam[e] if e >= 0 else 0.0)
                gain = -cost0
                admitted = []
                for k in wait:
                    if k != e and inst.lam[k] <= room + 1e-12:
                        room -= inst.lam[k]
                        gain += sav[k]
                        admitted.append(k)
                if admitted and gain > 1e-12 and (best is None
                                                 or gain > best[0]):
                    best = (gain, e, jp, admitted)
            if best is not None:
                _, e, jp, admitted = best
                if e >= 0:
                    load[assign[e]] -= inst.lam[e]
                    assign[e] = jp
                    load[jp] += inst.lam[e]
                for k in admitted:
                    load[assign[k]] -= inst.lam[k]
                    assign[k] = j
                    load[j] += inst.lam[k]
                cur = np.where(assign >= 0,
                               inst.c_d[np.arange(n),
                                        np.clip(assign, 0, m - 1)],
                               0.0) * l
                improved = True
        if not improved:
            break
    return assign


def _multi_construct(inst: AnyInstance) -> np.ndarray:
    """Greedy construction from several insertion orders (heavy-first,
    light-first, regret-first for dense costs), each followed by the
    close pass; keeps the candidate with the most devices placed, then
    the lowest cost.  Light-first matters for LAN-style costs: inserting
    heavy consumers first evicts many small devices from their free
    edge, where evicting one heavy device would have been cheaper."""
    orders = [np.argsort(-inst.lam), np.argsort(inst.lam)]
    if not isinstance(inst, LanHFLOPInstance) and inst.m >= 2:
        two = np.partition(inst.c_d, 1, axis=1)
        orders.append(np.argsort(-(two[:, 1] - two[:, 0])))
    n, m = inst.n, inst.m
    rows = _cost_rows_fn(inst)
    best = None
    for order in orders:
        assign = np.full(n, -1, int)
        load = np.zeros(m)
        opened = np.zeros(m, bool)
        _greedy_insert(rows, order, inst.lam, inst.r, inst.c_e, inst.l,
                       load, opened, assign)
        _close_edges(rows, inst.lam, inst.r, inst.c_e, inst.l, m,
                     assign, load, opened)
        key = (int(np.sum(assign >= 0)), -_objective_any(inst, assign))
        if best is None or key > best[0]:
            best = (key, assign)
    return best[1]


def solve_heuristic(inst: HFLOPInstance) -> HFLOPSolution:
    return local_search(inst, solve_greedy(inst))


# ---------------------------------------------------------------------------
# hierarchically decomposed solver (continuum scale)
# ---------------------------------------------------------------------------

def solve_decomposed(inst: AnyInstance, regions: Optional[int] = None,
                     ls_iters: int = 200, batch_passes: int = 6,
                     polish_cells: int = 4_000_000,
                     telemetry: Optional[Telemetry] = None,
                     ) -> HFLOPSolution:
    """Million-device HFLOP: partition the edge continuum into regions
    (LAN-balanced for structured instances, k-medoids on cost columns
    otherwise), solve each region as an independent dense capacitated
    sub-problem (vectorized greedy + bulk-move + local search), then
    stitch: globally repair devices their region could not place (they
    may cross region boundaries, re-opening edges), trim to T, and
    polish (full local search when the dense matrix fits
    ``polish_cells``; LAN-reclaim passes at larger scale).

    Returns a standard :class:`HFLOPSolution` with per-phase wall times,
    region stats and a cheap lower bound in ``sol.meta``.

    Phases are timed as tracer wall spans (``solve_decomposed.partition``
    / ``.subsolve`` / ``.stitch`` / ``.polish``): pass ``telemetry`` to
    collect them alongside everything else it records; without one a
    throwaway local tracer provides the same timing.  ``meta["phase_s"]``
    is a thin compatibility view of those spans' durations.
    """
    t0 = wall_clock()
    n, m = inst.n, inst.m
    lan = isinstance(inst, LanHFLOPInstance)
    tel = _maybe_tel(telemetry)
    tr = tel.tracer if tel is not None else SpanTracer()

    with tr.wall("solve_decomposed.partition", cat="solver") as sp_part:
        part = partition_instance(inst, regions=regions)

    with tr.wall("solve_decomposed.subsolve", cat="solver",
                 regions=int(part.n_regions)) as sp_sub:
        assign = np.full(n, -1, np.int64)
        for reg in range(part.n_regions):
            dev = part.devices_in(reg)
            if dev.size == 0:
                continue
            edg = part.edges_in(reg)
            if edg.size == 0:
                continue                  # stitch pass will repair these
            sub = sub_instance(inst, dev, edg)
            a = _multi_construct(sub)
            ach = int(np.sum(a >= 0))
            if ach < sub.T:               # region can't host everyone:
                sub = HFLOPInstance(sub.c_d, sub.c_e, sub.lam, sub.r,
                                    l=sub.l, T=ach)
            a = _polish_dense(sub, a, ls_iters, batch_passes)
            keep = a >= 0
            assign[dev[keep]] = edg[a[keep]]

    # stitch: boundary repair — leftover devices go wherever capacity
    # remains, cheapest (open-cost-amortized) edge first, across regions
    with tr.wall("solve_decomposed.stitch", cat="solver") as sp_stitch:
        ok = assign >= 0
        load = np.bincount(assign[ok], weights=inst.lam[ok], minlength=m)
        opened = np.bincount(assign[ok], minlength=m) > 0
        left = np.nonzero(~ok)[0]
        repaired = 0
        if left.size:
            before = int(ok.sum())
            order = left[np.argsort(-inst.lam[left])]
            _greedy_insert(_cost_rows_fn(inst), order, inst.lam, inst.r,
                           inst.c_e, inst.l, load, opened, assign)
            repaired = int(np.sum(assign >= 0)) - before
        surplus = int(np.sum(assign >= 0)) - inst.T
        if surplus > 0:                   # same trimming rule as greedy
            local = _local_costs_any(inst, assign)
            ordt = np.argsort(-local)
            drop = ordt[:min(surplus, int(np.sum(local > 0)))]
            np.subtract.at(load, assign[drop], inst.lam[drop])
            assign[drop] = -1
        # cross-region merge: regions solve in isolation, so the union
        # can hold redundant open edges near boundaries — the global
        # close pass drains and merges them wherever relocation beats
        # the open cost
        ok = assign >= 0
        load = np.bincount(assign[ok], weights=inst.lam[ok], minlength=m)
        opened = np.bincount(assign[ok], minlength=m) > 0
        _close_edges(_cost_rows_fn(inst), inst.lam, inst.r, inst.c_e,
                     inst.l, m, assign, load, opened)

    with tr.wall("solve_decomposed.polish", cat="solver") as sp_polish:
        if n * m <= polish_cells:
            dense = inst.to_dense() if lan else inst
            assign = _polish_dense(dense, assign.copy(), ls_iters,
                                   batch_passes)
            # small instances afford a second basin: a *global*
            # construction polished the same way; keep whichever places
            # more devices at lower cost (guards the optimality gap
            # where a region split is the wrong structure)
            alt = _polish_dense(dense, _multi_construct(dense),
                                ls_iters, batch_passes)
            if ((int(np.sum(alt >= 0)), -objective(dense, alt))
                    > (int(np.sum(assign >= 0)),
                       -objective(dense, assign))):
                assign = alt
        elif lan:
            assign = _lan_reclaim(inst, assign)

    # thin compatibility view of the tracer spans (one source of truth)
    phases = {"partition_s": sp_part.dur, "subsolve_s": sp_sub.dur,
              "stitch_s": sp_stitch.dur, "polish_s": sp_polish.dur}
    feasible = int(np.sum(assign >= 0)) >= inst.T
    cost = _objective_any(inst, assign) if feasible else np.inf
    lb = _lower_bound(inst)
    meta = {"phase_s": phases,
            "regions": int(part.n_regions),
            "partition_method": part.method,
            "repaired": int(repaired),
            "lower_bound": float(lb),
            "gap_vs_lb": (float(cost / lb - 1.0)
                          if lb > 0 and np.isfinite(cost)
                          else float("nan"))}
    return HFLOPSolution(assign, cost, optimal=False, solver="decomposed",
                         wall_time_s=wall_clock() - t0, meta=meta)


def _polish_dense(dense: HFLOPInstance, assign: np.ndarray,
                  ls_iters: int, batch_passes: int) -> np.ndarray:
    """Dense improvement stack: bulk moves, best-improvement local
    search, ejection chains, local search again."""
    a = _batch_moves(dense, assign, max_passes=batch_passes)
    s = local_search(dense, HFLOPSolution(a, objective(dense, a),
                                          solver="decomposed"),
                     max_iters=ls_iters)
    a = _ejection_pass(dense, s.assign)
    s = local_search(dense, HFLOPSolution(a, objective(dense, a),
                                          solver="decomposed"),
                     max_iters=ls_iters)
    return s.assign


def _lan_reclaim(inst: LanHFLOPInstance, assign: np.ndarray,
                 passes: int = 3) -> np.ndarray:
    """Continuum-scale polish for structured instances: pull cross-LAN
    devices back to their zero-cost home edge wherever slack allows
    (lightest devices first per edge maximizes the count).  Moves onto
    open homes always save ``l * unit_cost``; closed homes are only
    re-opened when the saving exceeds the open cost.  Each pass frees
    capacity on source edges, so iterate a few times."""
    for _ in range(passes):
        ok = assign >= 0
        load = np.bincount(assign[ok], weights=inst.lam[ok],
                           minlength=inst.m)
        opened = np.bincount(assign[ok], minlength=inst.m) > 0
        home = np.clip(inst.free, 0, inst.m - 1)
        allowed = opened[home] | (inst.l * inst.unit_cost > inst.c_e[home])
        cand = np.nonzero(ok & (inst.free >= 0) & (assign != inst.free)
                          & allowed)[0]
        if cand.size == 0:
            break
        h = inst.free[cand]
        w = inst.lam[cand]
        srt = np.lexsort((w, h))                  # per home, lightest first
        hs, ws = h[srt], w[srt]
        cw = np.cumsum(ws)
        first = np.searchsorted(hs, hs, side="left")
        run = load[hs] + (cw - (cw[first] - ws[first]))
        acc = srt[run <= inst.r[hs] + 1e-12]      # per-home prefix
        if acc.size == 0:
            break
        assign[cand[acc]] = inst.free[cand[acc]]
    return assign


def _lower_bound(inst: AnyInstance) -> float:
    """Cheap combinatorial lower bound: the T cheapest per-device local
    costs plus the cheapest set of edges large enough (by max capacity)
    to host the T lightest devices."""
    if inst.T <= 0:
        return 0.0
    if isinstance(inst, LanHFLOPInstance):
        cheap = np.where(inst.free >= 0, 0.0, inst.unit_cost)
    else:
        cheap = inst.c_d.min(axis=1)
    local_lb = float(np.sort(cheap)[:inst.T].sum()) * inst.l
    lam_t = np.sort(inst.lam)[:inst.T]
    rmax = float(np.max(inst.r))
    min_edges = max(1, int(np.ceil(lam_t.sum() / rmax))) if rmax > 0 else 1
    open_lb = float(np.sort(inst.c_e)[:min_edges].sum())
    return local_lb + open_lb


# ---------------------------------------------------------------------------
# exact: LP-relaxation branch & bound
# ---------------------------------------------------------------------------

def _round_lp(inst: HFLOPInstance, xfrac: np.ndarray) -> Optional[np.ndarray]:
    """Rounding heuristic fed to the B&B: assign each device to its
    largest-x edge if capacity admits (greedy by fractional mass).
    Chunk-speculated like ``_greedy_insert``; per-row preference order
    comes from one row-wise argsort turned into a rank matrix, so the
    batch pick (min-rank feasible candidate) matches the sequential
    scan exactly."""
    n, m = inst.n, inst.m
    xm = xfrac[:n * m].reshape(n, m)
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    order = np.argsort(-np.max(xm, axis=1))
    pref = np.argsort(-xm, axis=1)
    rank = np.empty((n, m), np.int64)
    np.put_along_axis(rank, pref, np.arange(m)[None, :], axis=1)
    mass = xm >= 1e-9
    cap = _chunk_cap(m)
    pos, chunk = 0, _CHUNK0
    while pos < n:
        ids = order[pos:pos + chunk]
        feas = (load[None, :] + inst.lam[ids][:, None]
                <= inst.r[None, :] + 1e-12)
        R = np.where(feas & mass[ids], rank[ids], m)
        picks = np.argmin(R, axis=1)
        okm = R[np.arange(ids.size), picks] < m
        cut, guard = _capacity_limit(picks, okm, inst.lam[ids],
                                     load, inst.r)
        if guard:                                 # scalar replay, verbatim
            for k in range(ids.size):
                i = ids[k]
                for j in np.argsort(-xm[i]):
                    if xm[i, j] < 1e-9:
                        break
                    if load[j] + inst.lam[i] <= inst.r[j] + 1e-12:
                        assign[i] = j
                        load[j] += inst.lam[i]
                        break
            pos += ids.size
            chunk = _CHUNK0
            continue
        com = okm[:cut]
        ci = ids[:cut][com]
        cp = picks[:cut][com]
        assign[ci] = cp
        np.add.at(load, cp, inst.lam[ci])
        good = cut == ids.size
        pos += cut
        chunk = min(chunk * 4, cap) if good else _CHUNK0
    if int(np.sum(assign >= 0)) < inst.T:
        return None
    v = np.zeros(n * m + m)
    okv = assign >= 0
    v[np.arange(n)[okv] * m + assign[okv]] = 1.0
    v[n * m + np.unique(assign[okv])] = 1.0
    return v


def solve_bnb(inst: HFLOPInstance, time_limit_s: float = 600.0,
              max_nodes: int = 200_000) -> HFLOPSolution:
    t0 = wall_clock()
    ilp = build_ilp(inst)
    warm = solve_heuristic(inst)
    inc = None
    if np.isfinite(warm.cost):
        inc = np.zeros(ilp.c.shape[0])
        for i in range(inst.n):
            if warm.assign[i] >= 0:
                inc[ilp.x_index(i, warm.assign[i])] = 1.0
        for j in np.unique(warm.assign[warm.assign >= 0]):
            inc[ilp.y_index(j)] = 1.0
    prio = np.zeros(ilp.c.shape[0])
    prio[inst.n * inst.m:] = 1.0                      # branch y first
    res = solve_milp(ilp.c, ilp.A, ilp.b, incumbent_x=inc,
                     branch_priority=prio,
                     rounding=lambda xf: _round_lp(inst, xf),
                     max_nodes=max_nodes, time_limit_s=time_limit_s)
    if res.x is None:
        return HFLOPSolution(np.full(inst.n, -1), np.inf, optimal=False,
                             solver="bnb", nodes_explored=res.nodes,
                             wall_time_s=wall_clock() - t0)
    xm = res.x[:inst.n * inst.m].reshape(inst.n, inst.m)
    assign = np.where(xm.max(axis=1) > 0.5, np.argmax(xm, axis=1), -1)
    return HFLOPSolution(assign, objective(inst, assign),
                         optimal=res.status == "optimal", solver="bnb",
                         nodes_explored=res.nodes,
                         wall_time_s=wall_clock() - t0)


# ---------------------------------------------------------------------------
# uncapacitated variant (paper Fig. 9 lower bound)
# ---------------------------------------------------------------------------

def solve_uncapacitated(inst: HFLOPInstance,
                        exact: bool = False) -> HFLOPSolution:
    """With r_j = inf the problem is classic UFL.  Greedy+LS by default;
    ``exact=True`` routes through the B&B."""
    un = inst.uncapacitated()
    if exact:
        sol = solve_bnb(un)
    else:
        sol = solve_heuristic(un)
    sol.solver = "uncap-" + sol.solver
    return sol
