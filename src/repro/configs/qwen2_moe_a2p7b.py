"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936.
[hf:Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                MoEConfig, RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
        num_layers=24,
        d_model=2048,
        d_ff=1408,
        vocab_size=151_936,
        attention=AttentionConfig(
            kind="full",
            num_heads=16,
            num_kv_heads=16,
            head_dim=128,
            rope_theta=1_000_000.0,
        ),
        moe=MoEConfig(num_experts=60, num_shared=4, top_k=4, d_expert=1408,
                      d_shared=5632, aux_loss_coef=0.001),
    ),
    run=RunConfig(microbatches=2, remat="layer"),
)
