"""Discrete-event simulator for inference serving during (continual) HFL
training — reproduces the paper's Fig. 7 (response times) and Fig. 8
(end-to-end latency vs compute speedup and request-rate scaling).

Each device emits a Poisson request stream at rate lambda_i (shared
generator: ``serving.workload.poisson_requests``).  Requests are routed
by rules R1-R3 (``repro.routing.rules``); edges have finite concurrent-
processing capacity derived from r_j; the cloud is infinite.

This module is a thin inference-only configuration of the shared event
engine: :class:`RequestProcessor` holds the routing + service logic
behind two interchangeable engines —

  ``batched``  (default) the vectorized macro-event request plane
               (``repro.sim.request_plane``): arrivals are pre-drawn
               columnar arrays, processed in NumPy batches over the
               windows between control-plane heap events; ~50-100x the
               simulated-requests/sec of the heap at Fig. 7 scale
               (``benchmarks/perf_event_throughput.py``);
  ``heap``     the original per-request event path (one
               ``REQUEST_ARRIVAL`` + ``REQUEST_COMPLETION`` heap event
               per request) — the *parity* reference the batched
               engine is validated against (``tests/
               test_event_engine.py``).

``repro.sim.cosim`` reuses the same processor but drives the busy flag
from an actual training round timeline and the service times through
an interference model; there the two engines are bit-identical because
routing is deterministic and the batched RTT draws consume the shared
generator stream in exactly the heap path's order.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.topology import ClusterTopology
from repro.routing.latency import LatencyModel
from repro.routing.rules import EdgeState, RouteDecision, route_request
from repro.serving.workload import poisson_request_arrays
from repro.sim.events import Event, EventKind, Simulation
from repro.sim.request_plane import (RULE_CODE, RULES, TIER_CLOUD,
                                     TIER_DEVICE, TIER_EDGE, ColumnarLog,
                                     RetryPolicy, backoff_delay,
                                     batched_rtt_draws, bucket_admissions,
                                     occupancy_replay)
from repro.telemetry import Telemetry, maybe as _maybe_tel

ENGINES = ("batched", "heap")

_RULE_NAMES = np.array(RULES, dtype=object)   # code -> str, C-speed take
_TIER_NAMES = ("device", "edge", "cloud")     # TIER_* code -> str

#: above this many open edges the per-window edge grouping switches
#: from one boolean scan per edge to a single stable argsort — scans
#: win decisively at the paper's continuum sizes (a handful of edges),
#: the sort wins once m x n passes would dominate n log n.
_EDGE_SCAN_MAX = 16


class RequestLog:
    """Columnar view of one run's served requests.  Rule names are kept
    as int8 codes (``rule_code``) and materialized to strings lazily on
    first access of ``rule`` — at 10^7 requests the eager
    list-of-strings was the single largest cost of ``log()``."""

    def __init__(self, t: np.ndarray, device: np.ndarray,
                 tier: np.ndarray,
                 rule: Optional[Sequence[str]] = None,
                 latency_ms: Optional[np.ndarray] = None, *,
                 rule_code: Optional[np.ndarray] = None):
        self.t = t
        self.device = device
        self.tier = tier
        self.latency_ms = (latency_ms if latency_ms is not None
                           else np.zeros(0))
        if rule_code is not None:
            self._rule_code = np.asarray(rule_code, dtype=np.int8)
        else:                        # legacy constructor: string names
            self._rule_code = np.asarray(
                [RULE_CODE[r] for r in (rule if rule is not None else ())],
                dtype=np.int8)
        self._rule_names: Optional[List[str]] = None

    @property
    def rule_code(self) -> np.ndarray:
        """Per-request routing-rule codes (int8, see ``RULES``)."""
        return self._rule_code

    @property
    def rule(self) -> List[str]:
        """Per-request rule names, materialized (and cached) on demand."""
        if self._rule_names is None:
            self._rule_names = _RULE_NAMES[self._rule_code].tolist()
        return self._rule_names

    def mean_latency(self) -> float:
        """Mean end-to-end latency in ms (NaN on an empty log)."""
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.mean(self.latency_ms))

    def std_latency(self) -> float:
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.std(self.latency_ms))

    def percentile_latency(self, p: float) -> float:
        """p-th percentile of end-to-end latency in ms (p in [0, 100]);
        NaN on an empty log — short smoke runs can legitimately serve
        zero requests, and reporting must not crash on them."""
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.percentile(self.latency_ms, p))

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p95/p99 summary (``tier_fractions``-style dict, in ms)."""
        return {f"p{p:g}": self.percentile_latency(p)
                for p in (50, 95, 99)}

    def percentile_ci(self, p: float, confidence: float = 0.95,
                      n_boot: int = 400, seed: int = 0,
                      ) -> tuple:
        """Bootstrap confidence interval of the p-th latency percentile
        — ``(lo, hi)`` in ms, NaN on an empty log.

        Per-request latencies are exact in the columnar log, so the
        bootstrap is the order-statistic shortcut: the p-th percentile
        of one resample of size n is (to interpolation) the K-th order
        statistic of the *original* sorted sample with
        ``K ~ Binomial(n, p/100)`` — B resamples cost one sort plus B
        binomial draws, never B x n copies, which is what makes CIs on
        10^7-request high-rate sweeps free."""
        n = self.latency_ms.size
        if n == 0:
            return (math.nan, math.nan)
        s = np.sort(self.latency_ms)
        rng = np.random.default_rng(seed)
        k = rng.binomial(n, p / 100.0, size=int(n_boot))
        boots = s[np.clip(k, 0, n - 1)]
        alpha = (1.0 - confidence) / 2.0
        return (float(np.percentile(boots, 100.0 * alpha)),
                float(np.percentile(boots, 100.0 * (1.0 - alpha))))

    def tier_fractions(self) -> Dict[str, float]:
        names = {0: "device", 1: "edge", 2: "cloud"}
        if self.tier.size == 0:
            return {name: math.nan for name in names.values()}
        out = {}
        for k, name in names.items():
            out[name] = float(np.mean(self.tier == k))
        return out

    def windowed_percentile(self, window_s: float, p: float = 95.0,
                            ) -> np.ndarray:
        """(n_windows, 2) array of [window start, p-th percentile latency]
        — the latency timeline the reactive monitors and examples plot.
        Windows without any arrivals are NaN rows (not silently dropped),
        so the timeline keeps a uniform grid and gaps stay visible.

        Arrival times are nondecreasing (the engines log in arrival
        order), so windows are contiguous ``searchsorted`` slices, and
        the per-window percentile is one grouped sort: ``lexsort`` on
        (window id, latency) orders every slice at once, then the
        linearly interpolated percentile is gathered per window with
        array arithmetic — no Python loop over windows."""
        if self.t.size == 0:
            return np.zeros((0, 2))
        edges = np.arange(0.0, float(self.t[-1]) + 1e-9, window_s)
        bounds = np.searchsorted(self.t, np.append(edges,
                                                   edges[-1] + window_s))
        counts = np.diff(bounds)
        nw = edges.size
        win_id = np.repeat(np.arange(nw), counts)
        lat = self.latency_ms[bounds[0]:bounds[-1]]
        s = lat[np.lexsort((lat, win_id))]   # each window's slice sorted
        out = np.full((nw, 2), math.nan)
        out[:, 0] = edges
        nz = counts > 0
        if nz.any():
            # numpy's default linear interpolation, vectorized across
            # windows: virtual index (count-1) * p/100 into the sorted
            # slice, then lerp between its two neighbours
            pos = (counts[nz] - 1) * (p / 100.0)
            lo_i = np.floor(pos).astype(np.int64)
            hi_i = np.minimum(lo_i + 1, counts[nz] - 1)
            frac = pos - lo_i
            base = (bounds[:-1] - bounds[0])[nz]
            s_lo = s[base + lo_i]
            out[nz, 1] = s_lo + frac * (s[base + hi_i] - s_lo)
        return out


@dataclass
class SimConfig:
    duration_s: float = 300.0
    seed: int = 0
    busy_fraction: float = 1.0       # fraction of time devices train (CL: 1)
    rate_scale: float = 1.0          # Fig. 8b: lambda x 10
    latency: LatencyModel = field(default_factory=LatencyModel)
    engine: str = "batched"          # "batched" | "heap" (parity)


class RequestProcessor:
    """Routing + service logic on the shared event engine — used by the
    inference-only simulator below and the training–inference
    co-simulation (``repro.sim.cosim``).

    Two engines share all admission/topology state (the ``EdgeState``
    dict control-plane handlers mutate) and the columnar log:

      ``heap``     per-request handlers on ``REQUEST_ARRIVAL`` /
                   ``REQUEST_COMPLETION`` events, driven by the scalar
                   policies ``busy_fn`` / ``service_fn`` /
                   ``extra_ms_fn``;
      ``batched``  pre-drawn arrival arrays (:meth:`add_arrivals`)
                   processed window-by-window through the simulation's
                   flush hook, driven by the vectorized policies
                   ``busy_mask_fn(devices, ts)``,
                   ``stretch_fn(tier, ids)`` and
                   ``extra_ms_vec_fn(ts, devices, tiers, edge_ids)``.

    Both log into a :class:`~repro.sim.request_plane.ColumnarLog`
    (preallocated arrays, arrival order), so telemetry percentiles are
    incremental either way."""

    def __init__(self, topo: ClusterTopology, rng: np.random.Generator,
                 latency: Optional[LatencyModel] = None,
                 busy_fn: Optional[Callable[[int, float], bool]] = None,
                 service_fn: Optional[
                     Callable[[int, RouteDecision, int], float]] = None,
                 extra_ms_fn: Optional[
                     Callable[[RouteDecision, float, int], float]] = None,
                 engine: str = "batched",
                 busy_mask_fn: Optional[Callable[
                     [np.ndarray, np.ndarray], np.ndarray]] = None,
                 stretch_fn: Optional[Callable[
                     [str, np.ndarray], np.ndarray]] = None,
                 extra_ms_vec_fn: Optional[Callable[
                     [np.ndarray, np.ndarray, np.ndarray, np.ndarray],
                     np.ndarray]] = None,
                 telemetry: Optional[Telemetry] = None):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; pick from "
                             f"{ENGINES}")
        if engine == "batched":
            # the batched engine only consults the vectorized policies:
            # a scalar-only caller would silently simulate the default
            # behavior instead — refuse loudly
            unpaired = [f"{scalar} (vectorized twin {vec} missing)"
                        for scalar, vec, s, v in (
                            ("busy_fn", "busy_mask_fn", busy_fn,
                             busy_mask_fn),
                            ("service_fn", "stretch_fn", service_fn,
                             stretch_fn),
                            ("extra_ms_fn", "extra_ms_vec_fn",
                             extra_ms_fn, extra_ms_vec_fn))
                        if s is not None and v is None]
            if unpaired:
                raise ValueError(
                    "engine='batched' ignores scalar policies: "
                    + "; ".join(unpaired)
                    + ". Pass the vectorized policy or engine='heap'.")
        self.engine = engine
        self.rng = rng
        self.lat = latency if latency is not None else LatencyModel()
        self.busy_fn = busy_fn or (lambda i, t: False)
        self.service_fn = service_fn
        self.extra_ms_fn = extra_ms_fn
        self.busy_mask_fn = busy_mask_fn
        self.stretch_fn = stretch_fn
        self.extra_ms_vec_fn = extra_ms_vec_fn
        # resolved once: None unless telemetry is present AND enabled,
        # so disabled runs pay exactly one is-None branch per window;
        # instrument handles are bound here too — the window path does
        # no registry lookups or name formatting
        self._tel = _maybe_tel(telemetry)
        if self._tel is not None:
            m = self._tel.metrics
            self._m_windows = m.counter("request_plane.windows")
            self._m_total = m.counter("requests.total")
            self._m_tier = [m.counter(f"requests.tier.{t}")
                            for t in _TIER_NAMES]
            self._m_rule = [m.counter(f"requests.rule.{r}")
                            for r in RULES]
            self._m_hist = m.histogram("request.latency_ms")
            self._m_fault_attempts = m.counter("requests.fault_attempts")
            self._m_fault_dropped = m.counter("requests.fault_dropped")
            self._m_retries = m.counter("requests.retries")
            self._m_failovers = m.counter("requests.failovers")
        self._cols = ColumnarLog()
        self._tier_code = {"device": TIER_DEVICE, "edge": TIER_EDGE,
                           "cloud": TIER_CLOUD}
        # batched-engine state: the pre-drawn arrival stream + cursor,
        # and per-edge in-flight completion times (only materialized
        # when the latency model's edge service is occupancy-dependent)
        self._arr_t = np.zeros(0, dtype=np.float64)
        self._arr_dev = np.zeros(0, dtype=np.int64)
        self._arr_pos = 0
        self._flush_started = False
        self._occ_edge = self.lat.occupancy_dependent("edge")
        self._pending: Dict[int, np.ndarray] = {}
        # fault-plane state (repro.sim.faults): all empty / None unless
        # a chaos plan is installed, so fault-free runs never branch
        # into the scalar core — the non-perturbation contract
        self.retry_policy: Optional[RetryPolicy] = None
        self._down: set = set()          # edges currently crashed/partitioned
        self._drop_p: Dict[int, float] = {}    # edge -> drop probability
        self._spike_ms: Dict[int, float] = {}  # edge -> added latency (ms)
        self._fault_active = False
        self._tick_armed = False         # one ARRIVAL_TICK outstanding, max
        self._sim: Optional[Simulation] = None
        # availability accounting (see benchmarks/perf_faults.py): every
        # failed attempt either schedules a retry or fails over, and
        # every arrival is logged exactly once unless its retry is still
        # pending at the horizon — log rows + (scheduled - dispatched)
        # retries == total arrivals, the CI hard gate
        self.fault_attempts = 0
        self.fault_drops = 0
        self.retries_scheduled = 0
        self.retries_dispatched = 0
        self.failovers = 0
        self.edges: Dict[int, EdgeState] = {}
        self.set_topology(topo)

    def set_topology(self, topo: ClusterTopology) -> None:
        """(Re)build admission state — used at start and when the
        reactive loop swaps in a re-clustered deployment.  In-flight
        completions keep a reference to their old ``EdgeState`` (the
        event payload), so they drain harmlessly after a swap; the
        batched engine equivalently drops its per-edge in-flight
        arrays."""
        self.topo = topo
        self.edges = {}
        for j in topo.open_edges:
            # capacity is a property of the edge host — it does NOT scale
            # with the request-rate multiplier (the point of Fig. 8b)
            self.edges[int(j)] = EdgeState(
                capacity_rps=float(topo.r[j]) if topo.r.size else np.inf)
        self._pending = {}

    def bind(self, sim: Simulation) -> None:
        self._sim = sim
        if self.engine == "heap":
            sim.on(EventKind.REQUEST_ARRIVAL, self.on_arrival)
            sim.on(EventKind.REQUEST_COMPLETION, self.on_completion)
        else:
            sim.set_flush(self.flush_window)
        # retry/tick events exist only in fault-mode runs; registering
        # the handlers is free otherwise
        sim.on(EventKind.REQUEST_RETRY, self.on_retry)
        sim.on(EventKind.ARRIVAL_TICK, self.on_tick)

    def fail_edge(self, edge_id: int) -> None:
        """Edge host died: zero capacity so R3 overflows to the cloud."""
        st = self.edges.get(int(edge_id))
        if st is not None:
            st.capacity_rps = 0.0
            st.tokens = 0.0

    # -- fault plane (repro.sim.faults) -------------------------------------

    def enable_faults(self, policy: RetryPolicy) -> None:
        """Arm the retry/failover core.  In fault mode *every* request
        of the heap engine — and every batched window with a fault
        active — goes through :meth:`_serve_attempt`, the shared scalar
        core, so the two engines are bit-identical by construction;
        batched windows with no fault active keep the vectorized path
        (which the scalar core reproduces exactly when nothing is
        down)."""
        self.retry_policy = policy

    def fault_down(self, edge_id: int) -> None:
        """Edge crashed / partitioned away: attempts targeting it fail
        into retry/failover until :meth:`fault_up`.  Bucket and
        in-flight state survive (transient outage, not `fail_edge`)."""
        self._down.add(int(edge_id))
        self._recompute_fault_active()

    def fault_up(self, edge_id: int) -> None:
        self._down.discard(int(edge_id))
        self._recompute_fault_active()

    def set_drop(self, edge_id: int, p: float) -> None:
        """Drop-burst window: edge-served requests dropped w.p. ``p``
        (one uniform draw per attempt); ``p <= 0`` clears."""
        if p > 0.0:
            self._drop_p[int(edge_id)] = float(p)
        else:
            self._drop_p.pop(int(edge_id), None)
        self._recompute_fault_active()

    def set_spike(self, edge_id: int, ms: float) -> None:
        """Latency-spike window: +``ms`` on every request touching the
        edge (served there or transiting it); ``ms <= 0`` clears."""
        if ms > 0.0:
            self._spike_ms[int(edge_id)] = float(ms)
        else:
            self._spike_ms.pop(int(edge_id), None)
        self._recompute_fault_active()

    def _recompute_fault_active(self) -> None:
        self._fault_active = bool(self._down or self._drop_p
                                  or self._spike_ms)
        # crash/partition/drop faults can FAIL attempts, whose backoff
        # retries must land in the future — the batched plane paces
        # arrivals one-per-tick while such a fault is live (spike-only
        # windows never fail anything, so they keep whole-window
        # scalar replay)
        if self._down or self._drop_p:
            self._arm_tick()

    def _arm_tick(self) -> None:
        """Schedule the batched plane's next fault-window pacing beat
        at the next pending arrival's exact timestamp (see
        ``EventKind.ARRIVAL_TICK``).  At most one tick is outstanding;
        a stale one (fault cleared before it fires) degenerates to a
        window split, which the vectorized path is invariant to."""
        if (self.engine == "heap" or self._tick_armed
                or self.retry_policy is None or self._sim is None):
            return
        if self._arr_pos < self._arr_t.size:
            self._sim.schedule(float(self._arr_t[self._arr_pos]),
                               EventKind.ARRIVAL_TICK)
            self._tick_armed = True

    def on_tick(self, sim: Simulation, ev: Event) -> None:
        """The pre-dispatch inclusive flush already served the arrival
        this tick paced; all that is left is to keep the beat going
        while a fail-capable fault remains live."""
        self._tick_armed = False
        if self._down or self._drop_p:
            self._arm_tick()

    # -- heap ("parity") engine ---------------------------------------------

    def on_completion(self, sim: Simulation, ev: Event) -> None:
        ev.payload.in_service -= 1

    def on_arrival(self, sim: Simulation, ev: Event) -> None:
        if self.retry_policy is not None:
            # fault mode: every request goes through the shared scalar
            # core (bit-identical to the fault-free path below when no
            # fault touches its route)
            self._serve_attempt(sim, ev.t, int(ev.node), 0, ev.t)
            return
        t, i = ev.t, ev.node
        busy = self.busy_fn(i, t)
        dec = route_request(i, busy, self.topo.assign, self.edges, now=t)
        # calibrated mode: service time reflects how many requests the
        # chosen replica already has in flight (constant model ignores it)
        occ = self.edges[dec.edge].in_service if dec.tier == "edge" else 0
        service = (self.service_fn(i, dec, occ) if self.service_fn
                   else self.lat.infer_ms(dec.tier, occupancy=occ))
        if dec.tier == "edge":
            st = self.edges[dec.edge]
            st.admit(t)
            sim.schedule(t + service / 1000.0, EventKind.REQUEST_COMPLETION,
                         node=dec.edge, payload=st)
            net = float(self.lat.rtt("edge", self.rng))
        elif dec.tier == "cloud":
            net = float(self.lat.rtt("cloud", self.rng))
            if dec.hops == 2:        # forwarded via the edge (R3 overflow)
                net += float(self.lat.rtt("edge", self.rng))
        else:
            net = float(self.lat.rtt("device", self.rng))
        if self.extra_ms_fn is not None:
            net += float(self.extra_ms_fn(dec, t, i))
        tier_code = self._tier_code[dec.tier]
        rule_code = RULE_CODE[dec.rule]
        self._cols.append(t, i, tier_code, rule_code, net + service)
        if self._tel is not None:
            self._record_scalar(tier_code, rule_code, net + service)

    # -- fault-mode scalar core (shared by both engines) ---------------------
    #
    # Parity by construction: the heap engine's every request, the
    # batched engine's fault-active windows, and both engines' retry
    # dispatches all run _serve_attempt — the same float arithmetic and
    # the same generator-draw order.  Batched windows with no fault
    # active keep the vectorized path, which _serve_attempt reproduces
    # exactly in the fault-free case (it is the on_arrival body plus
    # fault branches that never trigger).

    def on_retry(self, sim: Simulation, ev: Event) -> None:
        """A timed-out/dropped request re-attempts after backoff.
        Control-plane event in *both* engines, so retries split batched
        windows and interleave with arrivals in global time order."""
        attempt, t0 = ev.payload
        self.retries_dispatched += 1
        self._serve_attempt(sim, ev.t, int(ev.node), int(attempt),
                            float(t0))

    def _serve_attempt(self, sim: Simulation, t: float, i: int,
                       attempt: int, t0: float) -> None:
        """One routing/serve attempt of request ``(t0, i)`` at time
        ``t`` (``attempt`` is 0 for the arrival itself).  Re-admission
        goes through the same leaky bucket as a fresh arrival; a failed
        attempt (crashed/partitioned edge, drop burst) schedules a
        backoff retry or — attempts/timeout exhausted — fails over
        straight to the cloud replica (rule R4-failover)."""
        busy = self.busy_fn(i, t)
        dec = route_request(i, busy, self.topo.assign, self.edges, now=t)
        je = dec.edge
        if je is not None and self._fault_active:
            if je in self._down:
                # crashed or partitioned away: the attempt fails whether
                # the edge was serving (R1) or transiting (R3 overflow)
                self._fail_attempt(sim, t, i, attempt, t0)
                return
            if dec.tier == "edge" and je in self._drop_p:
                if self.rng.random() < self._drop_p[je]:
                    self.fault_drops += 1
                    if self._tel is not None:
                        self._bump(self._m_fault_dropped)
                    self._fail_attempt(sim, t, i, attempt, t0)
                    return
        occ = self._edge_occupancy(dec, t)
        service = (self.service_fn(i, dec, occ) if self.service_fn
                   else self.lat.infer_ms(dec.tier, occupancy=occ))
        if dec.tier == "edge":
            st = self.edges[je]
            st.admit(t)
            self._push_completion(sim, st, je, t, service)
            net = float(self.lat.rtt("edge", self.rng))
        elif dec.tier == "cloud":
            net = float(self.lat.rtt("cloud", self.rng))
            if dec.hops == 2:        # forwarded via the edge (R3 overflow)
                net += float(self.lat.rtt("edge", self.rng))
        else:
            net = float(self.lat.rtt("device", self.rng))
        if self.extra_ms_fn is not None:
            net += float(self.extra_ms_fn(dec, t, i))
        if self._spike_ms and je is not None:
            net += self._spike_ms.get(je, 0.0)
        tier_code = self._tier_code[dec.tier]
        rule_code = RULE_CODE[dec.rule]
        # retried requests log at final service time with the backoff
        # wait folded in — the columnar log stays time-sorted
        lat_ms = (t - t0) * 1000.0 + (net + service)
        self._cols.append(t, i, tier_code, rule_code, lat_ms)
        if self._tel is not None:
            self._record_scalar(tier_code, rule_code, lat_ms)

    @staticmethod
    def _bump(counter) -> None:
        """Increment a telemetry counter.  Callers guard on ``_tel`` —
        keeping the mutation here (like ``_record_scalar``) pins the
        guarded blocks to pure-telemetry effects (contract TEL001)."""
        counter.value += 1.0

    def _fail_attempt(self, sim: Simulation, t: float, i: int,
                      attempt: int, t0: float) -> None:
        pol = self.retry_policy
        self.fault_attempts += 1
        if self._tel is not None:
            self._bump(self._m_fault_attempts)
        if attempt + 1 < pol.max_attempts:
            # one uniform draw per scheduled retry — the only randomness
            # the retry path consumes (contract DET003)
            u = self.rng.random()
            t_r = t + backoff_delay(pol, attempt, u)
            if t_r - t0 <= pol.timeout_s:
                self.retries_scheduled += 1
                if self._tel is not None:
                    self._bump(self._m_retries)
                sim.schedule(t_r, EventKind.REQUEST_RETRY, node=i,
                             payload=(attempt + 1, t0))
                return
        # tier failover: the cloud replica is always reachable, so no
        # request is ever lost — it just pays the failover hop
        self.failovers += 1
        if self._tel is not None:
            self._bump(self._m_failovers)
        dec = RouteDecision("cloud", None, hops=1, rule="R4-failover")
        service = (self.service_fn(i, dec, 0) if self.service_fn
                   else self.lat.infer_ms("cloud", occupancy=0))
        net = float(self.lat.rtt("cloud", self.rng))
        if self.extra_ms_fn is not None:
            net += float(self.extra_ms_fn(dec, t, i))
        lat_ms = (t - t0) * 1000.0 + (net + service)
        self._cols.append(t, i, TIER_CLOUD, RULE_CODE["R4-failover"],
                          lat_ms)
        if self._tel is not None:
            self._record_scalar(TIER_CLOUD, RULE_CODE["R4-failover"],
                                lat_ms)

    def _edge_occupancy(self, dec: RouteDecision, t: float) -> int:
        """Occupancy the chosen edge replica has in flight at ``t`` —
        the heap engine reads its event-maintained ``in_service``, the
        batched fallback drains the same per-edge completion array the
        vectorized ``occupancy_replay`` carries (identical counts: both
        exclude completions at exactly ``t``, which a heap run would
        have processed before the same-instant arrival)."""
        if dec.tier != "edge":
            return 0
        if self.engine == "heap":
            return self.edges[dec.edge].in_service
        if not self._occ_edge:
            return 0                 # constant model ignores occupancy
        pend = self._pending.get(dec.edge)
        if pend is None or not pend.size:
            return 0
        cut = int(np.searchsorted(pend, t, side="right"))
        if cut:
            pend = pend[cut:]
            self._pending[dec.edge] = pend
            self.edges[dec.edge].in_service = int(pend.size)
        return int(pend.size)

    def _push_completion(self, sim: Simulation, st: EdgeState, je: int,
                         t: float, service: float) -> None:
        """Record the served request's completion: a heap event (the
        fault-free heap path's exact schedule) or a sorted insert into
        the batched engine's carried pending array."""
        if self.engine == "heap":
            sim.schedule(t + service / 1000.0,
                         EventKind.REQUEST_COMPLETION, node=je,
                         payload=st)
            return
        if not self._occ_edge:
            return
        c = t + service / 1000.0
        pend = self._pending.get(je)
        if pend is None or not pend.size:
            pend = np.array([c], dtype=np.float64)
        else:
            pend = np.insert(pend, int(np.searchsorted(pend, c)), c)
        self._pending[je] = pend
        st.in_service = int(pend.size)

    # -- batched engine ------------------------------------------------------

    def add_arrivals(self, t: np.ndarray, device: np.ndarray) -> None:
        """Hand the batched engine its (time-sorted) arrival stream.
        May be called several times before the run starts; streams are
        merged stably."""
        if self._flush_started:
            raise RuntimeError("cannot add arrivals after window "
                               "processing started (the columnar log "
                               "must stay time-sorted)")
        if self._arr_t.size:
            t = np.concatenate([self._arr_t, np.asarray(t, np.float64)])
            device = np.concatenate([self._arr_dev,
                                     np.asarray(device, np.int64)])
            order = np.argsort(t, kind="stable")
            t, device = t[order], device[order]
        self._arr_t = np.ascontiguousarray(t, dtype=np.float64)
        self._arr_dev = np.ascontiguousarray(device, dtype=np.int64)

    def flush_window(self, lo: float, hi: float, inclusive: bool) -> None:
        """Advance the request plane through one control window: route,
        admit and serve every pending arrival with ``t < hi``
        (``t <= hi`` for the inclusive tail window) in one vectorized
        batch.  Every routing input is constant over the window by
        construction — its endpoints *are* the control events."""
        self._flush_started = True
        hi_idx = int(np.searchsorted(self._arr_t, hi,
                                     side="right" if inclusive else "left"))
        if hi_idx <= self._arr_pos:
            return
        sl = slice(self._arr_pos, hi_idx)
        self._arr_pos = hi_idx
        self._process_window(self._arr_t[sl], self._arr_dev[sl])

    def _stretch_scalar(self, tier: str, node: int) -> float:
        if self.stretch_fn is None:
            return 1.0
        return float(self.stretch_fn(tier, np.asarray([node]))[0])

    def _process_window(self, t: np.ndarray, dev: np.ndarray) -> None:
        if self._fault_active and self.retry_policy is not None:
            # a fault is live somewhere on the continuum: replay the
            # window through the shared scalar core so drops, retries
            # and failovers land bit-identically to the heap engine.
            # Fault-free windows (the common case) stay vectorized.
            if self._tel is not None:
                self._bump(self._m_windows)
            sim = self._sim
            for k in range(t.size):
                tk = float(t[k])
                self._serve_attempt(sim, tk, int(dev[k]), 0, tk)
            return
        n = t.size
        assign = self.topo.assign
        busy = (np.asarray(self.busy_mask_fn(dev, t), dtype=bool)
                if self.busy_mask_fn is not None
                else np.zeros(n, dtype=bool))
        j = np.full(n, -1, dtype=np.int64)
        valid = (dev >= 0) & (dev < assign.size)
        j[valid] = assign[dev[valid]]

        tier = np.empty(n, dtype=np.int8)
        rule = np.empty(n, dtype=np.int8)
        edge_id = np.full(n, -1, dtype=np.int64)
        service = np.empty(n, dtype=np.float64)
        two_hop = np.zeros(n, dtype=bool)

        idle = ~busy                                    # R2: serve locally
        if idle.any():
            tier[idle] = TIER_DEVICE
            rule[idle] = RULE_CODE["R2-local"]
            s_dev = self.lat.infer_ms("device")
            if self.stretch_fn is not None:
                service[idle] = s_dev * self.stretch_fn("device", dev[idle])
            else:
                service[idle] = s_dev

        flat = busy & (j < 0)                           # R1 without an edge
        if flat.any():
            tier[flat] = TIER_CLOUD
            rule[flat] = RULE_CODE["R1-flat"]

        eb = busy & (j >= 0)                            # R1 via aggregator
        if eb.any():
            base_edge = self.lat.infer_ms("edge")
            for je, m in self._edge_groups(eb, j):
                st = self.edges[je]
                adm = bucket_admissions(t[m], st)
                a_idx, o_idx = m[adm], m[~adm]
                tier[a_idx] = TIER_EDGE
                rule[a_idx] = RULE_CODE["R1"]
                edge_id[a_idx] = je
                tier[o_idx] = TIER_CLOUD                # R3 overflow
                rule[o_idx] = RULE_CODE["R3-overflow"]
                edge_id[o_idx] = je
                two_hop[o_idx] = True
                stretch_e = self._stretch_scalar("edge", je)
                if self._occ_edge and a_idx.size:
                    self._serve_occupancy(je, t, a_idx, service, stretch_e)
                else:
                    service[a_idx] = base_edge * stretch_e

        cloud = tier == TIER_CLOUD
        if cloud.any():
            service[cloud] = (self.lat.infer_ms("cloud")
                              * self._stretch_scalar("cloud", 0))

        net = batched_rtt_draws(self.rng, self.lat, tier, two_hop)
        if self.extra_ms_vec_fn is not None:
            net = net + self.extra_ms_vec_fn(t, dev, tier, edge_id)
        lat_ms = net + service
        self._cols.extend(t, dev, tier, rule, lat_ms)
        if self._tel is not None:
            self._record_window(tier, rule, lat_ms)

    def _edge_groups(self, eb: np.ndarray, j: np.ndarray):
        """Window positions grouped by edge (arrival order within each
        group), ascending edge id.  A handful of open edges — the
        continuum sizes the paper sweeps — is grouped with one boolean
        scan per edge; larger edge counts fall back to a single stable
        argsort + split so cost stays O(n log n), not O(m n)."""
        if len(self.edges) <= _EDGE_SCAN_MAX:
            covered = 0
            for je in sorted(self.edges):
                m = np.flatnonzero(eb & (j == je))
                covered += m.size
                if m.size:
                    yield je, m
            if covered != int(np.count_nonzero(eb)):
                # an assigned edge with no admission state would slip
                # through the scans silently (the argsort path below
                # raises KeyError at self.edges[je]) — fail as loudly
                missing = np.setdiff1d(j[eb], list(self.edges))
                raise KeyError(f"requests routed to edges {missing} "
                               f"with no admission state (open edges: "
                               f"{sorted(self.edges)})")
            return
        eb_idx = np.nonzero(eb)[0]
        order = np.argsort(j[eb_idx], kind="stable")
        eb_sorted = eb_idx[order]
        je_sorted = j[eb_sorted]
        cuts = np.nonzero(np.diff(je_sorted))[0] + 1
        for m in np.split(eb_sorted, cuts):
            yield int(j[m[0]]), m

    def _serve_occupancy(self, je: int, t: np.ndarray, a_idx: np.ndarray,
                         service: np.ndarray, stretch_e: float) -> None:
        """Occupancy-dependent (calibrated) edge service: replay the
        per-edge occupancy process exactly through
        :func:`~repro.sim.request_plane.occupancy_replay` — stretches
        below the replica's slot count collapse to a closed-form bulk
        run, only genuinely oversubscribed stretches (where service and
        occupancy couple) replay with the scalar arithmetic.  Cost
        scales with time-at-oversubscription, not admitted load, and
        results are bit-identical to the per-request heap engine."""
        st = self.edges[je]
        pend = self._pending.get(je)
        if pend is None:
            pend = np.zeros(0, dtype=np.float64)
        svc, pend = occupancy_replay(
            t[a_idx], pend,
            base_ms=self.lat.base_service_ms("edge") * stretch_e,
            slots=self.lat.flat_service_slots("edge"),
            service_ms_fn=lambda occ: (
                self.lat.infer_ms("edge", occupancy=occ) * stretch_e))
        service[a_idx] = svc
        self._pending[je] = pend
        st.in_service = int(pend.size)

    # -- shared telemetry / log ---------------------------------------------

    def _record_window(self, tier: np.ndarray, rule: np.ndarray,
                       lat_ms: np.ndarray) -> None:
        """Bulk columnar recording: per-code ``count_nonzero`` passes
        (int8 compares — cheaper than bincount at these cardinalities)
        and one histogram merge per window, never a per-request Python
        call — what keeps enabled-mode overhead on the batched plane
        inside the CI gate.  Metric names match :meth:`_record_scalar`
        so both engines produce identical counter values for identical
        runs."""
        self._m_windows.value += 1.0
        n = tier.size
        if n == 0:
            return
        self._m_total.value += n
        left = n
        for k, c in enumerate(self._m_tier):
            if left == 0:
                break
            tc = int(np.count_nonzero(tier == k)) if k < 2 else left
            c.value += tc
            left -= tc
        left = n
        for k, c in enumerate(self._m_rule):
            if left == 0:
                break
            rc = (int(np.count_nonzero(rule == k))
                  if k < len(self._m_rule) - 1 else left)
            c.value += rc
            left -= rc
        self._m_hist.observe_array(lat_ms)

    def _record_scalar(self, tier_code: int, rule_code: int,
                       latency_ms: float) -> None:
        self._m_total.value += 1.0
        self._m_tier[tier_code].value += 1.0
        self._m_rule[rule_code].value += 1.0
        self._m_hist.observe(latency_ms)

    def recent_percentile(self, now: float, window_s: float, p: float,
                          min_requests: int = 1,
                          max_lookback: Optional[int] = None,
                          ) -> Optional[float]:
        """p-th latency percentile over requests arriving in
        ``[now - window_s, now]`` — the latency monitors' telemetry.
        None when the window holds fewer than ``min_requests``.

        Incremental over the columnar log (binary-searched window
        start from a monotone cursor): a telemetry tick costs
        O(log n + window), independent of total history.
        ``max_lookback`` is accepted for backward compatibility and
        ignored — the scan was capped when it rescanned Python lists;
        the columnar log makes the exact window affordable."""
        return self._cols.recent_percentile(now, window_s, p,
                                            min_requests=min_requests)

    def log(self) -> RequestLog:
        """Snapshot of the columnar log — O(n) array copies only; rule
        strings stay int8 codes until someone reads ``.rule``."""
        c = self._cols
        n = c.n
        return RequestLog(
            t=c.t[:n].copy(), device=c.device[:n].copy(),
            tier=c.tier[:n].astype(np.int64),
            latency_ms=c.latency_ms[:n].copy(),
            rule_code=c.rule[:n].copy())


def simulate(topo: ClusterTopology, cfg: SimConfig) -> RequestLog:
    """Inference-only run: Poisson arrivals, coin-flip training signal.
    ``cfg.engine`` picks the vectorized batched plane (default) or the
    per-request heap path (parity reference)."""
    rng = np.random.default_rng(cfg.seed)
    t_arr, dev_arr = poisson_request_arrays(topo.lam * cfg.rate_scale,
                                            cfg.duration_s, rng)
    sim = Simulation()
    if cfg.engine == "heap":
        proc = RequestProcessor(
            topo, rng, latency=cfg.latency, engine="heap",
            busy_fn=lambda i, t: rng.uniform() < cfg.busy_fraction)
        proc.bind(sim)
        for tt, dd in zip(t_arr, dev_arr):
            sim.schedule(tt, EventKind.REQUEST_ARRIVAL, node=int(dd))
    else:
        proc = RequestProcessor(
            topo, rng, latency=cfg.latency, engine=cfg.engine,
            busy_mask_fn=lambda d, t: rng.random(d.size)
            < cfg.busy_fraction)
        proc.bind(sim)
        proc.add_arrivals(t_arr, dev_arr)
    sim.run()
    return proc.log()


def compare_methods(inst, assigns: Dict[str, np.ndarray], cfg: SimConfig,
                    ) -> Dict[str, RequestLog]:
    """Run the same workload through several topologies (Fig. 7 setup:
    flat vs location-hierarchical vs HFLOP)."""
    out = {}
    for name, assign in assigns.items():
        if assign is None:           # flat FL
            topo = ClusterTopology.flat(inst.n, lam=inst.lam)
        else:
            topo = ClusterTopology(assign=np.asarray(assign),
                                   n_devices=inst.n, n_edges=inst.m,
                                   lam=inst.lam, r=inst.r, l=inst.l)
        out[name] = simulate(topo, cfg)
    return out
