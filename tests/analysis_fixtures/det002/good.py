"""DET002 good fixture: the audited wall-clock seam."""
from repro.telemetry.tracer import wall_clock


def stamp():
    return wall_clock()
