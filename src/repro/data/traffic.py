"""Synthetic METR-LA-like traffic dataset (offline container: the real
loop-detector data cannot be downloaded, so we generate a statistically
faithful stand-in and note the substitution in DESIGN.md/EXPERIMENTS.md).

Mimics the paper's §V-A setup: 207 sensors on LA highways, 5-minute
readings, 4 months (34,272 timestamps), strong daily periodicity with
rush-hour congestion, weekend effects, sensor-specific base speeds,
4 geographic clusters with correlated congestion, and occasional
incident-like drops.  Values are speeds in mph, normalized per sensor
for training exactly like standard METR-LA pipelines."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

STEPS_PER_DAY = 288                  # 5-minute readings
N_SENSORS = 207
N_CLUSTERS = 4


@dataclass
class TrafficDataset:
    speeds: np.ndarray               # (T, n_sensors) mph
    cluster_of: np.ndarray           # (n_sensors,) geographic cluster id
    positions: np.ndarray            # (n_sensors, 2) synthetic coordinates
    mean: np.ndarray                 # per-sensor normalization
    std: np.ndarray

    @property
    def num_steps(self) -> int:
        return self.speeds.shape[0]

    def normalized(self) -> np.ndarray:
        return (self.speeds - self.mean) / self.std


def generate(num_days: int = 119, n_sensors: int = N_SENSORS,
             seed: int = 0) -> TrafficDataset:
    """~4 months of 5-min data (119 days ~= 34,272 stamps for 288/day)."""
    rng = np.random.default_rng(seed)
    T = num_days * STEPS_PER_DAY
    t = np.arange(T)
    tod = (t % STEPS_PER_DAY) / STEPS_PER_DAY          # time of day [0,1)
    dow = (t // STEPS_PER_DAY) % 7
    weekend = (dow >= 5).astype(float)

    # geographic clusters on a synthetic map
    centers = rng.uniform(0, 10, (N_CLUSTERS, 2))
    cluster_of = rng.integers(0, N_CLUSTERS, n_sensors)
    positions = centers[cluster_of] + rng.normal(0, 0.8, (n_sensors, 2))

    # base free-flow speed per sensor
    base = rng.uniform(55, 68, n_sensors)

    # rush-hour congestion: morning (7:30~=0.3) and evening (17:30~=0.73)
    def bump(center, width, depth):
        return depth * np.exp(-0.5 * ((tod - center) / width) ** 2)

    am = bump(0.31, 0.045, 1.0)
    pm = bump(0.73, 0.055, 1.0)
    # per-cluster congestion severity + per-sensor jitter
    sev_am = rng.uniform(8, 22, N_CLUSTERS)[cluster_of] \
        * rng.uniform(0.8, 1.2, n_sensors)
    sev_pm = rng.uniform(10, 26, N_CLUSTERS)[cluster_of] \
        * rng.uniform(0.8, 1.2, n_sensors)
    cong = (am[:, None] * sev_am[None, :] + pm[:, None] * sev_pm[None, :])
    cong *= (1.0 - 0.65 * weekend)[:, None]           # light weekends

    # slow seasonal drift + cluster-correlated daily noise (AR(1))
    drift = 2.0 * np.sin(2 * np.pi * t / (STEPS_PER_DAY * 30))[:, None]
    ar = np.zeros((T, N_CLUSTERS))
    eps = rng.normal(0, 1.0, (T, N_CLUSTERS))
    for k in range(1, T):
        ar[k] = 0.97 * ar[k - 1] + eps[k]
    ar = ar / ar.std(axis=0, keepdims=True) * 2.2

    speeds = (base[None, :] - cong + drift + ar[:, cluster_of]
              + rng.normal(0, 1.6, (T, n_sensors)))

    # incident-like drops: random sensor, 30-120 min, 40-70% speed loss
    n_incidents = num_days * 3
    for _ in range(n_incidents):
        s = rng.integers(0, n_sensors)
        start = rng.integers(0, T - 24)
        dur = rng.integers(6, 24)
        speeds[start:start + dur, s] *= rng.uniform(0.3, 0.6)

    speeds = np.clip(speeds, 3.0, 75.0).astype(np.float32)
    mean = speeds.mean(axis=0)
    std = speeds.std(axis=0) + 1e-6
    return TrafficDataset(speeds=speeds, cluster_of=cluster_of,
                          positions=positions, mean=mean, std=std)


def inject_drift(ds: TrafficDataset, start_step: int,
                 severity: float = 0.35, ramp_steps: int = STEPS_PER_DAY,
                 sensors: Optional[np.ndarray] = None) -> TrafficDataset:
    """Concept drift for the reactive-orchestration loop: from
    ``start_step`` on, a regime change (lane closures / rerouted demand)
    depresses speeds by up to ``severity`` with a linear onset ramp.

    The returned dataset keeps the ORIGINAL per-sensor normalization —
    a model trained pre-drift sees genuinely shifted inputs, so its
    validation MSE rises (the accuracy-alarm trigger), instead of the
    drift being silently absorbed into re-standardization."""
    speeds = ds.speeds.copy()
    T = speeds.shape[0]
    if not 0 <= start_step < T:
        raise ValueError(f"start_step {start_step} outside [0, {T})")
    idx = (np.asarray(sensors, int) if sensors is not None
           else np.arange(speeds.shape[1]))
    ramp = np.clip((np.arange(T - start_step) + 1) / max(ramp_steps, 1),
                   0.0, 1.0)
    factor = 1.0 - severity * ramp
    speeds[start_step:, idx] = np.clip(
        speeds[start_step:, idx] * factor[:, None], 3.0, 75.0)
    return TrafficDataset(speeds=speeds, cluster_of=ds.cluster_of,
                          positions=ds.positions, mean=ds.mean, std=ds.std)


# ---------------------------------------------------------------------------
# windowing (per-sensor supervised samples)
# ---------------------------------------------------------------------------

def windows_for_sensor(ds: TrafficDataset, sensor: int, start: int,
                       end: int, history: int = 12
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Sliding windows over normalized speeds in [start, end):
    X (N, history, 1), y (N, 1) — predict the next 5-min value."""
    z = ds.normalized()[start:end, sensor]
    N = len(z) - history
    if N <= 0:
        raise ValueError("window range too short")
    idx = np.arange(N)[:, None] + np.arange(history)[None, :]
    X = z[idx][..., None].astype(np.float32)
    y = z[idx[:, -1] + 1][:, None].astype(np.float32)
    return X, y


def continual_split(ds: TrafficDataset, round_idx: int,
                    train_days: int = 21, val_days: int = 7,
                    shift_steps: int = 36) -> Tuple[slice, slice]:
    """Paper §V-B2: 3 weeks train + 1 week validation; after each
    aggregation round the window shifts by ``shift_steps`` timestamps to
    simulate time passing."""
    start = round_idx * shift_steps
    train_end = start + train_days * STEPS_PER_DAY
    val_end = train_end + val_days * STEPS_PER_DAY
    if val_end > ds.num_steps:
        raise ValueError(f"round {round_idx} exceeds dataset length")
    return slice(start, train_end), slice(train_end, val_end)


def select_fl_sensors(ds: TrafficDataset, per_cluster: int = 5,
                      seed: int = 0) -> np.ndarray:
    """Paper §V-B2: 5 random sensors from each of the 4 clusters -> 20 FL
    clients."""
    rng = np.random.default_rng(seed)
    chosen: List[int] = []
    for k in range(N_CLUSTERS):
        members = np.nonzero(ds.cluster_of == k)[0]
        take = min(per_cluster, len(members))
        chosen.extend(rng.choice(members, take, replace=False))
    return np.asarray(chosen)
