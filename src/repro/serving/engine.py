"""Serving engine: one-shot jitted prefill + slot-based continuous-batching
decode over the unified model API.

The engine owns a fixed number of *slots* (``batch_size``).  Each slot
holds one in-flight sequence: its KV/state cache, absolute position and
next input token.  Admission runs a single jitted **prefill** program
(full-sequence forward writing the cache in one scatter — see
``transformer.prefill``), or, for the inherently recurrent families
(ssm / hybrid / audio), a fused ``lax.scan`` over decode steps compiled
into one program.  All active slots then share ONE jitted decode program
(``decode_step`` vmapped over slots with per-slot positions), so
heterogeneous Poisson arrivals genuinely batch together: a sequence can be
admitted into slot 3 while slot 0 is 400 tokens into its generation.

The seed token-by-token prompt path is kept as ``generate_sequential`` —
it is the baseline that ``benchmarks/perf_serving_scheduler.py`` measures
the prefill path against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import make_model
from repro.telemetry import Telemetry, maybe as _maybe_tel


def bucket_len(n: int, lo: int = 8) -> int:
    """Smallest power-of-two >= n (>= lo): prompts are right-padded to
    buckets so the number of distinct prefill compilations stays
    O(log max_prompt_len)."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass(frozen=True)
class EngineMeasurement:
    """Wall-clock engine timings — the raw material for
    ``LatencyModel.from_measurements`` (routing/latency.py)."""
    prefill_ms: float              # one admission of a prompt_len prompt
    decode_ms_per_token: float     # one continuous-batching step
    batch_size: int                # slots sharing the decode program
    prompt_len: int
    decode_steps: int


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 batch_size: int, max_len: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        self.cfg = cfg
        self._tel = _maybe_tel(telemetry)
        self.api = make_model(cfg)
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len or cfg.run.max_cache_len
        template = self.api.init_cache(1, self.max_len)
        if template is None:
            raise ValueError(
                f"{cfg.name}: family {cfg.model.family!r} has no decode "
                "cache — serve it per-request via ReplicaPool instead")
        # per-slot cache: every leaf gains a leading slot axis, and each
        # slot keeps its own ring index / positions
        self._slot_template = template
        self.cache = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (batch_size,) + x.shape),
            template)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.next_tok = jnp.zeros((batch_size, 1, 1), jnp.int32)
        self.free_slots: List[int] = list(range(batch_size))

        self._decode = jax.jit(
            jax.vmap(self._slot_decode, in_axes=(None, 0, 0, 0)))
        self._prefill = jax.jit(self._prefill_impl)
        self._insert = jax.jit(self._insert_impl)
        self._seq_decode = jax.jit(self._seq_decode_impl)

    # -- jitted programs ----------------------------------------------------

    def _slot_decode(self, params, tok, pos, cache):
        """One decode step for one slot (vmapped over slots)."""
        logits, cache = self.api.decode_step(params, tok, pos, cache)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache

    def _prefill_impl(self, params, tokens, length, cache):
        """tokens (1, S_bucket) right-padded; length () valid tokens.
        Returns (first generated token (1,), prefilled cache)."""
        if self.api.prefill is not None:
            logits, cache = self.api.prefill(params, tokens, cache,
                                             length=length)
            last = logits[:, length - 1, :]
        else:
            # recurrent families: fused scan over decode steps — still ONE
            # program per bucket instead of S python-level dispatches
            S = tokens.shape[1]
            toks = tokens.T[:, :, None]                  # (S, 1, 1)
            ts = jnp.arange(S, dtype=jnp.int32)

            def body(c, xs):
                tok, t = xs
                logits, new_c = self.api.decode_step(params, tok, t, c)
                keep = t < length
                c = jax.tree.map(lambda n, o: jnp.where(keep, n, o),
                                 new_c, c)
                return c, logits[:, -1, :]

            cache, ys = jax.lax.scan(body, cache, (toks, ts))
            last = ys[length - 1]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    def _insert_impl(self, cache, new, slot):
        return jax.tree.map(lambda c, n: c.at[slot].set(n), cache, new)

    def _seq_decode_impl(self, params, tokens, pos, cache):
        logits, cache = self.api.decode_step(params, tokens, pos, cache)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache

    # -- slot management ----------------------------------------------------

    def acquire_slot(self) -> Optional[int]:
        return self.free_slots.pop(0) if self.free_slots else None

    def admit(self, prompt, slot: int) -> int:
        """Prefill ``prompt`` (S,) into ``slot``.  Returns the first
        generated (greedy) token."""
        if self._tel is not None:
            with self._tel.tracer.wall("serve.admit", cat="serving",
                                       slot=int(slot)):
                first = self._admit_impl(prompt, slot)
            self._tel.metrics.counter("serve.admissions").inc()
            return first
        return self._admit_impl(prompt, slot)

    def _admit_impl(self, prompt, slot: int) -> int:
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        S = prompt.shape[1]
        if S > self.max_len:
            raise ValueError(f"prompt ({S}) exceeds max_len {self.max_len}")
        Sb = bucket_len(S)
        padded = jnp.zeros((1, Sb), jnp.int32).at[:, :S].set(prompt)
        first, slot_cache = self._prefill(self.params, padded,
                                          jnp.int32(S), self._slot_template)
        self.cache = self._insert(self.cache, slot_cache, jnp.int32(slot))
        self.pos = self.pos.at[slot].set(S)
        self.next_tok = self.next_tok.at[slot, 0, 0].set(first[0])
        if slot in self.free_slots:
            self.free_slots.remove(slot)
        return int(first[0])

    def evict(self, slot: int) -> None:
        """Release a slot.  Its stale cache is simply overwritten by the
        next admission — no device work."""
        if slot not in self.free_slots:
            self.free_slots.append(slot)
            if self._tel is not None:
                self._tel.metrics.counter("serve.evictions").inc()

    @property
    def active_slots(self) -> int:
        return self.batch_size - len(self.free_slots)

    # -- decode -------------------------------------------------------------

    def decode(self) -> np.ndarray:
        """One continuous-batching step: every slot advances one token
        under its own position.  Returns (batch_size,) token ids (entries
        for free slots are meaningless)."""
        toks, self.cache = self._decode(self.params, self.next_tok,
                                        self.pos, self.cache)
        self.pos = self.pos + 1
        self.next_tok = toks[:, :, None]
        if self._tel is not None:
            self._tel.metrics.counter("serve.decode_steps").inc()
        return np.asarray(toks[:, 0])

    # -- convenience generation paths --------------------------------------

    def generate(self, prompt_tokens: jax.Array, steps: int) -> jax.Array:
        """Greedy generation via prefill + continuous-batching decode.
        Returns (B, steps) — same contract as the seed engine.

        Requires an idle engine: ``decode`` advances *every* slot, so
        interleaving ``generate`` with externally managed sequences would
        silently consume their tokens.  Mixed workloads go through
        ``ContinuousBatchingScheduler`` instead."""
        B, S = prompt_tokens.shape
        if B > self.batch_size:
            raise ValueError(f"batch {B} exceeds {self.batch_size} slots")
        if self.active_slots:
            raise RuntimeError(
                "engine has active sequences; drive mixed workloads "
                "through ContinuousBatchingScheduler")
        slots = [self.acquire_slot() for _ in range(B)]
        first = [self.admit(prompt_tokens[b], slot=s)
                 for b, s in enumerate(slots)]
        out = [np.asarray(first, np.int32)]
        for _ in range(steps - 1):
            toks = self.decode()
            out.append(toks[np.asarray(slots)])
        for s in slots:
            self.evict(s)
        return jnp.asarray(np.stack(out, axis=1))

    def generate_sequential(self, prompt_tokens: jax.Array,
                            steps: int) -> jax.Array:
        """The seed path: feeds the prompt token-by-token (S sequential
        decode dispatches) then samples ``steps`` continuations.  Kept as
        the baseline for the prefill speedup benchmark."""
        B, S = prompt_tokens.shape
        cache = self.api.init_cache(B, self.max_len)
        tok = None
        for s in range(S):
            tok, cache = self._seq_decode(self.params,
                                          prompt_tokens[:, s:s + 1],
                                          jnp.int32(s), cache)
        out = []
        for t in range(steps):
            out.append(tok)
            tok, cache = self._seq_decode(self.params, tok[:, None],
                                          jnp.int32(S + t), cache)
        return jnp.stack(out, axis=1)

    # -- calibration --------------------------------------------------------

    def measure(self, prompt_len: int = 64, decode_steps: int = 16,
                seed: int = 0) -> EngineMeasurement:
        """Measure wall-clock prefill and continuous-batching step times
        (after a warmup pass that triggers compilation).

        Safe to call mid-serving: the engine's slot state (caches,
        positions, pending tokens) is snapshotted before and restored
        after, so in-flight sequences resume exactly where they were —
        the measurement decodes never reach them."""
        if self._tel is not None:
            with self._tel.tracer.wall("serve.measure", cat="serving",
                                       prompt_len=int(prompt_len),
                                       decode_steps=int(decode_steps)):
                return self._measure_impl(prompt_len, decode_steps, seed)
        return self._measure_impl(prompt_len, decode_steps, seed)

    def _measure_impl(self, prompt_len: int, decode_steps: int,
                      seed: int) -> EngineMeasurement:
        saved = (self.cache, self.pos, self.next_tok,
                 list(self.free_slots))
        rng = np.random.default_rng(seed)
        vocab = max(self.cfg.model.vocab_size, 2)
        prompt = rng.integers(0, vocab, (prompt_len,))
        slot = self.free_slots[0] if self.free_slots else 0
        try:
            self.admit(prompt, slot=slot)        # warmup: compile prefill
            self.decode()                        # warmup: compile decode
            t0 = time.perf_counter()
            self.admit(prompt, slot=slot)
            prefill_ms = (time.perf_counter() - t0) * 1e3
            t0 = time.perf_counter()
            for _ in range(decode_steps):
                self.decode()
            decode_ms = (time.perf_counter() - t0) * 1e3 \
                / max(decode_steps, 1)
        finally:
            self.cache, self.pos, self.next_tok, self.free_slots = saved
        return EngineMeasurement(prefill_ms=prefill_ms,
                                 decode_ms_per_token=decode_ms,
                                 batch_size=self.batch_size,
                                 prompt_len=prompt_len,
                                 decode_steps=decode_steps)
