"""internvl2-76b [vlm] — InternViT + llama3-70b-class language model.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.  The InternViT
vision encoder + MLP projector is a STUB: input_specs() provides
precomputed patch embeddings already projected to d_model.
[arXiv:2404.16821]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, FrontendConfig,
                                ModelConfig, RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="internvl2-76b",
        family="vlm",
        source="arXiv:2404.16821",
        num_layers=80,
        d_model=8192,
        d_ff=28_672,
        vocab_size=128_256,
        attention=AttentionConfig(
            kind="full",
            num_heads=64,
            num_kv_heads=8,
            head_dim=128,
            rope_theta=500_000.0,
        ),
        frontend=FrontendConfig(kind="vision_patches", num_positions=256,
                                embed_dim=8192),
    ),
    run=RunConfig(microbatches=8, remat="layer", opt_state_dtype="float32"),
)
