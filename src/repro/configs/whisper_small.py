"""whisper-small [audio] — encoder-decoder transformer backbone.

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865.  The mel-spectrogram
+ conv feature extractor frontend is a STUB: input_specs() provides
precomputed frame embeddings (1500, 768).
[arXiv:2212.04356]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, FrontendConfig,
                                ModelConfig, RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="whisper-small",
        family="audio",
        source="arXiv:2212.04356",
        num_layers=12,              # decoder layers
        encoder_layers=12,
        is_encoder_decoder=True,
        d_model=768,
        d_ff=3072,
        vocab_size=51_865,
        norm="layernorm",
        act="gelu",
        attention=AttentionConfig(
            kind="full",
            num_heads=12,
            num_kv_heads=12,
            head_dim=64,
            rope_theta=0.0,        # whisper uses learned/sinusoidal positions
        ),
        frontend=FrontendConfig(kind="audio_frames", num_positions=1500,
                                embed_dim=768),
        tie_embeddings=True,
    ),
    run=RunConfig(microbatches=1, remat="layer"),
)
