"""TEL001 good fixture: guarded blocks touch telemetry state only."""


class Handler:
    def __init__(self, sim, tel):
        self.sim = sim
        self._tel = tel
        if self._tel is not None:
            m = self._tel.metrics               # tel-derived local
            self._ev_counter = m.counter("events")
            self._lat_hist = m.histogram("latency")

    def on_event(self, ev):
        if self._tel is not None:
            self._ev_counter.inc()
            self._tel.tracer.instant("event", ev.t, kind=str(ev.kind))
            local = {}                          # block-local scratch
            local["t"] = ev.t
            self._tel.audit.record(local)
