"""Request-engine throughput: vectorized batched plane vs per-request
heap, on the paper's Fig. 7 configuration.

Measures end-to-end ``simulate()`` wall-clock (arrival generation,
routing, admission, service, logging) for both engines on the same
seeded workload and reports simulated requests per second, the
batched/heap speedup, and the distributional parity (p50/p95 relative
difference, tier fractions).  A second section runs the full
co-simulation (training interference + reactive loop) both ways and
checks the stronger co-sim guarantee: **bit-identical** request logs
and control-plane trace fingerprints — there routing is deterministic
and the batched engine consumes the RTT stream in heap order.

A third section measures the **calibrated** (occupancy-coupled)
service path on a *provisioned* Fig. 7 continuum — capacity tracks the
traffic, so contention lives in serving occupancy rather than
admission throttling, the regime the per-request scalar replay used to
pay for every admitted request.  It reports engine-only simulated
requests/sec (arrivals pre-drawn outside the timer) for the constant
model, the calibrated model through the vectorized
``occupancy_replay`` bulk path, and the per-request heap engine as the
scalar-replay reference, plus the calibrated/constant ratio.

  python -m benchmarks.perf_event_throughput             # full (~1 min)
  python -m benchmarks.perf_event_throughput --smoke     # CI seconds
  python -m benchmarks.perf_event_throughput --rate-scale 100  # 10^6 reqs
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, Tuple

import numpy as np

from repro.core import solve_heuristic
from repro.core.topology import ClusterTopology
from repro.routing import CalibratedLatencyModel, LatencyModel, SimConfig, \
    simulate
from repro.routing.simulator import RequestProcessor
from repro.serving.workload import poisson_request_arrays
from repro.sim.events import EventKind, Simulation, control_trace
from repro.sim.scenarios import SCENARIOS, run_scenario

from benchmarks.common import emit
from benchmarks.fig7_inference_latency import build_scenario


def fig7_topology(seed: int = 0) -> ClusterTopology:
    """The Fig. 7 hot-zone continuum under the HFLOP assignment."""
    inst, _ = build_scenario(seed)
    sol = solve_heuristic(inst)
    return ClusterTopology(assign=np.asarray(sol.assign),
                           n_devices=inst.n, n_edges=inst.m,
                           lam=inst.lam, r=inst.r, l=inst.l)


def provisioned_fig7(seed: int = 0,
                     rate_scale: float = 100.0) -> ClusterTopology:
    """Fig. 7 continuum with edge capacity scaled alongside the request
    rate: admission keeps up, so the contention the calibrated model
    resolves sits in serving occupancy (the Fig. 7/8 oversubscription
    regime), not in the leaky bucket."""
    topo = fig7_topology(seed)
    return ClusterTopology(assign=topo.assign.copy(),
                           n_devices=topo.n_devices, n_edges=topo.n_edges,
                           lam=topo.lam, r=topo.r * rate_scale, l=topo.l)


def _engine_only_run(topo: ClusterTopology, lat, duration_s: float,
                     rate_scale: float, seed: int, engine: str,
                     telemetry=None) -> Tuple[int, float]:
    """(requests, wall seconds) for one engine pass with arrivals
    pre-drawn outside the timer — isolates the request engine itself.
    Devices are always busy (continual training), so routing is
    deterministic and every request exercises the edge/occupancy path."""
    rng = np.random.default_rng(seed)
    t_arr, dev = poisson_request_arrays(topo.lam * rate_scale, duration_s,
                                        rng)
    sim = Simulation()
    if engine == "heap":
        proc = RequestProcessor(topo, rng, latency=lat, engine="heap",
                                busy_fn=lambda i, t: True,
                                telemetry=telemetry)
        proc.bind(sim)
        for tt, dd in zip(t_arr, dev):
            sim.schedule(tt, EventKind.REQUEST_ARRIVAL, node=int(dd))
    else:
        proc = RequestProcessor(
            topo, rng, latency=lat, engine="batched",
            busy_mask_fn=lambda d, ts: np.ones(d.size, dtype=bool),
            telemetry=telemetry)
        proc.bind(sim)
        proc.add_arrivals(t_arr, dev)
    t0 = time.perf_counter()
    sim.run(until=duration_s)
    return int(t_arr.size), time.perf_counter() - t0


def run_calibrated(duration_s: float = 240.0, rate_scale: float = 100.0,
                   seed: int = 0, service_ms: float = 40.0,
                   slots_headroom: float = 1.25,
                   heap_fraction: float = 1.0 / 16.0) -> Dict[str, float]:
    """Calibrated-vs-constant engine throughput on the provisioned
    continuum.  ``slots`` sits ``slots_headroom`` above the occupancy
    knee (capacity x service time), so edges run near saturation with
    genuine oversubscription stretches — the regime where service and
    occupancy couple.  The heap engine measures the per-request scalar
    replay on a ``heap_fraction`` slice of the horizon (it would take
    minutes on the full one)."""
    topo = provisioned_fig7(seed, rate_scale)
    knee = float(topo.r[0]) * service_ms / 1000.0
    slots = max(int(round(knee * slots_headroom)), 1)
    lat_cal = CalibratedLatencyModel(tier_service_ms={"edge": service_ms},
                                     tier_slots={"edge": slots})
    out: Dict[str, float] = {}
    n_const, w_const = _engine_only_run(topo, LatencyModel(), duration_s,
                                        rate_scale, seed, "batched")
    rps_const = n_const / max(w_const, 1e-9)
    out["constant_requests_per_s"] = rps_const
    emit("event_engine_batched_provisioned", w_const * 1e6,
         f"requests={n_const};requests_per_s={rps_const:.0f};"
         f"rate_scale={rate_scale:g};engine_only=yes")
    n_cal, w_cal = _engine_only_run(topo, lat_cal, duration_s, rate_scale,
                                    seed, "batched")
    rps_cal = n_cal / max(w_cal, 1e-9)
    out["calibrated_requests_per_s"] = rps_cal
    ratio = rps_const / max(rps_cal, 1e-9)
    out["vs_constant"] = ratio
    emit("event_engine_batched_calibrated", w_cal * 1e6,
         f"requests={n_cal};requests_per_s={rps_cal:.0f};"
         f"slots={slots};service_ms={service_ms:g};"
         f"vs_constant={ratio:.2f};target_vs_constant=3;engine_only=yes")
    heap_dur = max(duration_s * heap_fraction, 5.0)
    n_heap, w_heap = _engine_only_run(topo, lat_cal, heap_dur, rate_scale,
                                      seed, "heap")
    rps_heap = n_heap / max(w_heap, 1e-9)
    out["scalar_requests_per_s"] = rps_heap
    speedup = rps_cal / max(rps_heap, 1e-9)
    out["speedup_vs_scalar"] = speedup
    emit("event_engine_heap_calibrated", w_heap * 1e6,
         f"requests={n_heap};requests_per_s={rps_heap:.0f};"
         f"batched_speedup={speedup:.1f};engine_only=yes")
    return out


def run_telemetry_overhead(duration_s: float = 60.0,
                           rate_scale: float = 50.0, seed: int = 0,
                           floor: float = 0.90,
                           repeats: int = 7) -> Dict[str, float]:
    """Telemetry-overhead gate on the batched request plane: the same
    engine-only pass with metrics recording off vs on.  The enabled
    pass must hold ``floor`` (90%) of the disabled-mode requests/sec —
    the ``vs_disabled`` field is what ``scripts/ci.sh`` checks.

    One pass at the smoke config is tens of milliseconds of wall time,
    so a single-shot ratio is scheduler noise: after a warmup pass per
    mode, the off/on passes run **interleaved** for ``repeats`` rounds
    (so clock-speed drift hits both modes alike) and the ratio
    compares the best (minimum-wall) pass of each — the standard
    microbenchmark estimator for the code path's intrinsic cost."""
    from repro.telemetry import Telemetry
    topo = provisioned_fig7(seed, rate_scale)
    lat = LatencyModel()
    tel = Telemetry()

    def one(telemetry):
        return _engine_only_run(topo, lat, duration_s, rate_scale, seed,
                                "batched", telemetry=telemetry)

    one(None)                                                  # warmup
    one(tel)
    n_off = n_on = 0
    w_off = w_on = float("inf")
    for _ in range(repeats):
        n_off, wi = one(None)
        w_off = min(w_off, wi)
        n_on, wi = one(tel)
        w_on = min(w_on, wi)
    rps_off = n_off / max(w_off, 1e-9)
    emit("event_engine_batched_telemetry_off", w_off * 1e6,
         f"requests={n_off};requests_per_s={rps_off:.0f};"
         f"rate_scale={rate_scale:g};repeats={repeats};engine_only=yes")
    rps_on = n_on / max(w_on, 1e-9)
    ratio = rps_on / max(rps_off, 1e-9)
    # every repeat recorded the same workload into the same registry
    recorded = tel.metrics.value("requests.total") / (repeats + 1)
    emit("event_engine_batched_telemetry", w_on * 1e6,
         f"requests={n_on};requests_per_s={rps_on:.0f};"
         f"vs_disabled={ratio:.3f};floor={floor:g};"
         f"recorded_per_pass={recorded:.0f};repeats={repeats};"
         f"engine_only=yes")
    if int(recorded) != n_on:
        print(f"# WARNING: telemetry recorded {recorded:.0f} requests "
              f"per pass, engine processed {n_on}", file=sys.stderr)
    return {"telemetry_off_requests_per_s": rps_off,
            "telemetry_on_requests_per_s": rps_on,
            "vs_disabled": ratio}


def run(duration_s: float = 600.0, rate_scale: float = 1.0, seed: int = 0,
        parity_scenarios: Tuple[str, ...] = ("straggler", "churn"),
        parity_duration_s: float = 60.0,
        calibrated_duration_s: float = 120.0,
        calibrated_rate_scale: float = 100.0) -> Dict[str, float]:
    """One engine-vs-engine measurement + parity check.  Returns the
    headline numbers (also CSV-emitted)."""
    topo = fig7_topology(seed)
    out: Dict[str, float] = {}
    logs = {}
    for engine in ("heap", "batched"):
        cfg = SimConfig(duration_s=duration_s, seed=seed, engine=engine,
                        rate_scale=rate_scale)
        t0 = time.perf_counter()
        log = simulate(topo, cfg)
        wall = time.perf_counter() - t0
        logs[engine] = log
        rps = log.t.size / wall if wall > 0 else float("inf")
        out[f"{engine}_requests_per_s"] = rps
        emit(f"event_engine_{engine}", wall * 1e6,
             f"requests={log.t.size};wall_s={wall:.3f};"
             f"requests_per_s={rps:.0f};rate_scale={rate_scale:g}")
    speedup = (out["batched_requests_per_s"]
               / max(out["heap_requests_per_s"], 1e-9))
    out["speedup"] = speedup
    emit("event_engine_speedup", speedup,
         f"speedup={speedup:.1f};target=50")

    # distributional parity on the inference-only path (the busy coin
    # flip interleaves generator draws differently per engine, so the
    # logs agree in distribution, not bit-for-bit)
    lh, lb = logs["heap"], logs["batched"]
    p50h, p50b = lh.percentile_latency(50), lb.percentile_latency(50)
    p95h, p95b = lh.percentile_latency(95), lb.percentile_latency(95)
    d50 = abs(p50h - p50b) / max(p50h, 1e-9)
    d95 = abs(p95h - p95b) / max(p95h, 1e-9)
    tiers_match = np.array_equal(lh.tier, lb.tier)
    out["p50_rel_diff"], out["p95_rel_diff"] = d50, d95
    emit("event_engine_parity_simulate", max(d50, d95) * 1e6,
         f"p50_rel_diff={d50:.5f};p95_rel_diff={d95:.5f};"
         f"tiers_identical={'yes' if tiers_match else 'NO'};tol=0.01")

    # bit-exact parity on the co-sim path, across the scenario engine
    all_bit = True
    for sc_name in parity_scenarios:
        for policy in ("reactive", "budgeted"):
            rb = run_scenario(SCENARIOS[sc_name](), policy=policy,
                              seed=seed, duration_s=parity_duration_s,
                              engine="batched")
            rh = run_scenario(SCENARIOS[sc_name](), policy=policy,
                              seed=seed, duration_s=parity_duration_s,
                              engine="heap")
            bit = (rb.control_fingerprint() == rh.control_fingerprint()
                   and np.array_equal(rb.log.latency_ms, rh.log.latency_ms)
                   and control_trace(rb.trace) == control_trace(rh.trace))
            all_bit &= bit
            emit(f"event_engine_parity_{sc_name}_{policy}",
                 0.0 if bit else 1.0,
                 f"control_fp_identical={'yes' if bit else 'NO'};"
                 f"n_requests={rb.log.t.size}")
    out["cosim_bit_identical"] = 1.0 if all_bit else 0.0

    # calibrated (occupancy-coupled) fast path on the provisioned
    # continuum — the configuration the vectorized occupancy replay
    # exists for
    cal = run_calibrated(duration_s=calibrated_duration_s,
                         rate_scale=calibrated_rate_scale, seed=seed)
    out["calibrated_requests_per_s"] = cal["calibrated_requests_per_s"]
    out["calibrated_vs_constant"] = cal["vs_constant"]
    out["calibrated_vs_scalar"] = cal["speedup_vs_scalar"]

    # telemetry-overhead gate: enabled-mode recording on the batched
    # plane must stay within 10% of disabled-mode throughput
    tel = run_telemetry_overhead(duration_s=calibrated_duration_s,
                                 rate_scale=calibrated_rate_scale,
                                 seed=seed)
    out["telemetry_vs_disabled"] = tel["vs_disabled"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="lambda multiplier (100 -> ~10^6 requests; "
                         "the heap side is what takes the time)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI sizes (shorter horizon)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        out = run(duration_s=240.0, rate_scale=args.rate_scale,
                  seed=args.seed, parity_duration_s=45.0,
                  calibrated_duration_s=60.0, calibrated_rate_scale=50.0)
    else:
        out = run(duration_s=args.duration, rate_scale=args.rate_scale,
                  seed=args.seed)
    print(f"\nbatched {out['batched_requests_per_s']:,.0f} req/s vs heap "
          f"{out['heap_requests_per_s']:,.0f} req/s -> "
          f"{out['speedup']:.1f}x; p50/p95 parity "
          f"{out['p50_rel_diff']:.5f}/{out['p95_rel_diff']:.5f}; "
          f"co-sim bit-identical: "
          f"{'yes' if out['cosim_bit_identical'] else 'NO'}")
    print(f"calibrated (occupancy-coupled) engine: "
          f"{out['calibrated_requests_per_s']:,.0f} req/s — "
          f"{out['calibrated_vs_constant']:.2f}x off the constant model, "
          f"{out['calibrated_vs_scalar']:.0f}x over the per-request "
          f"scalar replay")
    print(f"telemetry enabled holds {out['telemetry_vs_disabled']:.1%} "
          f"of disabled-mode throughput (floor 90%)")


if __name__ == "__main__":
    main()
