"""Hierarchical-FL training of a real LM architecture (the TPU-native
mapping from DESIGN.md §3, runnable on CPU): cluster-replicated
parameters, vmapped local steps (zero cross-cluster collectives), global
sync every l rounds with optional int8 error-feedback compression.

  PYTHONPATH=src python examples/train_lm_hfl.py --arch xlstm-125m \
      --steps 12 --clusters 2 --global-every 2 --compress
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import TokenStream, TokenStreamConfig
from repro.fl.collectives import cluster_divergence, stack_for_clusters
from repro.fl.compression import (compressed_global_sync, init_ef_state,
                                  sync_bytes)
from repro.models import make_model
from repro.training.optimizer import AdamW
from repro.training.train_step import make_hfl_train_step, hfl_global_round


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--global-every", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--full-size", action="store_true",
                    help="train the FULL config (slow on CPU)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"{args.arch}: {n_params / 1e6:.1f}M params, "
          f"{args.clusters} clusters, global sync every "
          f"{args.global_every} rounds, compress={args.compress}")

    C = args.clusters
    stacked = stack_for_clusters(params, C)
    opt = AdamW(lr=1e-3)
    opt_state = jax.vmap(opt.init)(stacked)
    local = jax.jit(make_hfl_train_step(api, cfg, opt))
    ef = init_ef_state(stacked) if args.compress else None
    streams = [TokenStream(TokenStreamConfig(
        vocab_size=max(cfg.model.vocab_size, 2), seq_len=args.seq,
        batch_size=args.batch), shard=c) for c in range(C)]

    for t in range(args.steps):
        batches = [s.next_batch() for s in streams]
        batch = {k: jnp.asarray(np.stack([b[k] for b in batches]))
                 for k in batches[0]}
        t0 = time.perf_counter()
        stacked, opt_state, losses = local(stacked, opt_state, batch)
        msg = (f"round {t:3d} losses="
               f"{[round(float(x), 3) for x in losses]}"
               f" ({time.perf_counter() - t0:.2f}s)")
        if (t + 1) % args.global_every == 0:
            div = float(cluster_divergence(stacked))
            if args.compress:
                stacked, ef = compressed_global_sync(stacked, ef)
                payload = sync_bytes(stacked, compressed=True)
            else:
                stacked = hfl_global_round(stacked)
                payload = sync_bytes(stacked, compressed=False)
            msg += (f" [GLOBAL SYNC: divergence {div:.2e}, "
                    f"payload {payload / 1e6:.1f} MB/cluster]")
        print(msg)


if __name__ == "__main__":
    main()
