"""Decoder-only transformer assembly covering the dense, moe and vlm
families (stablelm, h2o-danube, gemma3, llama3-405b, internvl2 LM,
deepseek-v2-lite, qwen2-moe).

Training/prefill runs a ``lax.scan`` over stacked layer parameters.
Heterogeneous layer *behaviour* (gemma3's 5 local : 1 global pattern,
per-layer rope bases) is expressed as traced per-layer scalars fed through
the scan, so the stack stays homogeneous.  DeepSeek's leading dense layer
is unstacked.  Decode uses a layer scan with stacked caches when the
cache geometry is uniform, else (gemma3) a python loop with per-layer
cache capacities (local layers keep only a 512-slot ring).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (ParamBuilder, shard, stack_axes,
                                 stack_params, to_dtype)
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embedding, init_mlp, init_norm,
                                 logits_from_hidden)
from repro.models.moe import apply_moe, init_moe
from repro.models.rope import rope_frequencies

FULL_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# per-layer static metadata
# ---------------------------------------------------------------------------

def layer_is_global(cfg: ModelConfig, i: int) -> bool:
    a = cfg.attention
    if a.kind != "local_global":
        return True
    return (i + 1) % (a.local_global_ratio + 1) == 0


def layer_window(cfg: ModelConfig, i: int) -> int:
    a = cfg.attention
    if a.kind == "swa":
        return a.window
    if a.kind == "local_global" and not layer_is_global(cfg, i):
        return a.window
    if a.kind == "full" and a.window:          # zamba2 shared block long mode
        return a.window
    return FULL_WINDOW

def layer_theta(cfg: ModelConfig, i: int) -> float:
    a = cfg.attention
    if a.kind == "local_global" and not layer_is_global(cfg, i):
        return a.rope_theta_local or a.rope_theta
    return a.rope_theta


def stacked_rope(cfg: ModelConfig, layers=None) -> jax.Array:
    a = cfg.attention
    idx = range(cfg.num_layers) if layers is None else layers
    hd = (a.mla.qk_rope_head_dim if a.kind == "mla" and a.mla else a.head_dim)
    rows = []
    for i in idx:
        th = layer_theta(cfg, i)
        if th == 0.0:
            rows.append(np.zeros((0,), np.float32))
        else:
            rows.append(np.asarray(
                rope_frequencies(hd, th, a.rope_fraction)))
    return jnp.asarray(np.stack(rows))


def stacked_windows(cfg: ModelConfig, layers=None) -> jax.Array:
    idx = range(cfg.num_layers) if layers is None else layers
    return jnp.asarray([layer_window(cfg, i) for i in idx], jnp.int32)


def sinusoidal_positions(S: int, d: int, offset=0) -> jax.Array:
    p = jnp.arange(S)[:, None] + offset
    k = jnp.arange(d // 2)[None, :]
    ang = p / (10000.0 ** (2 * k / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(rng, cfg: ModelConfig, *, moe_layer: bool,
                d_ff: Optional[int] = None):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    a = cfg.attention
    init_norm(pb, "ln1", cfg.d_model, cfg.norm)
    if a.kind == "mla":
        attn.init_mla(pb, "attn", cfg.d_model, a)
    else:
        attn.init_gqa(pb, "attn", cfg.d_model, a)
    init_norm(pb, "ln2", cfg.d_model, cfg.norm)
    if moe_layer:
        init_moe(pb, "moe", cfg.d_model, cfg.moe, cfg.act)
    else:
        init_mlp(pb, "mlp", cfg.d_model, d_ff or cfg.d_ff, cfg.act)
    return pb.build()


def init_params(rng, cfg: ModelConfig):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_embedding(pb, cfg)
    n_dense_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    for i in range(n_dense_lead):
        p, ax = _init_layer(jax.random.fold_in(rng, 1000 + i), cfg,
                            moe_layer=False,
                            d_ff=cfg.moe.dense_d_ff or cfg.d_ff)
        pb.subtree(f"lead/{i}", p, ax)
    stackable = range(n_dense_lead, cfg.num_layers)
    per_layer = [_init_layer(jax.random.fold_in(rng, 2000 + i), cfg,
                             moe_layer=cfg.moe is not None)
                 for i in stackable]
    stacked = stack_params([p for p, _ in per_layer])
    pb.subtree("layers", stacked, stack_axes(per_layer[0][1]))
    init_norm(pb, "final_norm", cfg.d_model, cfg.norm)
    return pb.build()


# ---------------------------------------------------------------------------
# one decoder layer
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, p, x, positions, inv_freq, window,
               moe_layer: bool):
    a = cfg.attention
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if a.kind == "mla":
        y = attn.mla_forward(p["attn"], a, h, positions, inv_freq)
    else:
        y = attn.gqa_forward(p["attn"], a, h, positions, inv_freq,
                             window=window)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if moe_layer:
        y, aux = apply_moe(p["moe"], cfg.moe, h, cfg.act)
    else:
        y, aux = apply_mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + y, aux


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds: Optional[jax.Array] = None,
            remat: str = "layer") -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S) [+ optional (B,P,d) prefix embeddings for vlm/audio].
    Returns (logits (B,S_total,V), aux_loss)."""
    x = embed_tokens(params, cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.attention.rope_theta == 0.0:      # learned-position-free fallback
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]

    moe_layer = cfg.moe is not None
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(n_lead):
        p = params["lead"][str(i)]
        x, aux = _layer_fwd(cfg, p, x, positions,
                            stacked_rope(cfg, [i])[0],
                            jnp.int32(layer_window(cfg, i)), False)
        aux_total += aux

    inv_freqs = stacked_rope(cfg, range(n_lead, cfg.num_layers))
    windows = stacked_windows(cfg, range(n_lead, cfg.num_layers))

    def body(carry, xs):
        xc, aux_c = carry
        p, ifr, win = xs
        xo, aux = _layer_fwd(cfg, p, xc, positions, ifr, win, moe_layer)
        return (xo, aux_c + aux), None

    body_fn = jax.checkpoint(body) if remat != "none" else body
    (x, aux_total), _ = jax.lax.scan(
        body_fn, (x, aux_total), (params["layers"], inv_freqs, windows))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), aux_total


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def _uniform_cache_geometry(cfg: ModelConfig) -> bool:
    wins = {layer_window(cfg, i) for i in range(cfg.num_layers)}
    return len(wins) == 1


def cache_capacity(cfg: ModelConfig, i: int, max_len: int) -> int:
    w = layer_window(cfg, i)
    return min(max_len, w) if w != FULL_WINDOW else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    if dtype is None:
        from repro.models.common import to_dtype
        dtype = to_dtype(cfg.dtype)
    a = cfg.attention
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    lead = {}
    for i in range(n_lead):
        cap = cache_capacity(cfg, i, max_len)
        lead[str(i)] = (attn.init_mla_cache(batch, cap, a, dtype)
                        if a.kind == "mla"
                        else attn.init_kv_cache(batch, cap, a.num_kv_heads,
                                                a.head_dim, dtype))
    rest = range(n_lead, cfg.num_layers)
    if _uniform_cache_geometry(cfg):
        cap = cache_capacity(cfg, n_lead, max_len)
        n = cfg.num_layers - n_lead
        if a.kind == "mla":
            per = [attn.init_mla_cache(batch, cap, a, dtype) for _ in range(n)]
        else:
            per = [attn.init_kv_cache(batch, cap, a.num_kv_heads,
                                      a.head_dim, dtype) for _ in range(n)]
        stackedc = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        return {"lead": lead, "layers": stackedc}
    per = {}
    for i in rest:
        cap = cache_capacity(cfg, i, max_len)
        per[str(i)] = attn.init_kv_cache(batch, cap, a.num_kv_heads,
                                         a.head_dim, dtype)
    return {"lead": lead, "layers": per}


def _layer_prefill(cfg, p, x, positions, length, cache, inv_freq, window,
                   moe_layer):
    a = cfg.attention
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if a.kind == "mla":
        y, cache = attn.mla_prefill(p["attn"], a, h, positions, length,
                                    cache, inv_freq)
    else:
        y, cache = attn.gqa_prefill(p["attn"], a, h, positions, length,
                                    cache, inv_freq, window=window)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if moe_layer:
        y, _ = apply_moe(p["moe"], cfg.moe, h, cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def prefill(params, cfg: ModelConfig, tokens: jax.Array, cache,
            length=None, extra_embeds=None):
    """One-shot prefill: the same full-sequence pass as :func:`forward`,
    but every layer also writes its KV/latent cache for positions
    ``[0, length)`` in a single scatter — S sequential decode steps
    collapse into one program.  ``tokens`` (B,S) may be right-padded
    beyond ``length``; returns (logits (B,S,V), cache ready for decode at
    position ``length``)."""
    if length is None:
        length = tokens.shape[1]
    length = jnp.asarray(length, jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.attention.rope_theta == 0.0:
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    moe_layer = cfg.moe is not None
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    new_lead = {}
    for i in range(n_lead):
        x, c = _layer_prefill(cfg, params["lead"][str(i)], x, positions,
                              length, cache["lead"][str(i)],
                              stacked_rope(cfg, [i])[0],
                              jnp.int32(layer_window(cfg, i)), False)
        new_lead[str(i)] = c
    rest = list(range(n_lead, cfg.num_layers))
    stacked = not isinstance(cache["layers"], dict)
    if stacked:
        inv_freqs = stacked_rope(cfg, rest)
        windows = stacked_windows(cfg, rest)

        def body(x_c, xs):
            p, c, ifr, win = xs
            xo, c2 = _layer_prefill(cfg, p, x_c, positions, length, c, ifr,
                                    win, moe_layer)
            return xo, c2

        x, new_stack = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], inv_freqs, windows))
        new_cache = {"lead": new_lead, "layers": new_stack}
    else:
        new_per = {}
        for i in rest:
            p = jax.tree.map(lambda a_: a_[i - n_lead], params["layers"])
            x, c = _layer_prefill(cfg, p, x, positions, length,
                                  cache["layers"][str(i)],
                                  stacked_rope(cfg, [i])[0],
                                  jnp.int32(layer_window(cfg, i)), moe_layer)
            new_per[str(i)] = c
        new_cache = {"lead": new_lead, "layers": new_per}
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# paged cache + paged prefill / decode
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=None):
    """Paged cache for every layer.  Unlike :func:`init_cache` the
    geometry is uniform by construction — windowed layers are handled by
    masking at score time, not by smaller rings — so the layer stack
    always scans, gemma3 included.  Page arrays are shared across
    sequences; per-layer arrays are stacked along a leading layer axis
    and indexed by the same pool-issued page ids."""
    if dtype is None:
        from repro.models.common import to_dtype
        dtype = to_dtype(cfg.dtype)
    a = cfg.attention

    def one():
        if a.kind == "mla":
            return attn.init_paged_mla_cache(num_pages, page_size, a, dtype)
        return attn.init_paged_kv_cache(num_pages, page_size,
                                        a.num_kv_heads, a.head_dim, dtype)

    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    lead = {str(i): one() for i in range(n_lead)}
    per = [one() for _ in range(cfg.num_layers - n_lead)]
    stackedc = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return {"lead": lead, "layers": stackedc}


def _layer_paged_prefill(cfg, p, x, positions, length, cache, block_tables,
                         inv_freq, window, moe_layer):
    a = cfg.attention
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if a.kind == "mla":
        y, cache = attn.paged_mla_prefill(p["attn"], a, h, positions,
                                          length, cache, block_tables,
                                          inv_freq)
    else:
        y, cache = attn.paged_gqa_prefill(p["attn"], a, h, positions,
                                          length, cache, block_tables,
                                          inv_freq, window=window)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if moe_layer:
        y, _ = apply_moe(p["moe"], cfg.moe, h, cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def paged_prefill(params, cfg: ModelConfig, tokens: jax.Array, cache,
                  block_tables: jax.Array, length=None):
    """One-shot prefill through the block table: same full-sequence math
    as :func:`prefill`, cache writes scattered into pool pages.  ``tokens``
    (B,S) right-padded past ``length``; ``block_tables`` (B, pages_per_seq)
    pool page ids.  Returns (logits (B,S,V), new paged cache)."""
    if length is None:
        length = tokens.shape[1]
    length = jnp.asarray(length, jnp.int32)
    x = embed_tokens(params, cfg, tokens)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.attention.rope_theta == 0.0:
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None]
    moe_layer = cfg.moe is not None
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    new_lead = {}
    for i in range(n_lead):
        x, c = _layer_paged_prefill(cfg, params["lead"][str(i)], x,
                                    positions, length, cache["lead"][str(i)],
                                    block_tables, stacked_rope(cfg, [i])[0],
                                    jnp.int32(layer_window(cfg, i)), False)
        new_lead[str(i)] = c
    rest = list(range(n_lead, cfg.num_layers))
    inv_freqs = stacked_rope(cfg, rest)
    windows = stacked_windows(cfg, rest)

    def body(x_c, xs):
        p, c, ifr, win = xs
        xo, c2 = _layer_paged_prefill(cfg, p, x_c, positions, length, c,
                                      block_tables, ifr, win, moe_layer)
        return xo, c2

    x, new_stack = jax.lax.scan(
        body, x, (params["layers"], cache["layers"], inv_freqs, windows))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), \
        {"lead": new_lead, "layers": new_stack}


def _layer_paged_decode(cfg, p, x, pos, cache, block_tables, inv_freq,
                        window, moe_layer):
    a = cfg.attention
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if a.kind == "mla":
        y, cache = attn.paged_mla_decode(p["attn"], a, h, pos, cache,
                                         block_tables, inv_freq)
    else:
        y, cache = attn.paged_gqa_decode(p["attn"], a, h, pos, cache,
                                         block_tables, inv_freq,
                                         window=window)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if moe_layer:
        y, _ = apply_moe(p["moe"], cfg.moe, h, cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def paged_decode_step(params, cfg: ModelConfig, tokens: jax.Array,
                      pos: jax.Array, cache, block_tables: jax.Array):
    """Batched paged decode: one program advances every live sequence.
    ``tokens`` (B,1); ``pos`` (B,) per-row absolute positions (free rows
    point their block table at the scratch page and are ignored by the
    caller).  Returns (logits (B,1,V), new paged cache)."""
    x = embed_tokens(params, cfg, tokens)
    if cfg.attention.rope_theta == 0.0:
        sp = jax.vmap(lambda po: sinusoidal_positions(1, cfg.d_model,
                                                      offset=po))(pos)
        x = x + sp.astype(x.dtype)
    moe_layer = cfg.moe is not None
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    new_lead = {}
    for i in range(n_lead):
        x, c = _layer_paged_decode(cfg, params["lead"][str(i)], x, pos,
                                   cache["lead"][str(i)], block_tables,
                                   stacked_rope(cfg, [i])[0],
                                   jnp.int32(layer_window(cfg, i)), False)
        new_lead[str(i)] = c
    rest = list(range(n_lead, cfg.num_layers))
    inv_freqs = stacked_rope(cfg, rest)
    windows = stacked_windows(cfg, rest)

    def body(x_c, xs):
        p, c, ifr, win = xs
        xo, c2 = _layer_paged_decode(cfg, p, x_c, pos, c, block_tables,
                                     ifr, win, moe_layer)
        return xo, c2

    x, new_stack = jax.lax.scan(
        body, x, (params["layers"], cache["layers"], inv_freqs, windows))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), \
        {"lead": new_lead, "layers": new_stack}


def _layer_decode(cfg, p, x, pos, cache, inv_freq, window, moe_layer):
    a = cfg.attention
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if a.kind == "mla":
        y, cache = attn.mla_decode(p["attn"], a, h, pos, cache, inv_freq)
    else:
        y, cache = attn.gqa_decode(p["attn"], a, h, pos, cache, inv_freq,
                                   window=window)
    x = x + y
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if moe_layer:
        y, _ = apply_moe(p["moe"], cfg.moe, h, cfg.act)
    else:
        y = apply_mlp(p["mlp"], h, cfg.act)
    return x + y, cache


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                cache, extra_embeds=None):
    """tokens (B,1); pos () int32 absolute position.  Returns
    (logits (B,1,V), new cache)."""
    x = embed_tokens(params, cfg, tokens)
    if cfg.attention.rope_theta == 0.0:
        x = x + sinusoidal_positions(1, cfg.d_model, offset=pos
                                     ).astype(x.dtype)[None]
    moe_layer = cfg.moe is not None
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0
    new_lead = {}
    for i in range(n_lead):
        x, c = _layer_decode(cfg, params["lead"][str(i)], x, pos,
                             cache["lead"][str(i)],
                             stacked_rope(cfg, [i])[0],
                             jnp.int32(layer_window(cfg, i)), False)
        new_lead[str(i)] = c
    rest = list(range(n_lead, cfg.num_layers))
    stacked = not isinstance(cache["layers"], dict)
    if stacked:
        inv_freqs = stacked_rope(cfg, rest)
        windows = stacked_windows(cfg, rest)

        def body(x_c, xs):
            p, c, ifr, win = xs
            xo, c2 = _layer_decode(cfg, p, x_c, pos, c, ifr, win, moe_layer)
            return xo, c2

        x, new_stack = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], inv_freqs, windows))
        new_cache = {"lead": new_lead, "layers": new_stack}
    else:
        new_per = {}
        for i in rest:
            p = jax.tree.map(lambda a_: a_[i - n_lead], params["layers"])
            x, c = _layer_decode(cfg, p, x, pos, cache["layers"][str(i)],
                                 stacked_rope(cfg, [i])[0],
                                 jnp.int32(layer_window(cfg, i)), moe_layer)
            new_per[str(i)] = c
        new_cache = {"lead": new_lead, "layers": new_per}
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_cache
