"""Inference workload generation: per-device Poisson streams (rate
lambda_i) aggregated into serving batches — the bridge between the
paper's request model and the TPU decode step."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Tuple, Union

import numpy as np


@dataclass
class RequestEvent:
    t: float
    device: int


def poisson_request_arrays(lam: np.ndarray, duration_s: float,
                           seed: Union[int, np.random.Generator] = 0,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-device Poisson arrival streams as columnar ``(t, device)``
    arrays sorted by arrival time (ties keep device order, matching the
    historical event-list sort).  This is the request plane's native
    format: exponential gaps are drawn in chunks per device, so 10^7
    arrivals cost milliseconds instead of 10^7 scalar generator calls.

    ``seed`` may be an existing ``np.random.Generator`` so callers that
    draw more randomness after the arrivals (e.g. the event engine's
    RTT draws) share one deterministic stream."""
    rng = (seed if isinstance(seed, np.random.Generator)
           else np.random.default_rng(seed))
    ts: List[np.ndarray] = []
    ds: List[np.ndarray] = []
    for i, rate in enumerate(np.asarray(lam, dtype=np.float64)):
        if rate <= 0:
            continue
        expected = rate * duration_s
        chunk = int(expected + 4.0 * math.sqrt(expected) + 16.0)
        t_end, parts = 0.0, []
        while True:
            gaps = rng.exponential(1.0 / rate, size=chunk)
            cum = t_end + np.cumsum(gaps)
            parts.append(cum)
            t_end = float(cum[-1])
            if t_end > duration_s:
                break
            chunk = max(chunk // 4, 16)
        t_i = np.concatenate(parts) if len(parts) > 1 else parts[0]
        t_i = t_i[t_i <= duration_s]
        ts.append(t_i)
        ds.append(np.full(t_i.size, i, dtype=np.int64))
    if not ts:
        return np.zeros(0, dtype=np.float64), np.zeros(0, dtype=np.int64)
    t = np.concatenate(ts)
    d = np.concatenate(ds)
    order = np.argsort(t, kind="stable")
    return t[order], d[order]


def poisson_requests(lam: np.ndarray, duration_s: float,
                     seed: Union[int, np.random.Generator] = 0,
                     ) -> List[RequestEvent]:
    """Per-device Poisson arrival streams as a time-sorted event list —
    the object view of :func:`poisson_request_arrays` (same draws, same
    order for the same seed)."""
    t, d = poisson_request_arrays(lam, duration_s, seed)
    return [RequestEvent(t=float(tt), device=int(dd))
            for tt, dd in zip(t, d)]


def batched_arrivals(events: List[RequestEvent], batch_size: int,
                     max_wait_s: float = 0.05
                     ) -> Iterator[Tuple[float, np.ndarray]]:
    """Continuous batching: emit a batch when it is full or the oldest
    request has waited ``max_wait_s``.

    A batch whose deadline (oldest arrival + ``max_wait_s``) passes is
    flushed *at that deadline*, before the next event joins — a late
    arrival must open a fresh batch, not ride along with (and further
    delay) one that should already have left."""
    cur: List[RequestEvent] = []
    for ev in events:
        if cur and ev.t - cur[0].t >= max_wait_s:
            yield cur[0].t + max_wait_s, np.asarray([e.device for e in cur])
            cur = []
        cur.append(ev)
        if len(cur) >= batch_size:
            yield ev.t, np.asarray([e.device for e in cur])
            cur = []
    if cur:
        yield cur[0].t + max_wait_s, np.asarray([e.device for e in cur])
