#!/usr/bin/env bash
# CI entry point: the repo's tier-1 verification in one command.
#   scripts/ci.sh            # tier-1 test suite + fast co-sim smoke
#   scripts/ci.sh -k serving # pass extra pytest args through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# fast co-sim smoke: exercises the event core, interference model,
# reactive loop and the batched request engine end-to-end on every CI
# run (seconds, CSV to stdout, JSON perf record to BENCH_cosim.json)
python -m benchmarks.run --smoke --json BENCH_cosim.json

# soft events-per-second floor on the batched engine: a regression
# below the floor prints a loud warning (and shows up in the uploaded
# BENCH_cosim.json trajectory) but does not fail CI — shared runners
# are too noisy for a hard perf gate.
python - <<'EOF'
import json

FLOOR_REQ_PER_S = 300_000.0   # batched engine, Fig. 7 smoke config
data = json.load(open("BENCH_cosim.json"))
row = data.get("event_engine_batched", {})
rps = row.get("requests_per_s")
if rps is None:
    print("WARNING: no batched event-engine throughput in "
          "BENCH_cosim.json")
elif rps < FLOOR_REQ_PER_S:
    print(f"WARNING: batched event engine at {rps:,.0f} simulated "
          f"req/s — below the soft floor of {FLOOR_REQ_PER_S:,.0f}")
else:
    print(f"event engine throughput OK: {rps:,.0f} simulated req/s "
          f">= soft floor {FLOOR_REQ_PER_S:,.0f}")
speedup = data.get("event_engine_speedup", {}).get("speedup")
if speedup is not None:
    print(f"batched/heap speedup: {speedup:.1f}x")
EOF
