"""Reactive orchestration loop — closes the monitor -> controller ->
re-deploy cycle the paper describes (§III last paragraph) inside the
co-simulation.

Monitors emit telemetry on the shared event core and drive the
``LearningController`` hooks mid-simulation:

  accuracy monitor   modeled validation MSE (drift onset ramps it up,
                     each retraining round *started after the onset*
                     closes part of the gap) -> ``on_accuracy_alarm``
                     -> retraining burst
  latency monitor    windowed p95 over the request log; sustained
                     violations pick the bottleneck edge and call
                     ``on_capacity_change`` with its training-degraded
                     effective rate -> HFLOP re-clusters -> the co-sim
                     swaps the deployment (with migration cost)
  failure monitor    ``NODE_FAILURE`` events -> ``on_node_failure`` ->
                     re-cluster around the dead edge
  straggler monitor  ``STRAGGLER`` events -> deadline check -> drop the
                     device from rounds it can no longer finish in time
                     (partial aggregation); devices that keep missing
                     deadlines are marked unreliable and HFLOP is
                     re-solved without them (``unreliable_after_drops``)
  mobility monitor   ``DEVICE_MOVE`` events -> update the inventory's
                     LAN association and re-cluster, budget permitting

Every re-deploy stamps the shared recluster cooldown, and every
*optional* one (latency derate, idle restore, mobility) is metered by
the co-sim's :class:`~repro.sim.budget.ReconfigBudget` when one is
attached — an exhausted budget defers the reaction instead of paying
``migration_share`` + ``reconfig_penalty_ms`` again.

The loop keeps an explicit topology-edge -> inventory-index mapping:
the two numberings coincide right after a deployment goes live, but
drift apart when a node failure renumbers the inventory while the
budget holds back the re-deploy — reactions must keep landing on the
right physical host regardless.

All reactions are deterministic functions of the event stream, so a
reactive run is reproducible seed-for-seed like any other.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


from repro.fl.schedule import round_schedule
from repro.sim.events import Event, EventKind, Simulation


@dataclass
class AccuracyModel:
    """Closed-form serving-accuracy telemetry: base MSE until drift
    onset, then a ramp toward ``drift_mse`` over ``ramp_s`` seconds;
    every completed retraining round that *started after the onset*
    multiplies the remaining gap by ``1 - recovery_per_round``
    (continual learning re-fits the model).  Rounds trained entirely on
    pre-drift data cannot recover post-drift MSE, so they earn no
    credit."""
    base_mse: float = 0.03
    drift_mse: float = 0.12
    ramp_s: float = 30.0
    recovery_per_round: float = 0.5
    drift_t: Optional[float] = None
    gap_scale: float = 1.0

    def on_drift(self, t: float, drift_mse: Optional[float] = None) -> None:
        self.drift_t = t
        self.gap_scale = 1.0
        if drift_mse is not None:
            self.drift_mse = float(drift_mse)

    def on_round_complete(self, round_start: Optional[float] = None) -> None:
        if self.drift_t is None:
            return
        if round_start is not None and round_start < self.drift_t:
            return                   # trained on pre-drift data: no credit
        self.gap_scale *= (1.0 - self.recovery_per_round)

    def mse(self, t: float) -> float:
        if self.drift_t is None or t < self.drift_t:
            return self.base_mse
        ramp = min((t - self.drift_t) / max(self.ramp_s, 1e-9), 1.0)
        return self.base_mse + self.gap_scale * ramp * (self.drift_mse
                                                        - self.base_mse)


@dataclass
class ReactivePolicy:
    p95_threshold_ms: float = 40.0   # sustained p95 above this -> recluster
    window_s: float = 10.0           # telemetry window for p95
    min_window_requests: int = 20
    cooldown_s: float = 30.0         # between reclusterings
    capacity_derate: float = 0.6     # edge_agg_share estimate used when
    #                                  reporting effective capacity
    feasibility_slack: float = 1.05  # keep sum(r) >= slack * sum(lam)
    burst_rounds: int = 4            # retraining burst on accuracy alarm
    burst_local_epochs: int = 5
    burst_epoch_s: float = 4.0
    burst_upload_s: float = 1.5
    restore_idle_s: float = 20.0     # training idle this long -> restore
    #                                  nominal capacities (and re-cluster)
    drop_stragglers: bool = True     # deadline-based partial aggregation
    unreliable_after_drops: Optional[int] = None  # total deadline drops
    #                                  before a device is marked unreliable
    #                                  and re-clustered out (None: off)
    recluster_on_move: bool = True   # re-solve HFLOP after a handover
    budget_exempt_failures: bool = True  # failure reclusters are
    #                                  correctness, not optimization: they
    #                                  go through even on a spent budget


class ReactiveLoop:
    """Binds a ``LearningController`` to a running :class:`CoSim`."""

    def __init__(self, controller, accuracy: Optional[AccuracyModel] = None,
                 policy: Optional[ReactivePolicy] = None):
        self.controller = controller
        self.acc = accuracy if accuracy is not None else AccuracyModel()
        self.policy = policy if policy is not None else ReactivePolicy()
        self.mse_series: List[Tuple[float, float]] = []
        self.actions: List[Tuple[float, str]] = []
        self.burst_until = -math.inf
        self.last_recluster_t = -math.inf
        # nominal (pre-derate) capacity per INVENTORY index: derates are
        # computed from here so repeated alarms don't compound, and
        # capacities are restored once training goes idle
        self._nominal_caps: Dict[int, float] = {}
        # device -> cumulative deadline drops (straggler monitor)
        self._drop_counts: Dict[int, int] = {}
        # topology edge id -> inventory index.  Identity right after a
        # deployment goes live; diverges when a failure renumbers the
        # inventory while the budget defers the re-deploy.
        self._edge_to_inv: Dict[int, int] = {}
        self.cosim = None
        self.tel = None

    def bind(self, cosim) -> None:
        self.cosim = cosim
        # already resolved by the co-sim: None unless enabled.  The
        # audit log is additive observation — `actions` strings and the
        # budget ledger are byte-identical with telemetry on or off.
        self.tel = cosim.tel
        self._edge_to_inv = {j: j for j in
                             range(len(self.controller.inventory.edges))}
        sim: Simulation = cosim.sim
        sim.on(EventKind.TELEMETRY, self.on_telemetry)
        sim.on(EventKind.DRIFT_ONSET, self.on_drift)
        sim.on(EventKind.NODE_FAILURE, self.on_node_failure)
        sim.on(EventKind.CAPACITY_CHANGE, self.on_capacity_change)
        sim.on(EventKind.ROUND_END, self.on_round_end)
        sim.on(EventKind.STRAGGLER, self.on_straggler)
        sim.on(EventKind.DEVICE_MOVE, self.on_device_move)
        tick = cosim.cfg.telemetry_s
        n_ticks = int(cosim.cfg.duration_s / tick)
        for k in range(1, n_ticks + 1):
            sim.schedule(k * tick, EventKind.TELEMETRY)

    # -- topology-edge -> inventory mapping ---------------------------------

    def _inv_index(self, topo_edge: int) -> Optional[int]:
        idx = self._edge_to_inv.get(int(topo_edge))
        if idx is None or idx >= len(self.controller.inventory.edges):
            return None
        return idx

    def _mapping_is_identity(self) -> bool:
        # identity must cover the live topology's whole id space: after
        # a deferred failure drops the highest-numbered edge, the
        # surviving {0:0,...} entries alone are NOT identity — the
        # renumbering still has to be composed into alias/demand keys
        n = self.cosim.proc.topo.n_edges
        return (len(self._edge_to_inv) == n
                and all(self._edge_to_inv.get(j) == j for j in range(n)))

    def _budget_allows(self, t: float, reason: str) -> bool:
        """Pre-flight check for *optional* reclusterings: when the
        budget can't cover another migration, record the veto and defer
        (the alarm will re-fire after the cooldown if it persists).
        The check uses the inventory size as an upper bound on the
        re-solved deployment's open edges, so once it passes the actual
        charge in ``apply_deployment`` cannot fail — the controller is
        never mutated for a swap that then gets vetoed."""
        budget = self.cosim.budget
        if budget is None:
            return True
        cost = self.cosim.reconfig_cost(
            n_edges=len(self.controller.inventory.edges))
        if budget.can_afford(cost):
            return True
        budget.charge(t, cost, reason, forced=False)   # records the veto
        if self.tel is not None:
            self.tel.audit.record(
                t, "deployment_swap", trigger=reason, outcome="deferred",
                cost=cost, charged=False,
                evidence={"budget_remaining": budget.remaining,
                          "budget_total": budget.total})
        self.actions.append(
            (t, f"{reason} deferred: reconfig budget exhausted "
             f"({budget.summary()})"))
        return False

    def _apply(self, dep, t: float, reason: str,
               forced: bool = False) -> bool:
        """Swap a controller-produced deployment into the co-sim.  The
        new topology uses the (possibly renumbered) inventory ids, so
        external edge demand keyed by old topology ids is re-keyed
        first, and on success the mapping collapses back to identity.
        Stamps the shared recluster cooldown — every re-deploy pays the
        same migration window, whichever monitor asked for it."""
        old_map = dict(self._edge_to_inv)
        if not self._mapping_is_identity():
            self.cosim.interference.remap_tier("edge", old_map.get)
            self.cosim.remap_edge_alias(old_map.get)
        applied = self.cosim.apply_deployment(dep, reason=reason,
                                              forced=forced)
        if applied:
            self._edge_to_inv = {j: j for j in
                                 range(len(self.controller.inventory.edges))}
            self.last_recluster_t = t
        return applied

    # -- environment events -> controller hooks -----------------------------

    def on_drift(self, sim: Simulation, ev: Event) -> None:
        self.acc.on_drift(ev.t, drift_mse=ev.payload)
        self.actions.append((ev.t, "drift onset"))
        if self.tel is not None:
            self.tel.audit.record(
                ev.t, "drift_alarm", trigger="drift_onset",
                outcome="noted",
                evidence={"drift_mse": self.acc.drift_mse,
                          "base_mse": self.acc.base_mse})

    def on_round_end(self, sim: Simulation, ev: Event) -> None:
        sid, w = ev.payload
        # credit only rounds that trained on post-drift data AND (under
        # an armed chaos plan with a quorum) aggregated enough devices
        # — a below-quorum partial aggregate earns no recovery
        if not self.cosim.last_round_quorum_ok:
            return
        self.acc.on_round_complete(round_start=w.start)

    def on_node_failure(self, sim: Simulation, ev: Event) -> None:
        # events name edges by injection-time id: resolve to the
        # current topology numbering first
        failed = self.cosim.resolve_edge(ev.node)
        inv_idx = self._inv_index(failed) if failed is not None else None
        if inv_idx is None:
            self.actions.append((ev.t, f"edge {ev.node} failed but is "
                                 "not in the inventory — ignored"))
            return

        def shift(y: int) -> Optional[int]:
            # inventory indices after removing inv_idx
            return None if y == inv_idx else (y - 1 if y > inv_idx else y)

        budget = self.cosim.budget
        exempt = self.policy.budget_exempt_failures
        # a failure landing inside an in-flight deployment swap folds
        # into that swap: the open migration window already paid, so
        # the budget is not charged again (and the re-solve below runs
        # against the controller's current — post-swap — inventory, so
        # it can never recluster the pre-swap topology)
        in_window = ev.t < self.cosim.reconfig_until
        # bound: the re-solved deployment opens at most the surviving
        # inventory edges
        fail_cost = self.cosim.reconfig_cost(
            n_edges=len(self.controller.inventory.edges) - 1)
        if (not exempt and not in_window and budget is not None
                and not budget.can_afford(fail_cost)):
            # the edge is gone either way: record the truth in the
            # inventory, but defer the re-deploy — the stale topology
            # keeps serving (the dead edge's requests spill to the
            # cloud) and the edge mapping tracks the renumbering
            budget.charge(ev.t, fail_cost,
                          f"failure recluster (edge {failed})",
                          forced=False)
            if self.tel is not None:
                self.tel.audit.record(
                    ev.t, "deployment_swap",
                    trigger=f"failure recluster (edge {failed})",
                    outcome="deferred", cost=fail_cost, charged=False,
                    evidence={"failed_edge": failed,
                              "budget_remaining": budget.remaining})
            self.controller.on_node_failure(inv_idx, redeploy=False)
            self._edge_to_inv = {
                tj: s for tj, y in self._edge_to_inv.items()
                if (s := shift(y)) is not None}
            self._nominal_caps = {
                s: cap for j, cap in self._nominal_caps.items()
                if (s := shift(j)) is not None}
            self.actions.append(
                (ev.t, f"edge {failed} failed; recluster deferred "
                 f"(reconfig budget exhausted, {budget.summary()})"))
            return

        old_map = dict(self._edge_to_inv)
        dep = self.controller.on_node_failure(inv_idx)
        self._nominal_caps = {
            s: cap for j, cap in self._nominal_caps.items()
            if (s := shift(j)) is not None}

        def to_new(x: int) -> Optional[int]:
            # old topology id -> old inventory idx -> post-removal idx,
            # which is the new topology numbering
            return shift(old_map[x]) if x in old_map else None

        # external (tenant/handover) edge demand and the scheduled-event
        # alias both follow their physical hosts into the new numbering
        self.cosim.interference.remap_tier("edge", to_new)
        self.cosim.remap_edge_alias(to_new)
        self._edge_to_inv = {j: j for j in
                             range(len(self.controller.inventory.edges))}
        if self.cosim.apply_deployment(
                dep, reason=f"failure recluster (edge {failed})",
                forced=exempt, absorb=in_window):
            self.last_recluster_t = ev.t         # cooldown covers the
            #                                      open migration window
        self.actions.append((ev.t, f"edge {failed} failed -> reclustered "
                             f"to {len(dep.topology.open_edges)} edges"
                             + (" (folded into in-flight migration)"
                                if in_window else "")))

    def on_capacity_change(self, sim: Simulation, ev: Event) -> None:
        topo_j = self.cosim.resolve_edge(ev.node)
        inv_idx = self._inv_index(topo_j) if topo_j is not None else None
        if inv_idx is None:
            self.actions.append(
                (ev.t, f"edge {ev.node} capacity change outside the "
                 "inventory — admission updated only"))
            return
        # a real hardware capacity change supersedes any derated nominal
        # we recorded — _restore_capacity must not revert it later
        self._nominal_caps.pop(inv_idx, None)
        if not self._budget_allows(
                ev.t, f"capacity recluster (edge {topo_j})"):
            # record the new truth without re-deploying
            self.controller.inventory.edges[inv_idx].capacity_rps = \
                float(ev.payload)
            return
        dep = self.controller.on_capacity_change(inv_idx,
                                                 float(ev.payload))
        if self._apply(dep, ev.t,
                       reason=f"capacity recluster (edge {topo_j})"):
            self.actions.append(
                (ev.t, f"edge {topo_j} capacity -> "
                 f"{float(ev.payload):.2f} rps, reclustered"))

    def on_straggler(self, sim: Simulation, ev: Event) -> None:
        """The co-sim has already re-timed the device's remaining
        epochs; decide whether it can still make each round's upload
        deadline, and drop it from rounds it cannot (partial
        aggregation — the paper's deadline-based fallback)."""
        i, factor = int(ev.node), float(ev.payload)
        info = self.cosim.straggler_info(i)
        self.actions.append(
            (ev.t, f"device {i} straggling x{factor:.1f} "
             f"({len(info)} active round(s) affected)"))
        if not self.policy.drop_stragglers:
            return
        rounds_dropped = 0
        for sid, w, projected_end in info:
            if projected_end > w.upload_end + 1e-9:
                dropped = self.cosim.drop_from_round(i, sid, w.index)
                if dropped:
                    rounds_dropped += 1
                    self.actions.append(
                        (ev.t, f"device {i} projected to finish round "
                         f"{w.index} at t={projected_end:.1f}s > deadline "
                         f"{w.upload_end:.1f}s -> dropped ({dropped} "
                         "epochs cancelled, partial aggregation)"))
                    if self.tel is not None:
                        self.tel.audit.record(
                            ev.t, "straggler_drop",
                            trigger="deadline_miss", outcome="applied",
                            evidence={"device": i, "round": w.index,
                                      "epochs_dropped": dropped,
                                      "projected_end_s": projected_end,
                                      "deadline_s": w.upload_end})
        if rounds_dropped:
            self._note_drops(ev.t, i, rounds_dropped)

    def _note_drops(self, t: float, i: int, rounds_dropped: int) -> None:
        """Straggler re-clustering: a device that keeps missing upload
        deadlines is marked ``reliable=False`` in the inventory and
        HFLOP is re-solved without it (it keeps serving inference, but
        stops gating rounds).  The re-deploy is metered like any other
        optional recluster — on a spent budget or inside the cooldown
        only the mark is recorded, and the next recluster from any
        monitor picks it up."""
        thresh = self.policy.unreliable_after_drops
        if thresh is None:
            return
        self._drop_counts[i] = self._drop_counts.get(i, 0) + rounds_dropped
        devices = self.controller.inventory.devices
        if (self._drop_counts[i] < thresh or i >= len(devices)
                or not devices[i].reliable):
            return
        reason = f"unreliable recluster (device {i})"
        if self.tel is not None:
            self.tel.audit.record(
                t, "unreliable_mark", trigger="deadline_drops",
                outcome="noted",
                evidence={"device": i, "drops": self._drop_counts[i],
                          "threshold": thresh})
        if (t - self.last_recluster_t < self.policy.cooldown_s
                or not self._budget_allows(t, reason)):
            self.controller.on_unreliable_devices([i], redeploy=False)
            self.actions.append(
                (t, f"device {i} marked unreliable after "
                 f"{self._drop_counts[i]} deadline drops; recluster "
                 "deferred"))
            return
        dep = self.controller.on_unreliable_devices([i])
        if dep is not None and self._apply(dep, t, reason=reason):
            self.actions.append(
                (t, f"device {i} marked unreliable after "
                 f"{self._drop_counts[i]} deadline drops -> re-clustered "
                 "without it"))

    def on_device_move(self, sim: Simulation, ev: Event) -> None:
        """The co-sim has already re-homed the device's requests and
        started the handover window; mirror the move into the
        inventory's LAN association and re-solve HFLOP around the new
        cost structure — budget and cooldown permitting."""
        i = int(ev.node)
        new_topo_edge = self.cosim.resolve_edge(ev.payload)
        if new_topo_edge is None:
            return                   # target host gone: co-sim dropped it
        inv_idx = self._inv_index(new_topo_edge)
        self.actions.append(
            (ev.t, f"device {i} handed over to edge {ev.payload}"))
        recluster = (self.policy.recluster_on_move
                     and ev.t - self.last_recluster_t
                     >= self.policy.cooldown_s)
        if recluster and not self._budget_allows(
                ev.t, f"mobility recluster (device {i})"):
            self.last_recluster_t = ev.t         # defer past the cooldown
            recluster = False
        dep = self.controller.on_device_move(i, inv_idx,
                                             redeploy=recluster)
        if dep is not None and self._apply(
                dep, ev.t, reason=f"mobility recluster (device {i})"):
            self.actions.append(
                (ev.t, f"re-clustered around device {i}'s new LAN edge"))

    # -- telemetry tick ------------------------------------------------------

    def on_telemetry(self, sim: Simulation, ev: Event) -> None:
        t = ev.t
        mse = self.acc.mse(t)
        self.mse_series.append((t, mse))
        if (self.controller.on_accuracy_alarm(mse)
                and t >= self.burst_until):
            self._trigger_retraining(t, mse)
        p95 = self._window_p95(t)
        if (p95 is not None and p95 > self.policy.p95_threshold_ms
                and t - self.last_recluster_t >= self.policy.cooldown_s):
            self._recluster_for_latency(t, p95)
        elif (self._nominal_caps and not self.cosim.training_active
                and t - self.cosim.last_round_end
                >= self.policy.restore_idle_s
                and t - self.last_recluster_t >= self.policy.cooldown_s):
            self._restore_capacity(t)

    def _trigger_retraining(self, t: float, mse: float) -> None:
        p = self.policy
        burst = round_schedule(p.burst_rounds, l=self.controller.l,
                               local_epochs=p.burst_local_epochs,
                               epoch_s=p.burst_epoch_s,
                               upload_s=p.burst_upload_s, start_s=t)
        self.cosim.add_training(burst)
        self.burst_until = burst[-1].end
        self.actions.append((t, f"accuracy alarm (mse={mse:.3f}) -> "
                             f"retraining burst of {p.burst_rounds} rounds"))
        if self.tel is not None:
            self.tel.audit.record(
                t, "retraining_burst", trigger="drift_alarm",
                outcome="applied",
                evidence={"mse": mse, "rounds": p.burst_rounds,
                          "local_epochs": p.burst_local_epochs,
                          "burst_until_s": self.burst_until})
            self.tel.metrics.counter("alarms.accuracy").inc()

    def _window_p95(self, t: float) -> Optional[float]:
        # incremental over the columnar log: each tick binary-searches
        # the window start from a monotone cursor (O(log n + window)),
        # so telemetry cost no longer grows with total request history
        return self.cosim.proc.recent_percentile(
            t, self.policy.window_s, 95,
            min_requests=self.policy.min_window_requests)

    def _recluster_for_latency(self, t: float, p95: float) -> None:
        """Pick the busiest edge in the window and report its effective
        (training-degraded) capacity to the controller, which re-solves
        HFLOP — load moves off the bottleneck."""
        if self.tel is not None:
            self.tel.audit.record(
                t, "latency_alarm", trigger="windowed_p95_breach",
                outcome="noted",
                evidence={"p95_ms": p95,
                          "threshold_ms": self.policy.p95_threshold_ms,
                          "window_s": self.policy.window_s})
            self.tel.metrics.counter("alarms.latency").inc()
        proc = self.cosim.proc
        edges = proc.edges
        if not edges:
            return
        # bottleneck = edge with the highest assigned request load,
        # in the *topology* numbering — translate before touching the
        # inventory (after a deferred failure re-deploy they differ)
        loads = self.cosim.proc.topo.cluster_loads()
        if not loads:
            return
        bottleneck = max(loads, key=loads.get)
        inv_idx = self._inv_index(bottleneck)
        if inv_idx is None:
            self.actions.append(
                (t, f"latency alarm (p95={p95:.1f}ms) but bottleneck "
                 f"edge {bottleneck} is not in the inventory — skipped"))
            self.last_recluster_t = t            # don't re-log every tick
            return
        inv_edges = self.controller.inventory.edges
        cur = inv_edges[inv_idx].capacity_rps
        # derate from the NOMINAL capacity, not the current value —
        # repeated alarms must not compound toward zero
        nominal = self._nominal_caps.get(inv_idx, cur)
        eff = nominal * (1.0 - self.policy.capacity_derate)
        # never report a capacity that makes the instance infeasible
        lam_total = sum(d.lam for d in self.controller.inventory.devices)
        others = sum(e.capacity_rps for e in inv_edges) - cur
        eff = max(eff, self.policy.feasibility_slack * lam_total - others)
        if eff >= cur * 0.999:
            return                   # no meaningful reduction possible
        if not self._budget_allows(t, "latency recluster"):
            self.last_recluster_t = t            # defer past the cooldown
            return
        self._nominal_caps.setdefault(inv_idx, nominal)
        dep = self.controller.on_capacity_change(inv_idx, float(eff))
        if self._apply(dep, t, reason="latency recluster"):
            self.actions.append(
                (t, f"latency alarm (p95={p95:.1f}ms) -> edge "
                 f"{bottleneck} effective capacity {eff:.2f} rps, "
                 "reclustered"))

    def _restore_capacity(self, t: float) -> None:
        """Training has been idle long enough: the interference the
        derated capacities modeled is gone, so hand the controller its
        nominal rates back and re-cluster once."""
        inv_edges = self.controller.inventory.edges
        items = [(j, cap) for j, cap in sorted(self._nominal_caps.items())
                 if j < len(inv_edges)]
        if not items:
            self._nominal_caps.clear()
            return
        if not self._budget_allows(t, "restore recluster"):
            self.last_recluster_t = t            # defer past the cooldown
            return
        for j, cap in items[:-1]:
            inv_edges[j].capacity_rps = cap
        last_j, last_cap = items[-1]
        dep = self.controller.on_capacity_change(last_j, float(last_cap))
        if self._apply(dep, t, reason="restore recluster"):
            # clear the bookkeeping only once the swap went live — a
            # (defensive) veto keeps the derate on record for a retry
            self._nominal_caps.clear()
            self.actions.append(
                (t, "training idle -> nominal edge capacities restored, "
                 "reclustered"))
