"""EVT001: every EventKind carries a window-fusion classification.

``repro.sim.events.EVENT_EFFECTS`` tells the fused request-plane replay
which control events can invalidate an open occupancy window.  A kind
*missing* from the dict silently defaults to "mutates routing" at
dispatch — safe but forfeiting fusion — and, worse, a kind someone adds
for a new scenario without thinking about its request-plane contract is
exactly the case that corrupts fused replays.  This rule fails the
build until the author classifies the new kind explicitly.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, dotted_name

EVENTS_MODULE = "repro.sim.events"


def _enum_members(cls: ast.ClassDef) -> List[Tuple[str, int]]:
    out: List[Tuple[str, int]] = []
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                if isinstance(target, ast.Name) and not \
                        target.id.startswith("_"):
                    out.append((target.id, stmt.lineno))
        elif isinstance(stmt, ast.AnnAssign) and \
                isinstance(stmt.target, ast.Name) and stmt.value is not None:
            if not stmt.target.id.startswith("_"):
                out.append((stmt.target.id, stmt.lineno))
    return out


class EventEffectsRule(Rule):
    """EVT001: EVENT_EFFECTS must cover EventKind exactly."""

    id = "EVT001"
    name = "event-effects-complete"
    description = ("every EventKind member needs an EVENT_EFFECTS "
                   "classification (and no stale keys), so window "
                   "fusion never guesses a new event's request-plane "
                   "contract")

    def check_project(self, project: Project) -> List[Finding]:
        path = project.module_path(EVENTS_MODULE)
        if path is None:
            return []           # fixture trees without a sim package
        ctx = project.context(path)
        kind_cls: Optional[ast.ClassDef] = None
        effects: Optional[ast.Dict] = None
        effects_line = 1
        for stmt in ctx.tree.body:
            if isinstance(stmt, ast.ClassDef) and stmt.name == "EventKind":
                kind_cls = stmt
            target = None
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                target = stmt.targets[0]
            elif isinstance(stmt, ast.AnnAssign):
                target = stmt.target
            if (isinstance(target, ast.Name)
                    and target.id == "EVENT_EFFECTS"
                    and isinstance(stmt.value, ast.Dict)):
                effects = stmt.value
                effects_line = stmt.lineno
        findings: List[Finding] = []
        if kind_cls is None:
            return [Finding(path=ctx.rel_path, line=1, rule=self.id,
                            message="EventKind class not found in "
                                    f"{EVENTS_MODULE}")]
        if effects is None:
            return [Finding(path=ctx.rel_path, line=1, rule=self.id,
                            message="EVENT_EFFECTS dict literal not "
                                    f"found in {EVENTS_MODULE}")]
        members = _enum_members(kind_cls)
        member_names = {name for name, _ in members}
        covered: Set[str] = set()
        for key in effects.keys:
            name = dotted_name(key) if key is not None else None
            if name is None or not name.startswith("EventKind."):
                findings.append(Finding(
                    path=ctx.rel_path, line=key.lineno if key else
                    effects_line, rule=self.id,
                    message="EVENT_EFFECTS key is not an EventKind "
                            "attribute"))
                continue
            member = name.split(".", 1)[1]
            if member not in member_names:
                findings.append(Finding(
                    path=ctx.rel_path,
                    line=key.lineno, rule=self.id,
                    message=f"EVENT_EFFECTS has stale key EventKind."
                            f"{member} (no such member)"))
            covered.add(member)
        for name, line in members:
            if name not in covered:
                findings.append(Finding(
                    path=ctx.rel_path, line=line, rule=self.id,
                    message=f"EventKind.{name} has no EVENT_EFFECTS "
                            f"classification; add it (and decide "
                            f"whether it mutates routing inputs)"))
        return findings
