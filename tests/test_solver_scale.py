"""The decomposed million-device solver stack: bit-compat of the
vectorized greedy / LP-rounding rewrites against the pre-rewrite
scalar loops (kept here verbatim as references), partition invariants,
LAN-instance parity, decomposed-solver feasibility + determinism at
scale, and the optimality gap vs exact B&B on paper-instance
subsamples."""
import numpy as np
import pytest

from repro.core import (HFLOPInstance, LanHFLOPInstance, is_feasible,
                        objective, paper_cost_instance, paper_cost_lan,
                        partition_instance, random_instance, solve_bnb,
                        solve_decomposed, solve_greedy, sub_instance)
from repro.core import solvers
from repro.core.hflop import HFLOPSolution


# ---------------------------------------------------------------------------
# pre-rewrite reference implementations, verbatim — the vectorized
# solvers must reproduce these decision-for-decision (bit-compat)
# ---------------------------------------------------------------------------

def _local_costs(inst, assign):
    ok = assign >= 0
    local = np.zeros(inst.n)
    local[ok] = inst.c_d[np.arange(inst.n)[ok], assign[ok]] * inst.l
    return local


def ref_greedy(inst):
    n, m = inst.n, inst.m
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    opened = np.zeros(m, bool)
    order = np.argsort(-inst.lam)
    for i in order:
        costs = inst.c_d[i] * inst.l + np.where(opened, 0.0, inst.c_e)
        feas = load + inst.lam[i] <= inst.r + 1e-12
        costs = np.where(feas, costs, np.inf)
        j = int(np.argmin(costs))
        if np.isfinite(costs[j]):
            assign[i] = j
            load[j] += inst.lam[i]
            opened[j] = True
    for j in np.argsort(np.bincount(assign[assign >= 0] + 0,
                                    minlength=m))[:m]:
        if not opened[j]:
            continue
        members = np.nonzero(assign == j)[0]
        if members.size == 0:
            opened[j] = False
            continue
        delta = 0.0
        moves = {}
        load2 = load.copy()
        ok = True
        for i in members[np.argsort(-inst.lam[members])]:
            costs = inst.c_d[i] * inst.l
            feas = (load2 + inst.lam[i] <= inst.r + 1e-12) & opened
            feas[j] = False
            costs = np.where(feas, costs, np.inf)
            k = int(np.argmin(costs))
            if not np.isfinite(costs[k]):
                ok = False
                break
            moves[i] = k
            load2[k] += inst.lam[i]
            delta += (inst.c_d[i, k] - inst.c_d[i, j]) * inst.l
        if ok and delta < inst.c_e[j] - 1e-12:
            for i, k in moves.items():
                assign[i] = k
            load = load2
            load[j] = 0.0
            opened[j] = False
    surplus = int(np.sum(assign >= 0)) - inst.T
    if surplus > 0:
        local = _local_costs(inst, assign)
        for i in np.argsort(-local):
            if surplus <= 0 or assign[i] < 0:
                break
            if local[i] <= 0:
                break
            load[assign[i]] -= inst.lam[i]
            assign[i] = -1
            surplus -= 1
    return assign


def ref_round_lp(inst, xfrac):
    n, m = inst.n, inst.m
    xm = xfrac[:n * m].reshape(n, m)
    assign = np.full(n, -1, int)
    load = np.zeros(m)
    order = np.argsort(-np.max(xm, axis=1))
    for i in order:
        for j in np.argsort(-xm[i]):
            if xm[i, j] < 1e-9:
                break
            if load[j] + inst.lam[i] <= inst.r[j] + 1e-12:
                assign[i] = j
                load[j] += inst.lam[i]
                break
    if int(np.sum(assign >= 0)) < inst.T:
        return None
    v = np.zeros(n * m + m)
    for i in range(n):
        if assign[i] >= 0:
            v[i * m + assign[i]] = 1.0
    for j in np.unique(assign[assign >= 0]):
        v[n * m + j] = 1.0
    return v


def _cases(seeds):
    for s in seeds:
        yield random_instance(25, 5, seed=s)
        yield random_instance(40, 7, seed=s, T=30)
        yield random_instance(12, 4, seed=s, capacity_slack=1.02, T=9)
        yield paper_cost_instance(30, 5, seed=s)
        yield paper_cost_instance(60, 8, seed=s, capacity_slack=1.1)


def test_greedy_bit_compat_with_scalar_reference():
    for k, inst in enumerate(_cases(range(12))):
        want = ref_greedy(inst)
        got = solve_greedy(inst)
        assert np.array_equal(want, got.assign), f"case {k}"


def test_round_lp_bit_compat_with_scalar_reference():
    rng = np.random.default_rng(0)
    for k in range(30):
        inst = random_instance(18, 5, seed=k, T=14 if k % 2 else None)
        xf = rng.uniform(0, 1, inst.n * inst.m + inst.m)
        xf[rng.uniform(0, 1, xf.shape[0]) < 0.3] = 0.0  # hit the 1e-9 break
        want = ref_round_lp(inst, xf)
        got = solvers._round_lp(inst, xf)
        if want is None:
            assert got is None, f"case {k}"
        else:
            assert got is not None and np.array_equal(want, got), f"case {k}"


def test_local_search_only_improves_on_greedy():
    for inst in _cases(range(4)):
        g = solve_greedy(inst)
        if not np.isfinite(g.cost):
            continue
        ls = solvers.local_search(inst, g)
        assert ls.cost <= g.cost + 1e-9
        assert is_feasible(inst, ls.assign)


# ---------------------------------------------------------------------------
# LAN (implicit paper-cost) instances
# ---------------------------------------------------------------------------

def test_lan_instance_matches_dense_paper_instance():
    for seed in range(4):
        lan = paper_cost_lan(300, 12, seed=seed, capacity_slack=1.2)
        dense = paper_cost_instance(300, 12, seed=seed,
                                    capacity_slack=1.2)
        d2 = lan.to_dense()
        assert np.array_equal(d2.c_d, dense.c_d)
        assert np.array_equal(d2.c_e, dense.c_e)
        assert np.array_equal(d2.lam, dense.lam)
        assert np.array_equal(d2.r, dense.r)
        assert d2.T == dense.T


def test_greedy_identical_on_lan_and_dense_form():
    for seed in range(4):
        lan = paper_cost_lan(400, 10, seed=seed)
        a = solve_greedy(lan).assign
        b = solve_greedy(lan.to_dense()).assign
        assert np.array_equal(a, b)


def test_sub_instance_preserves_costs_and_loads():
    lan = paper_cost_lan(5000, 40, seed=1)
    rng = np.random.default_rng(2)
    dev = np.sort(rng.choice(lan.n, 200, replace=False))
    edg = np.unique(np.concatenate([np.unique(lan.free[dev]),
                                    rng.choice(lan.m, 5, replace=False)]))
    sub = sub_instance(lan, dev, edg)
    assert sub.n == dev.size and sub.m == edg.size
    dense = sub.to_dense() if hasattr(sub, "to_dense") else sub
    full = lan.to_dense()
    assert np.array_equal(dense.c_d, full.c_d[np.ix_(dev, edg)])
    assert np.array_equal(dense.lam, full.lam[dev])
    assert np.array_equal(dense.r, full.r[edg])


def test_partition_covers_all_edges_and_devices():
    for inst in (paper_cost_lan(20_000, 64, seed=0),
                 random_instance(600, 24, seed=0)):
        part = partition_instance(inst)
        assert part.region_of_edge.shape == (inst.m,)
        assert part.region_of_device.shape == (inst.n,)
        assert np.all(part.region_of_edge >= 0)
        assert np.all(part.region_of_device >= 0)
        assert np.all(part.region_of_edge < part.n_regions)
        # every device's region is its cheapest edge's region
        covered = np.zeros(inst.m, bool)
        for g in range(part.n_regions):
            covered[part.edges_in(g)] = True
        assert covered.all()


# ---------------------------------------------------------------------------
# decomposed solver: feasibility, determinism, scale, exact gap
# ---------------------------------------------------------------------------

def test_decomposed_feasible_and_deterministic_at_scale():
    inst = paper_cost_lan(100_000, 200, seed=0)
    sol = solve_decomposed(inst)
    assert sol.solver == "decomposed"
    assert inst.is_feasible(sol.assign)
    assert int(np.sum(sol.assign >= 0)) == inst.T
    assert {"partition_s", "subsolve_s", "stitch_s",
            "polish_s"} <= set(sol.meta["phase_s"])
    again = solve_decomposed(inst)
    assert np.array_equal(sol.assign, again.assign)
    assert sol.cost == again.cost


def test_decomposed_matches_quality_on_dense_instances():
    """On small dense instances the decomposed pipeline must be at
    least as good as plain greedy and feasible."""
    for seed in range(6):
        inst = paper_cost_instance(80, 8, seed=seed, capacity_slack=1.2)
        dec = solve_decomposed(inst)
        grd = solve_greedy(inst)
        assert is_feasible(inst, dec.assign)
        if np.isfinite(grd.cost):
            assert dec.cost <= grd.cost + 1e-9


def test_decomposed_gap_vs_exact_on_subsamples():
    """The acceptance bound: <=5% optimality gap vs the exact B&B on
    <=80-device subsamples of a continuum-scale paper instance."""
    big = paper_cost_lan(50_000, 100, seed=0)
    for s in range(2):
        rng = np.random.default_rng(1000 + s)
        dev = np.sort(rng.choice(big.n, size=60, replace=False))
        edg = np.unique(np.concatenate([
            np.unique(big.free[dev]),
            rng.choice(big.m, size=4, replace=False)]))
        sub = sub_instance(big, dev, edg)
        dense = sub.to_dense() if hasattr(sub, "to_dense") else sub
        exact = solve_bnb(dense)
        dec = solve_decomposed(sub)
        assert is_feasible(dense, dec.assign)
        gap = (dec.cost - exact.cost) / max(exact.cost, 1e-9)
        assert gap <= 0.05, f"sub_seed {s}: gap {gap:.4f}"


def test_decomposed_respects_explicit_region_count():
    inst = paper_cost_lan(20_000, 64, seed=3)
    sol = solve_decomposed(inst, regions=4)
    assert inst.is_feasible(sol.assign)
    assert sol.meta["regions"] == 4


# ---------------------------------------------------------------------------
# property-based feasibility (skipped when hypothesis is unavailable)
# ---------------------------------------------------------------------------

def test_decomposed_feasibility_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(0, 10_000),
               m=st.integers(8, 64),
               slack=st.floats(1.05, 2.0))
    @hyp.settings(max_examples=10, deadline=None)
    def prop(seed, m, slack):
        inst = paper_cost_lan(10_000, m, seed=seed, capacity_slack=slack)
        sol = solve_decomposed(inst)
        assert inst.is_feasible(sol.assign)

    prop()
