"""Orchestration under environment dynamics (paper §III + §VI):
edge-node failure and capacity changes trigger re-clustering; the
deployment adapts while staying feasible.

  PYTHONPATH=src python examples/orchestrate_dynamic.py
"""

from repro.core import is_feasible
from repro.orchestration import LearningController, random_inventory


def show(dep, label):
    t = dep.topology
    print(f"--- {label} ---")
    print(t.describe())
    print(f"    services: {len(dep.inference_services)} "
          f"(aggregators on edges {dep.aggregator_nodes})")


def main():
    inv = random_inventory(n=30, m=6, seed=1, capacity_slack=1.6)
    ctl = LearningController(inventory=inv, l=2)
    dep = show(ctl.deploy(), "initial deployment") or ctl.deployment

    # an edge host fails -> learning controller re-clusters
    failed = dep.aggregator_nodes[0]
    print(f"\n!! edge {failed} failed")
    dep = ctl.on_node_failure(failed)
    show(dep, "after failure re-clustering")
    inst = ctl.inventory.to_instance(l=2)
    assert is_feasible(inst, dep.topology.assign)

    # a co-located workload halves one edge's serving capacity
    victim = dep.aggregator_nodes[0]
    new_cap = ctl.inventory.edges[victim].capacity_rps * 0.5
    print(f"\n!! edge {victim} capacity drops to {new_cap:.1f} req/s")
    dep = ctl.on_capacity_change(victim, new_cap)
    show(dep, "after capacity re-clustering")
    inst = ctl.inventory.to_instance(l=2)
    assert is_feasible(inst, dep.topology.assign)
    print(f"\nreclusterings performed: {ctl.recluster_count}")


if __name__ == "__main__":
    main()
