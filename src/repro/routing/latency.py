"""Latency model for inference serving (paper §V-C1).

The paper measured HTTP round-trip times: cloud 50-100 ms, edge 8-10 ms.
Processing time is the model's inference time, scaled per serving tier:
Fig. 8 sweeps a "theoretical speedup of up to 95%" of cloud vs edge
compute, i.e. cloud_infer = edge_infer * (1 - speedup).

Two service-time models share this interface:

  - :class:`LatencyModel` — the paper's constant closed-form per-tier
    inference time (the fast default; reproduces Fig. 7/8 exactly);
  - :class:`CalibratedLatencyModel` — per-tier service times *measured*
    from the real serving engines (``ReplicaPool.measure()``), with
    occupancy-dependent slowdown once a replica's continuous-batching
    slots are oversubscribed.  Built via
    ``LatencyModel.from_measurements(...)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping

import numpy as np


@dataclass(frozen=True)
class LatencyModel:
    edge_rtt_ms: tuple = (8.0, 10.0)       # uniform, paper §V-C1
    cloud_rtt_ms: tuple = (50.0, 100.0)    # uniform, paper §V-C1
    device_rtt_ms: tuple = (0.0, 0.0)      # on-device serving: no network
    base_infer_ms: float = 2.0             # GRU forward on an edge host
    cloud_speedup: float = 0.0             # Fig. 8: 0..0.95
    device_slowdown: float = 2.0           # devices slower than edge hosts

    def rtt(self, tier: str, rng: np.random.Generator,
            size=None) -> np.ndarray:
        lo, hi = {"device": self.device_rtt_ms,
                  "edge": self.edge_rtt_ms,
                  "cloud": self.cloud_rtt_ms}[tier]
        return rng.uniform(lo, hi, size)

    def infer_ms(self, tier: str, occupancy: float = 0.0) -> float:
        """Service time of one request on ``tier``.  ``occupancy`` is the
        number of requests already in service on the chosen replica; the
        constant model ignores it (closed-form paper behaviour)."""
        if tier == "cloud":
            return self.base_infer_ms * (1.0 - self.cloud_speedup)
        if tier == "device":
            return self.base_infer_ms * self.device_slowdown
        return self.base_infer_ms

    def occupancy_dependent(self, tier: str) -> bool:
        """Whether ``infer_ms`` on ``tier`` varies with occupancy — the
        batched request engine takes its fully vectorized path only
        when it does not."""
        return False

    def flat_service_slots(self, tier: str) -> float:
        """The step boundary of the occupancy-service coupling: while a
        replica on ``tier`` has strictly fewer than this many requests
        in service, ``infer_ms`` returns the flat base — the regime the
        batched engine's closed-form bulk replay
        (:func:`repro.sim.request_plane.occupancy_replay`) exploits.
        The constant model is flat everywhere: ``math.inf``."""
        return math.inf

    def base_service_ms(self, tier: str) -> float:
        """Service time in the flat (occupancy below
        :meth:`flat_service_slots`) regime — bit-identical to
        ``infer_ms(tier, occupancy=o)`` for every such ``o``, which is
        what lets the bulk replay broadcast one scalar."""
        return self.infer_ms(tier)

    def infer_ms_array(self, tier: str, occupancy: np.ndarray,
                       ) -> np.ndarray:
        """Vectorized :meth:`infer_ms` over an occupancy array (the
        constant model broadcasts one scalar)."""
        occupancy = np.asarray(occupancy, dtype=np.float64)
        return np.full(occupancy.shape, self.infer_ms(tier))

    def forward_hop_ms(self, rng: np.random.Generator) -> float:
        """Edge->cloud forwarding hop (R3 overflow): the request pays the
        edge leg plus the cloud leg."""
        return float(self.rtt("cloud", rng))

    @classmethod
    def from_measurements(cls, measurements: Mapping[str, object],
                          decode_tokens: int = 0,
                          **kwargs) -> "CalibratedLatencyModel":
        """Build a calibrated model from per-tier engine measurements
        (``ReplicaPool.measure()`` output, or anything exposing
        ``prefill_ms`` / ``decode_ms_per_token`` / ``batch_size``).

        ``decode_tokens`` is the per-request generation length the
        simulator should assume; 0 means prefill-only service (the
        paper's GRU: one forward per request).  Extra ``kwargs`` override
        the network RTT fields."""
        service, slots = {}, {}
        for tier, m in measurements.items():
            service[tier] = float(m.prefill_ms
                                  + decode_tokens * m.decode_ms_per_token)
            slots[tier] = int(m.batch_size)
        return CalibratedLatencyModel(tier_service_ms=service,
                                      tier_slots=slots, **kwargs)


@dataclass(frozen=True)
class CalibratedLatencyModel(LatencyModel):
    """Per-tier service times measured from the serving engines.

    ``infer_ms`` becomes occupancy-dependent: a replica's continuous-
    batching slots serve concurrently at the measured rate; once
    ``occupancy`` exceeds the slot count, requests time-share the decode
    program and per-request service stretches proportionally.  Tiers
    without a measurement fall back to the constant closed-form model, so
    a partially calibrated pool still simulates."""
    tier_service_ms: Dict[str, float] = field(default_factory=dict)
    tier_slots: Dict[str, int] = field(default_factory=dict)

    def infer_ms(self, tier: str, occupancy: float = 0.0) -> float:
        base = self.tier_service_ms.get(tier)
        if base is None:
            return super().infer_ms(tier, occupancy)
        slots = max(self.tier_slots.get(tier, 1), 1)
        oversubscription = max((occupancy + 1.0) / slots, 1.0)
        return base * oversubscription

    def occupancy_dependent(self, tier: str) -> bool:
        return tier in self.tier_service_ms

    def flat_service_slots(self, tier: str) -> float:
        """Continuous-batching slot count of a measured tier: occupancy
        below it serves at the flat measured rate, at or above it the
        ``(occupancy + 1) / slots`` stretch kicks in.  Unmeasured tiers
        inherit the constant model's ``inf``."""
        if tier not in self.tier_service_ms:
            return super().flat_service_slots(tier)
        return float(max(self.tier_slots.get(tier, 1), 1))

    def infer_ms_array(self, tier: str, occupancy: np.ndarray,
                       ) -> np.ndarray:
        base = self.tier_service_ms.get(tier)
        if base is None:
            return super().infer_ms_array(tier, occupancy)
        slots = max(self.tier_slots.get(tier, 1), 1)
        occupancy = np.asarray(occupancy, dtype=np.float64)
        return base * np.maximum((occupancy + 1.0) / slots, 1.0)
