"""§Perf hillclimb 3 (the paper's technique, most representative pair):
llama3-405b x train_4k on the 2x16x16 multi-pod mesh.

The paper's claim, mapped to TPU pods: hierarchical aggregation pays the
expensive cross-pod (DCI) traffic only once per l local rounds, while
flat data-parallel FedAvg pays it every step.  Programs are lowered and
compared on cross-pod collective bytes (replica groups reconstructed
from the compiled HLO; any group spanning both pods is DCI traffic).

Iteration log (hypothesis -> change -> measure -> verdict):
  A    flat baseline (grad sync spans pods every step)
  it1  HFL local rounds as vmap over a cluster-sharded leading dim
       hypothesis: GSPMD keeps the cluster axis local -> 0 cross-pod
  it2  HFL local rounds under manual shard_map over "cluster"
       (structural cluster locality)
  it3  global round, int8 delta compression in pure jnp
       hypothesis: int8 payload halves cross-pod bytes
  it4  global round, int8 via shard_map all_gather (int8 on the wire)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax

import repro.launch.dryrun  # noqa: F401  (sets the 512-device XLA flag)
from repro.configs import INPUT_SHAPES, get_config
from repro.fl.collectives import (global_sync, global_sync_shardmap,
                                  make_hfl_local_step_shardmap)
from repro.fl.compression import (compressed_global_sync,
                                  compressed_global_sync_shardmap,
                                  init_ef_state)
from repro.launch import shardings as sh
from repro.launch.mesh import DCI_BW, make_hfl_mesh, make_production_mesh
from repro.launch.roofline import collective_stats
from repro.launch.specs import model_batch_specs, param_specs_and_axes
from repro.models import make_model
from repro.models.common import logical_sharding
from repro.training.optimizer import AdamW
from repro.training.train_step import make_hfl_train_step, make_train_step

POD_SIZE = 256  # devices per pod on the 2x16x16 mesh


def _stack_specs(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def flat_baseline(arch: str, shape_name: str):
    from repro.launch.dryrun import build_programs
    mesh = make_production_mesh(multi_pod=True)
    cfg = get_config(arch)
    rules = sh.rules_for(cfg, mesh)
    fn, inputs = build_programs(arch, shape_name, mesh, rules)
    compiled = fn.lower(*inputs).compile()
    return collective_stats(compiled.as_text(), POD_SIZE)


class _HFLSetup:
    def __init__(self, arch: str, shape_name: str):
        self.mesh = make_hfl_mesh(multi_pod=True)   # cluster == pod
        self.cfg = get_config(arch)
        self.rules = sh.rules_for(self.cfg, self.mesh)
        self.api = make_model(self.cfg)
        shape = INPUT_SHAPES[shape_name]
        n = self.mesh.shape["cluster"]
        p_struct, axes = param_specs_and_axes(self.api)
        self.p_stacked = _stack_specs(p_struct, n)
        isaxes = lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)
        axes_stacked = jax.tree.map(lambda a: ("cluster",) + tuple(a),
                                    axes, is_leaf=isaxes)
        self.p_sh = sh.params_shardings(axes_stacked, self.p_stacked,
                                        self.mesh, self.rules)
        self.opt = AdamW(lr=self.cfg.run.learning_rate,
                         state_dtype=self.cfg.run.opt_state_dtype)
        self.opt_struct = jax.eval_shape(jax.vmap(self.opt.init),
                                         self.p_stacked)
        self.opt_sh = type(self.opt_struct)(
            step=sh.named_sharding_for(self.mesh, self.rules, ("cluster",),
                                       (n,)),
            m=self.p_sh, v=self.p_sh)
        per = dataclasses.replace(shape,
                                  global_batch=shape.global_batch // n)
        batch = model_batch_specs(self.cfg, per, with_labels=True)
        self.batch_stacked = _stack_specs(batch, n)
        self.b_sh = sh.batch_shardings(self.batch_stacked, self.mesh,
                                       self.rules, cluster_dim=True)

    def lower_local_vmap(self):
        local = make_hfl_train_step(self.api, self.cfg, self.opt)

        def wrapped(p, o, b):
            with logical_sharding(self.mesh, self.rules):
                return local(p, o, b)

        fn = jax.jit(wrapped, in_shardings=(self.p_sh, self.opt_sh,
                                            self.b_sh),
                     out_shardings=(self.p_sh, self.opt_sh,
                                    sh.replicated(self.mesh)),
                     donate_argnums=(0, 1))
        return collective_stats(
            fn.lower(self.p_stacked, self.opt_struct, self.batch_stacked)
            .compile().as_text(), POD_SIZE)

    def lower_local_shardmap(self):
        base = make_train_step(self.api, self.cfg, self.opt)
        # inside the manual region, constraints may not mention "cluster"
        inner_rules = {k: tuple(a for a in v if a != "cluster")
                       for k, v in self.rules.items()}

        def base_with_rules(p, o, b):
            with logical_sharding(self.mesh, inner_rules):
                return base(p, o, b)

        stepped = make_hfl_local_step_shardmap(base_with_rules, self.mesh)
        # XLA workaround: partitioning the embedding *gather* inside a
        # manual subgroup hits an SPMD-partitioner CHECK
        # (spmd_partitioner_util.cc:504, ExpandDeviceGroupsWithIota via
        # PartitionGather).  Replicate the embedding table for this
        # program — it removes that gather's resharding entirely and does
        # not touch the cross-pod traffic being measured.
        p_sh = jax.tree_util.tree_map_with_path(
            lambda path, s: (sh.named_sharding_for(
                self.mesh, self.rules, ("cluster", None, None), (2, 1, 1))
                if any(getattr(k, "key", "") == "embed" for k in path)
                else s),
            self.p_sh)
        opt_sh = type(self.opt_struct)(step=self.opt_sh.step,
                                       m=p_sh, v=p_sh)
        fn = jax.jit(stepped, in_shardings=(p_sh, opt_sh, self.b_sh),
                     donate_argnums=(0, 1))
        return collective_stats(
            fn.lower(self.p_stacked, self.opt_struct, self.batch_stacked)
            .compile().as_text(), POD_SIZE)

    def lower_gsync(self, kind: str):
        if kind == "bf16":
            fn = jax.jit(lambda p: global_sync_shardmap(p, self.mesh),
                         in_shardings=(self.p_sh,), donate_argnums=(0,))
            lowered = fn.lower(self.p_stacked)
        elif kind == "int8_jnp":
            ef = jax.eval_shape(init_ef_state, self.p_stacked)
            ef_sh = type(ef)(anchor=self.p_sh, residual=self.p_sh)
            fn = jax.jit(compressed_global_sync,
                         in_shardings=(self.p_sh, ef_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(self.p_stacked, ef)
        elif kind == "int8_shardmap":
            ef = jax.eval_shape(init_ef_state, self.p_stacked)
            ef_sh = type(ef)(anchor=self.p_sh, residual=self.p_sh)
            # per-leaf inner specs = the param specs minus the manual
            # leading "cluster" dim (keeps the int8 payload sharded over
            # data/model inside the manual region)
            from jax.sharding import PartitionSpec as P
            inner = [P(*ns.spec[1:]) for ns in
                     jax.tree_util.tree_leaves(self.p_sh)]
            fn = jax.jit(lambda p, e: compressed_global_sync_shardmap(
                p, e, self.mesh, inner_specs=inner),
                in_shardings=(self.p_sh, ef_sh), donate_argnums=(0, 1))
            lowered = fn.lower(self.p_stacked, ef)
        else:  # int8_manual: fully-manual shard_map over all axes
            from repro.fl.compression import compressed_global_sync_manual
            ef = jax.eval_shape(init_ef_state, self.p_stacked)
            ef_sh = type(ef)(anchor=self.p_sh, residual=self.p_sh)
            leaf_specs = [ns.spec for ns in
                          jax.tree_util.tree_leaves(self.p_sh)]
            fn = jax.jit(lambda p, e: compressed_global_sync_manual(
                p, e, self.mesh, leaf_specs),
                in_shardings=(self.p_sh, ef_sh), donate_argnums=(0, 1))
            lowered = fn.lower(self.p_stacked, ef)
        return collective_stats(lowered.compile().as_text(), POD_SIZE)


def report(arch="llama3-405b", shape="train_4k", l=2, out=""):
    print(f"=== {arch} x {shape}, l={l}, mesh 2x16x16 (pod == cluster) ===")
    res = {}
    flat = flat_baseline(arch, shape)
    print(f"A  flat baseline      : cross-pod={flat.cross_pod_bytes:.3e} "
          f"B/dev/step (dci {flat.cross_pod_bytes / DCI_BW * 1e3:.1f} ms)")
    res["flat"] = flat.cross_pod_bytes

    s = _HFLSetup(arch, shape)
    it1 = s.lower_local_vmap()
    print(f"it1 local (vmap/GSPMD): cross-pod={it1.cross_pod_bytes:.3e}  "
          f"{'REFUTED (expected 0)' if it1.cross_pod_bytes else 'confirmed'}")
    res["local_vmap"] = it1.cross_pod_bytes
    it2 = s.lower_local_shardmap()
    print(f"it2 local (shard_map) : cross-pod={it2.cross_pod_bytes:.3e}  "
          f"{'confirmed 0' if it2.cross_pod_bytes == 0 else 'nonzero!'}")
    res["local_shardmap"] = it2.cross_pod_bytes

    g_bf16 = s.lower_gsync("bf16")
    print(f"G  global sync (bf16) : cross-pod={g_bf16.cross_pod_bytes:.3e}")
    res["gsync_bf16"] = g_bf16.cross_pod_bytes
    it3 = s.lower_gsync("int8_jnp")
    print(f"it3 global int8 (jnp) : cross-pod={it3.cross_pod_bytes:.3e}  "
          f"{'REFUTED (fp32 on wire)' if it3.cross_pod_bytes >= 0.9 * g_bf16.cross_pod_bytes else 'reduced'}")
    res["gsync_int8_jnp"] = it3.cross_pod_bytes
    it4 = s.lower_gsync("int8_shardmap")
    print(f"it4 global int8 (sm)  : cross-pod={it4.cross_pod_bytes:.3e}  "
          f"({g_bf16.cross_pod_bytes / max(it4.cross_pod_bytes, 1):.2f}x vs bf16)")
    res["gsync_int8_sm"] = it4.cross_pod_bytes
    it5 = s.lower_gsync("int8_manual")
    print(f"it5 global int8 (full-manual): "
          f"cross-pod={it5.cross_pod_bytes:.3e}  "
          f"({g_bf16.cross_pod_bytes / max(it5.cross_pod_bytes, 1):.2f}x vs bf16)")
    res["gsync_int8_manual"] = it5.cross_pod_bytes

    best_g = min(g_bf16.cross_pod_bytes, it5.cross_pod_bytes)
    eff_hfl = it2.cross_pod_bytes + g_bf16.cross_pod_bytes / l
    eff_int8 = it2.cross_pod_bytes + best_g / l
    res.update(effective_hfl=eff_hfl, effective_hfl_int8=eff_int8,
               dci_ms_flat=flat.cross_pod_bytes / DCI_BW * 1e3,
               dci_ms_hfl=eff_hfl / DCI_BW * 1e3,
               dci_ms_hfl_int8=eff_int8 / DCI_BW * 1e3)
    print(f"\neffective cross-pod B/dev/step (global amortized over l={l}):")
    print(f"  flat     : {flat.cross_pod_bytes:.3e}  "
          f"({res['dci_ms_flat']:.1f} ms DCI)")
    print(f"  HFL      : {eff_hfl:.3e}  ({res['dci_ms_hfl']:.1f} ms DCI)  "
          f"-> {flat.cross_pod_bytes / max(eff_hfl, 1):.2f}x")
    print(f"  HFL+int8 : {eff_int8:.3e}  ({res['dci_ms_hfl_int8']:.1f} ms "
          f"DCI)  -> {flat.cross_pod_bytes / max(eff_int8, 1):.2f}x")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(res, f, indent=2)
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-405b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--out", default="results/perf_hfl_vs_flat.json")
    a = ap.parse_args()
    report(a.arch, a.shape, a.l, a.out)
