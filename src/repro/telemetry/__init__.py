"""Continuum telemetry: metrics, span tracing, and decision audit.

One `Telemetry` object carries the three instruments the orchestration
stack shares:

- ``metrics`` — :class:`~repro.telemetry.registry.MetricsRegistry`
  (counters / gauges / histograms with bulk columnar recording for the
  vectorized request plane).
- ``tracer`` — :class:`~repro.telemetry.tracer.SpanTracer` (rounds,
  epochs, aggregation windows, deployment swaps, solver phases,
  serving admit/measure → Chrome/Perfetto trace JSON + JSONL).
- ``audit`` — :class:`~repro.telemetry.audit.DecisionAudit` (every
  orchestration action with trigger, evidence, budget charge, and
  applied/deferred/forced outcome).

Usage::

    from repro.telemetry import Telemetry
    tel = Telemetry()
    res = run_scenario(SCENARIOS["churn"](), "budgeted", telemetry=tel)
    tel.write_trace("trace.json")          # load in ui.perfetto.dev
    tel.audit.write_jsonl("audit.jsonl")
    print(tel.to_prometheus())

Zero-overhead contract: instrumented classes resolve
``self._tel = maybe(telemetry)`` once at construction — `maybe` returns
``None`` unless telemetry is present *and* enabled, so disabled-mode
hot paths pay exactly one ``is None`` branch and never build a single
telemetry object.  Enabled or not, telemetry never draws from any RNG
stream, never schedules events, and never mutates simulation state:
control fingerprints are bit-identical with telemetry on or off
(asserted across the scenario suite in ``tests/test_telemetry.py``).

This package is numpy-only (no jax imports) so the routing/sim
importers stay jax-free.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

from repro.telemetry.audit import AuditRecord, DecisionAudit, OUTCOMES
from repro.telemetry.registry import (Counter, Gauge, Histogram,
                                      MetricsRegistry, Text,
                                      DEFAULT_LATENCY_EDGES_MS)
from repro.telemetry.tracer import Instant, Span, SpanTracer

__all__ = [
    "Telemetry", "maybe", "MetricsRegistry", "Counter", "Gauge",
    "Histogram", "Text", "SpanTracer", "Span", "Instant",
    "DecisionAudit", "AuditRecord", "OUTCOMES",
    "DEFAULT_LATENCY_EDGES_MS",
]


class Telemetry:
    """Facade bundling a metrics registry, span tracer, and audit log."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = bool(enabled)
        self.metrics = MetricsRegistry()
        self.tracer = SpanTracer()
        self.audit = DecisionAudit()

    # -- export surface --------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """JSON-able snapshot of everything recorded so far."""
        return {
            "enabled": self.enabled,
            "metrics": self.metrics.snapshot(),
            "spans": len(self.tracer.spans),
            "instants": len(self.tracer.instants),
            "audit": self.audit.counts(),
        }

    def to_prometheus(self) -> str:
        return self.metrics.to_prometheus()

    def write_snapshot(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)

    def write_trace(self, path: str) -> None:
        """Chrome/Perfetto trace-event JSON (open in ui.perfetto.dev)."""
        self.tracer.write_chrome(path)

    def write_trace_jsonl(self, path: str) -> None:
        self.tracer.write_jsonl(path)


def maybe(telemetry: Optional[Telemetry]) -> Optional[Telemetry]:
    """Resolve a telemetry argument to the hot-path handle: the object
    itself when present and enabled, else ``None`` — so instrumented
    code guards with a single ``if self._tel is not None``."""
    if telemetry is not None and telemetry.enabled:
        return telemetry
    return None
