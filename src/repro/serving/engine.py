"""Serving engine: jitted prefill / decode steps over the unified model
API, with greedy sampling.  ``decode_step`` is the program lowered by the
``decode_32k`` / ``long_500k`` dry-run shapes."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ModelApi, make_model


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any,
                 batch_size: int, max_len: Optional[int] = None):
        self.cfg = cfg
        self.api = make_model(cfg)
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len or cfg.run.max_cache_len
        self.cache = self.api.init_cache(batch_size, self.max_len)
        self.pos = jnp.zeros((), jnp.int32)
        self._decode = jax.jit(self._decode_impl)

    def _decode_impl(self, params, tokens, pos, cache):
        logits, cache = self.api.decode_step(params, tokens, pos, cache)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    def step(self, tokens: jax.Array) -> jax.Array:
        """tokens (B,1) -> next token ids (B,)."""
        next_tok, self.cache = self._decode(self.params, tokens, self.pos,
                                            self.cache)
        self.pos = self.pos + 1
        return next_tok

    def generate(self, prompt_tokens: jax.Array, steps: int) -> jax.Array:
        """Greedy generation: feeds the prompt token-by-token then samples
        ``steps`` continuations.  Returns (B, steps)."""
        B, S = prompt_tokens.shape
        out = []
        tok = None
        for s in range(S):
            tok = self.step(prompt_tokens[:, s:s + 1])
        for _ in range(steps):
            out.append(tok)
            tok = self.step(tok[:, None])
        return jnp.stack(out, axis=1)
