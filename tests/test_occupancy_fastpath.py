"""Occupancy-aware fast path: the vectorized calibrated service replay
(``request_plane.occupancy_replay``), control-window fusion, the
parallel scenario grid, and the columnar-log satellites (lazy rule
strings, grouped windowed percentiles, order-statistic bootstrap CIs).
"""
import heapq

import numpy as np
import pytest

from repro.routing import CalibratedLatencyModel, LatencyModel, SimConfig, \
    simulate
from repro.routing.simulator import RequestLog
from repro.sim import CoSim, CoSimConfig
from repro.sim.request_plane import RULE_CODE, occupancy_replay
from repro.sim.scenarios import SCENARIOS, hot_zone_topology, run_grid, \
    run_scenario


# ---------------------------------------------------------------------------
# occupancy_replay vs the scalar (heap-arithmetic) reference
# ---------------------------------------------------------------------------

def _scalar_reference(t, pending, service_ms_fn):
    """The pre-vectorization per-request loop, verbatim: pop completed,
    serve at current occupancy, push own completion."""
    pend = list(pending)
    heapq.heapify(pend)
    service = np.empty(t.size)
    for k, tk in enumerate(t):
        while pend and pend[0] <= tk:
            heapq.heappop(pend)
        s = service_ms_fn(len(pend))
        service[k] = s
        heapq.heappush(pend, tk + s / 1000.0)
    return service, np.sort(np.asarray(pend, dtype=np.float64))


def _calibrated_fn(base_ms, slots, stretch=1.0):
    lat = CalibratedLatencyModel(tier_service_ms={"edge": base_ms},
                                 tier_slots={"edge": slots})
    return lambda occ: lat.infer_ms("edge", occupancy=occ) * stretch


@pytest.mark.parametrize("slots,load_mult,seed", [
    (1, 0.5, 0),       # single slot, underloaded: mostly bulk
    (1, 1.5, 1),       # single slot, overloaded: mostly scalar
    (2, 1.0, 2),       # critically loaded at the boundary
    (4, 0.95, 3),      # grazing the slot count from below
    (4, 1.05, 4),      # grazing it from above
    (8, 2.0, 5),       # deep oversubscription stretches
])
def test_occupancy_replay_bit_exact(slots, load_mult, seed):
    """The vectorized replay is bit-identical to the scalar loop —
    service arrays AND carried pending state — across under-, over-
    and boundary-loaded regimes."""
    rng = np.random.default_rng(seed)
    base_ms = 40.0
    rate = slots / (base_ms / 1000.0) * load_mult
    t = np.cumsum(rng.exponential(1.0 / rate, size=3000))
    fn = _calibrated_fn(base_ms, slots)
    got_s, got_p = occupancy_replay(t, np.zeros(0), base_ms, float(slots),
                                    fn)
    want_s, want_p = _scalar_reference(t, np.zeros(0), fn)
    assert np.array_equal(got_s, want_s)
    assert np.array_equal(got_p, want_p)


def test_occupancy_replay_resumes_across_windows():
    """Pending state carried across flush windows equals one long
    replay — the co-sim cuts windows at arbitrary control events."""
    rng = np.random.default_rng(11)
    base_ms, slots = 30.0, 3
    rate = slots / (base_ms / 1000.0)
    t = np.cumsum(rng.exponential(1.0 / rate, size=4000))
    fn = _calibrated_fn(base_ms, slots)
    want_s, want_p = _scalar_reference(t, np.zeros(0), fn)
    pend = np.zeros(0)
    parts = []
    for chunk in np.array_split(t, 17):
        s, pend = occupancy_replay(chunk, pend, base_ms, float(slots), fn)
        parts.append(s)
    assert np.array_equal(np.concatenate(parts), want_s)
    assert np.array_equal(pend, want_p)


def test_occupancy_replay_with_interference_stretch():
    """The flat base is base x stretch — exactly what a window under
    training interference hands the replay."""
    rng = np.random.default_rng(5)
    base_ms, slots, stretch = 25.0, 2, 1.75
    t = np.cumsum(rng.exponential(0.012, size=2000))
    fn = _calibrated_fn(base_ms, slots, stretch)
    got_s, got_p = occupancy_replay(t, np.zeros(0), base_ms * stretch,
                                    float(slots), fn)
    want_s, want_p = _scalar_reference(t, np.zeros(0), fn)
    assert np.array_equal(got_s, want_s)
    assert np.array_equal(got_p, want_p)


def test_occupancy_replay_boundary_fuzz():
    """Seeded fuzz of the oversubscription boundary: occupancy grazing
    ``slots`` is where the bulk run's cut decision must agree with the
    scalar recursion to the bit.  Sweeps rates around the knee with
    random carried-over pending arrays."""
    rng = np.random.default_rng(99)
    for trial in range(60):
        slots = int(rng.integers(1, 6))
        base_ms = float(rng.uniform(5.0, 80.0))
        load = float(rng.uniform(0.8, 1.2))     # hover at the knee
        rate = slots / (base_ms / 1000.0) * load
        n = int(rng.integers(50, 800))
        t0 = float(rng.uniform(0.0, 2.0))
        t = t0 + np.cumsum(rng.exponential(1.0 / rate, size=n))
        n_pend = int(rng.integers(0, 2 * slots + 2))
        pend = np.sort(t0 + rng.uniform(-0.05, 0.2, size=n_pend))
        fn = _calibrated_fn(base_ms, slots)
        got_s, got_p = occupancy_replay(t, pend, base_ms, float(slots), fn)
        want_s, want_p = _scalar_reference(t, pend, fn)
        assert np.array_equal(got_s, want_s), \
            (trial, slots, base_ms, load)
        assert np.array_equal(got_p, want_p), \
            (trial, slots, base_ms, load)


def test_occupancy_replay_level_bucket_fuzz():
    """Seeded fuzz of the deep-oversubscription regime the per-level
    bucketing serves in bulk: sustained occupancy above ``slots`` with
    bursts and lulls forcing frequent level changes — service arrays
    and carried pending must match the scalar recursion to the bit."""
    rng = np.random.default_rng(1234)
    for trial in range(40):
        slots = int(rng.integers(1, 5))
        base_ms = float(rng.uniform(10.0, 120.0))
        rate = slots / (base_ms / 1000.0) * float(rng.uniform(1.5, 4.0))
        # bursty arrivals: alternating hot/cold segments move the
        # steady-state occupancy level mid-replay
        segs = []
        t_cur = float(rng.uniform(0.0, 1.0))
        for _ in range(int(rng.integers(2, 6))):
            k = int(rng.integers(30, 400))
            mult = float(rng.uniform(0.3, 3.0))
            seg = t_cur + np.cumsum(
                rng.exponential(1.0 / (rate * mult), size=k))
            t_cur = float(seg[-1]) + float(rng.uniform(0.0, 0.3))
            segs.append(seg)
        t = np.concatenate(segs)
        n_pend = int(rng.integers(0, 4 * slots + 4))
        pend = np.sort(float(t[0]) + rng.uniform(-0.1, 0.5, size=n_pend))
        fn = _calibrated_fn(base_ms, slots)
        got_s, got_p = occupancy_replay(t, pend, base_ms, float(slots), fn)
        want_s, want_p = _scalar_reference(t, pend, fn)
        assert np.array_equal(got_s, want_s), (trial, slots, base_ms)
        assert np.array_equal(got_p, want_p), (trial, slots, base_ms)


# ---------------------------------------------------------------------------
# end-to-end: calibrated co-sim stays bit-identical to the heap engine
# ---------------------------------------------------------------------------

def _training(duration):
    from repro.fl import round_schedule
    rounds = max(int(duration / 20.0), 1)
    return round_schedule(rounds=rounds, l=2, local_epochs=5, epoch_s=3.5,
                          upload_s=2.0, gap_s=2.0)


@pytest.mark.parametrize("slots,service_ms", [(1, 60.0), (2, 40.0),
                                              (6, 120.0)])
def test_calibrated_oversubscribed_cosim_parity(slots, service_ms):
    """Heap-vs-batched bit-identity through the vectorized occupancy
    replay on configurations that genuinely oversubscribe the edges
    (deep queues, not just boundary grazing)."""
    lat = CalibratedLatencyModel(tier_service_ms={"edge": service_ms},
                                 tier_slots={"edge": slots})
    logs = {}
    for engine in ("heap", "batched"):
        topo, *_ = hot_zone_topology(seed=1)
        cfg = CoSimConfig(duration_s=40.0, seed=1, engine=engine,
                          latency=lat)
        logs[engine] = CoSim(topo, cfg, schedule=_training(40.0)).run().log
    assert np.array_equal(logs["heap"].latency_ms,
                          logs["batched"].latency_ms)
    assert np.array_equal(logs["heap"].rule_code,
                          logs["batched"].rule_code)


def test_calibrated_scenario_engine_parity():
    """The scenario engine (reactive loop + perturbations) through a
    calibrated model: both engines, same control fingerprint."""
    lat = CalibratedLatencyModel(tier_service_ms={"edge": 40.0},
                                 tier_slots={"edge": 2})
    rb = run_scenario(SCENARIOS["churn"](), policy="reactive", seed=0,
                      duration_s=45.0, engine="batched", latency=lat)
    rh = run_scenario(SCENARIOS["churn"](), policy="reactive", seed=0,
                      duration_s=45.0, engine="heap", latency=lat)
    assert rb.control_fingerprint() == rh.control_fingerprint()
    assert np.array_equal(rb.log.latency_ms, rh.log.latency_ms)


# ---------------------------------------------------------------------------
# control-window fusion: trace equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sc_name,policy", [
    ("baseline", "static"), ("straggler", "reactive"),
    ("mobility", "budgeted"), ("multi_tenant", "reactive"),
    ("churn", "budgeted")])
def test_fused_windows_trace_equivalent(sc_name, policy):
    """Fused and unfused runs of the same (scenario, policy, seed) must
    produce identical full traces, request logs, and reactive actions —
    the fusion guarantee across the scenario suite."""
    fused = run_scenario(SCENARIOS[sc_name](), policy=policy, seed=0,
                         duration_s=60.0, fuse_windows=True)
    plain = run_scenario(SCENARIOS[sc_name](), policy=policy, seed=0,
                         duration_s=60.0, fuse_windows=False)
    assert fused.fingerprint() == plain.fingerprint()
    assert fused.control_fingerprint() == plain.control_fingerprint()
    assert np.array_equal(fused.log.latency_ms, plain.log.latency_ms)
    assert fused.log.rule == plain.log.rule
    assert fused.actions == plain.actions
    assert fused.trace == plain.trace


def test_fusion_actually_fires():
    """A continual-training co-sim must fuse some windows (ROUND_START
    is effect-free; straggler-cancelled epoch events are no-ops) —
    guard against the gate silently degrading to flush-always."""
    topo, *_ = hot_zone_topology(seed=0)
    cfg = CoSimConfig(duration_s=60.0, seed=0)
    cosim = CoSim(topo, cfg, schedule=_training(60.0))
    cosim.schedule_straggler(12.0, 0, 4.0)
    cosim.run()
    assert cosim.sim.fused_windows > 0
    unfused = CoSim(topo, CoSimConfig(duration_s=60.0, seed=0,
                                      fuse_windows=False),
                    schedule=_training(60.0))
    unfused.run()
    assert unfused.sim.fused_windows == 0


def test_fusion_overlapping_bursts_equivalent():
    """Overlapping training bursts make devices busy twice over —
    exactly the regime where epoch boundaries stop flipping the busy
    flag and fuse.  Results must not change."""
    results = {}
    for fuse in (True, False):
        topo, *_ = hot_zone_topology(seed=2)
        cfg = CoSimConfig(duration_s=50.0, seed=2, fuse_windows=fuse)
        cosim = CoSim(topo, cfg, schedule=_training(50.0))
        from repro.fl import round_schedule
        cosim.add_training(round_schedule(rounds=2, l=2, local_epochs=3,
                                          epoch_s=5.0, upload_s=2.0,
                                          start_s=7.0))
        res = cosim.run()
        results[fuse] = (res.log.latency_ms, res.trace,
                        cosim.sim.fused_windows)
    assert np.array_equal(results[True][0], results[False][0])
    assert results[True][1] == results[False][1]
    assert results[True][2] > results[False][2] == 0


# ---------------------------------------------------------------------------
# parallel scenario grid
# ---------------------------------------------------------------------------

def test_run_grid_parallel_matches_serial():
    """jobs=2 over the process pool returns bit-identical cells (same
    fingerprints, same summary numbers) in the same order as serial."""
    names = ("straggler", "mobility")
    serial = run_grid(names, ("static", "reactive"), jobs=1,
                      check_determinism=True, seed=0, duration_s=40.0)
    parallel = run_grid(names, ("static", "reactive"), jobs=2,
                        check_determinism=False, seed=0, duration_s=40.0)
    assert list(serial) == list(parallel)
    for key in serial:
        s, det = serial[key]
        p, _ = parallel[key]
        assert det is True
        assert s.fingerprint() == p.fingerprint()
        assert s.p95 == p.p95 and s.n_requests == p.n_requests


# ---------------------------------------------------------------------------
# columnar-log satellites
# ---------------------------------------------------------------------------

def test_request_log_lazy_rules():
    codes = np.array([0, 2, 5, 2], dtype=np.int8)
    log = RequestLog(t=np.arange(4.0), device=np.zeros(4, np.int64),
                     tier=np.zeros(4, np.int64),
                     latency_ms=np.ones(4), rule_code=codes)
    assert log._rule_names is None          # nothing materialized yet
    assert log.rule == ["R1", "R2-local", "R3-overflow", "R2-local"]
    assert log.rule is log.rule             # cached
    assert np.array_equal(log.rule_code, codes)
    # legacy constructor (string names) still round-trips
    legacy = RequestLog(t=np.zeros(2), device=np.zeros(2, np.int64),
                        tier=np.zeros(2, np.int64),
                        rule=["R1", "R3-overflow"],
                        latency_ms=np.zeros(2))
    assert np.array_equal(legacy.rule_code,
                          [RULE_CODE["R1"], RULE_CODE["R3-overflow"]])
    assert legacy.rule == ["R1", "R3-overflow"]


def test_simulate_log_defers_rule_strings():
    topo, *_ = hot_zone_topology(seed=0)
    log = simulate(topo, SimConfig(duration_s=10.0, seed=0))
    assert log._rule_names is None
    assert log.rule_code.dtype == np.int8
    assert len(log.rule) == log.t.size


def test_windowed_percentile_matches_naive():
    """The grouped-sort windowed percentile equals the per-window
    np.percentile loop it replaced, NaN rows included."""
    rng = np.random.default_rng(3)
    t = np.sort(rng.uniform(0.0, 100.0, 4000))
    t = t[(t < 40.0) | (t > 60.0)]          # force empty windows
    lat = rng.exponential(15.0, t.size)
    log = RequestLog(t=t, device=np.zeros(t.size, np.int64),
                     tier=np.zeros(t.size, np.int64),
                     latency_ms=lat,
                     rule_code=np.zeros(t.size, np.int8))
    for window_s, p in ((5.0, 95.0), (7.3, 50.0), (10.0, 99.0)):
        got = log.windowed_percentile(window_s, p)
        edges = np.arange(0.0, float(t[-1]) + 1e-9, window_s)
        bounds = np.searchsorted(t, np.append(edges,
                                              edges[-1] + window_s))
        assert got.shape == (edges.size, 2)
        assert np.array_equal(got[:, 0], edges)
        for k in range(edges.size):
            sl = lat[bounds[k]:bounds[k + 1]]
            if sl.size == 0:
                assert np.isnan(got[k, 1])
            else:
                assert got[k, 1] == pytest.approx(
                    float(np.percentile(sl, p)), rel=1e-12)


def test_percentile_ci_brackets_point_estimate():
    rng = np.random.default_rng(0)
    lat = rng.exponential(10.0, 20000)
    log = RequestLog(t=np.sort(rng.uniform(0, 100, lat.size)),
                     device=np.zeros(lat.size, np.int64),
                     tier=np.zeros(lat.size, np.int64),
                     latency_ms=lat,
                     rule_code=np.zeros(lat.size, np.int8))
    p95 = log.percentile_latency(95)
    lo, hi = log.percentile_ci(95)
    assert lo <= p95 <= hi
    assert hi - lo < 0.2 * p95              # tight at 20k samples
    assert (lo, hi) == log.percentile_ci(95)   # deterministic
    empty = RequestLog(t=np.zeros(0), device=np.zeros(0, np.int64),
                       tier=np.zeros(0, np.int64),
                       latency_ms=np.zeros(0),
                       rule_code=np.zeros(0, np.int8))
    assert all(np.isnan(v) for v in empty.percentile_ci(95))
