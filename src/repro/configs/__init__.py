from repro.configs.base import (ArchConfig, AttentionConfig, FrontendConfig,
                                INPUT_SHAPES, InputShape, MLAConfig,
                                ModelConfig, MoEConfig, RunConfig, SSMConfig,
                                XLSTMConfig, TRAIN_4K, PREFILL_32K, DECODE_32K,
                                LONG_500K)
from repro.configs.registry import (ASSIGNED, all_configs, applicable_shapes,
                                    get_config)

__all__ = [
    "ArchConfig", "AttentionConfig", "FrontendConfig", "INPUT_SHAPES",
    "InputShape", "MLAConfig", "ModelConfig", "MoEConfig", "RunConfig",
    "SSMConfig", "XLSTMConfig", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "ASSIGNED", "all_configs", "applicable_shapes", "get_config",
]
