"""Telemetry non-perturbation rules.

The observability layer's core promise (see ``repro.telemetry``):
enabling tracing/metrics/audit changes *nothing* about the simulated
system — control fingerprints are bit-identical with telemetry on or
off, and disabled mode costs one is-None branch.  Two rules keep that
promise honest:

- TEL001: telemetry code never perturbs the simulation.  Inside
  ``repro.telemetry`` itself and inside ``if self._tel is not None:``
  guarded blocks anywhere, no RNG draws, no event scheduling
  (``.schedule()`` / ``heappush``), and — in guarded blocks — no
  mutation of non-telemetry state the surrounding code can observe.
- TEL002: instrumented classes resolve the telemetry facade once at
  construction (``self._tel = maybe(telemetry)``), never per call in
  hot paths — ``maybe()`` in a loop or a non-init method is a finding.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.core import (FileContext, Finding, Rule, dotted_name)

#: np.random.Generator draw methods (the explicit-stream idiom means the
#: receiver is conventionally named ``rng``/``_rng``)
RNG_DRAW_METHODS = {
    "random", "normal", "standard_normal", "uniform", "integers",
    "choice", "shuffle", "permutation", "exponential", "poisson",
    "binomial", "gamma", "beta", "lognormal", "geometric",
}

#: attribute components that mark a chain as telemetry-owned state
TEL_COMPONENTS = {"tel", "_tel", "tracer", "metrics", "audit",
                  "telemetry"}

#: list/set/dict methods that mutate their receiver
MUTATING_METHODS = {"append", "add", "extend", "insert", "update", "pop",
                    "remove", "clear", "setdefault", "discard",
                    "popleft", "appendleft"}


def _chain_parts(node: ast.AST) -> List[str]:
    name = dotted_name(node)
    return name.split(".") if name else []


def _is_tel_chain(node: ast.AST, tel_locals: Set[str]) -> bool:
    parts = _chain_parts(node)
    if not parts:
        return False
    if parts[0] in tel_locals:
        return True
    return any(p in TEL_COMPONENTS for p in parts)


def _derives_from_tel(node: ast.AST, tel_locals: Set[str]) -> bool:
    """Whether an expression's value flows out of the telemetry facade
    (``self._tel.metrics``, ``m.counter(...)`` with tel-derived ``m``)."""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Attribute, ast.Name)):
            if _is_tel_chain(sub, tel_locals):
                return True
    return False


def _guard_is_tel_check(test: ast.expr) -> bool:
    """``<chain ending in tel/_tel> is not None`` — possibly one clause
    of an ``and`` chain, possibly a bare truthiness test on the chain."""
    clauses = (test.values if isinstance(test, ast.BoolOp)
               and isinstance(test.op, ast.And) else [test])
    for clause in clauses:
        target: Optional[ast.expr] = None
        if (isinstance(clause, ast.Compare)
                and len(clause.ops) == 1
                and isinstance(clause.ops[0], ast.IsNot)
                and isinstance(clause.comparators[0], ast.Constant)
                and clause.comparators[0].value is None):
            target = clause.left
        elif isinstance(clause, (ast.Attribute, ast.Name)):
            target = clause
        if target is not None:
            parts = _chain_parts(target)
            if parts and parts[-1] in ("tel", "_tel", "telemetry"):
                return True
    return False


class _RegionChecker:
    """Shared deny-list walk over one telemetry-only region."""

    def __init__(self, ctx: FileContext, rule_id: str,
                 check_mutations: bool):
        self.ctx = ctx
        self.rule_id = rule_id
        self.check_mutations = check_mutations
        self.findings: List[Finding] = []
        # plain-name locals assigned inside the region (scratch state the
        # outside can't observe) and the subset derived from telemetry
        self.block_locals: Set[str] = set()
        self.tel_locals: Set[str] = set()

    def _emit(self, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            path=self.ctx.rel_path, line=node.lineno, rule=self.rule_id,
            message=message))

    def check_stmts(self, stmts: List[ast.stmt]) -> None:
        for stmt in stmts:
            self._check_stmt(stmt)

    def _check_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.With):
            # `with self._tel.tracer.wall("x"): <timed work>` — the body
            # is the *measured* code, not telemetry code; the span
            # context manager wraps work that runs either way
            if any(_derives_from_tel(item.context_expr, self.tel_locals)
                   for item in stmt.items):
                return
            self._check_exprs_in(stmt)
            self.check_stmts(stmt.body)
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            self._check_assign(stmt)
            if stmt.value is not None:
                self._check_exprs(stmt.value)
            return
        self._check_exprs_in(stmt)
        for attr in ("body", "orelse", "finalbody"):
            self.check_stmts(getattr(stmt, attr, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            self.check_stmts(handler.body)

    def _check_assign(self, stmt: ast.stmt) -> None:
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        value = stmt.value
        tel_value = value is not None and _derives_from_tel(
            value, self.tel_locals)
        for target in targets:
            if isinstance(target, ast.Name):
                self.block_locals.add(target.id)
                if tel_value:
                    self.tel_locals.add(target.id)
            elif isinstance(target, ast.Tuple):
                for elt in target.elts:
                    if isinstance(elt, ast.Name):
                        self.block_locals.add(elt.id)
            elif (self.check_mutations
                  and isinstance(target, (ast.Attribute, ast.Subscript))):
                base = (target.value if isinstance(target, ast.Subscript)
                        else target)
                parts = _chain_parts(base)
                root_local = bool(parts) and parts[0] in self.block_locals
                if (not _is_tel_chain(base, self.tel_locals)
                        and not root_local and not tel_value):
                    name = dotted_name(base) or "<expr>"
                    self._emit(target,
                               f"telemetry-guarded block mutates "
                               f"non-telemetry state {name!r}")

    def _check_exprs_in(self, stmt: ast.stmt) -> None:
        for field_value in ast.iter_fields(stmt):
            value = field_value[1]
            if isinstance(value, ast.expr):
                self._check_exprs(value)
            elif isinstance(value, list):
                for item in value:
                    if isinstance(item, ast.expr):
                        self._check_exprs(item)

    def _check_exprs(self, expr: ast.expr) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = dotted_name(func) or ""
            parts = name.split(".")
            last = parts[-1] if parts else ""
            if last == "schedule":
                self._emit(node, "telemetry code schedules a simulation "
                                 "event (.schedule call)")
            elif last in ("heappush", "heappop", "heapreplace",
                          "heappushpop"):
                self._emit(node, f"telemetry code touches an event heap "
                                 f"({last})")
            elif (last in RNG_DRAW_METHODS and len(parts) >= 2
                  and ("rng" in parts[-2] or "random" in parts[-2])):
                self._emit(node, f"telemetry code draws randomness "
                                 f"({name}); RNG streams must be "
                                 f"untouched by observability")
            elif (self.check_mutations and last in MUTATING_METHODS
                  and isinstance(func, ast.Attribute)):
                base_parts = _chain_parts(func.value)
                root_local = (bool(base_parts)
                              and base_parts[0] in self.block_locals)
                if (base_parts and not root_local
                        and not _is_tel_chain(func.value,
                                              self.tel_locals)):
                    recv = dotted_name(func.value) or "<expr>"
                    self._emit(node,
                               f"telemetry-guarded block mutates "
                               f"non-telemetry state via "
                               f"{recv}.{last}()")


class NonPerturbationRule(Rule):
    """TEL001: telemetry never perturbs simulation state."""

    id = "TEL001"
    name = "telemetry-non-perturbation"
    description = ("repro.telemetry and `if self._tel is not None:` "
                   "blocks must not draw RNG, schedule events, or "
                   "mutate non-telemetry state")

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if ctx.module is None or not ctx.module.startswith("repro"):
            return []
        findings: List[Finding] = []
        if (ctx.module == "repro.telemetry"
                or ctx.module.startswith("repro.telemetry.")):
            checker = _RegionChecker(ctx, self.id, check_mutations=False)
            checker.check_stmts(ctx.tree.body)
            findings.extend(checker.findings)
            for node in ast.walk(ctx.tree):
                if isinstance(node, (ast.Import, ast.ImportFrom)):
                    mods = ([a.name for a in node.names]
                            if isinstance(node, ast.Import)
                            else [node.module or ""])
                    if "random" in mods:
                        findings.append(Finding(
                            path=ctx.rel_path, line=node.lineno,
                            rule=self.id,
                            message="telemetry module imports stdlib "
                                    "random"))
        else:
            for node in ast.walk(ctx.tree):
                if (isinstance(node, ast.If)
                        and _guard_is_tel_check(node.test)):
                    checker = _RegionChecker(ctx, self.id,
                                             check_mutations=True)
                    checker.check_stmts(node.body)
                    findings.extend(checker.findings)
        return findings


class TelemetryBindOnceRule(Rule):
    """TEL002: resolve the telemetry facade once, at construction."""

    id = "TEL002"
    name = "telemetry-bind-once"
    description = ("maybe()/_maybe_tel() must run at construction "
                   "(__init__/__post_init__/bind) or module-function "
                   "scope, never inside loops or per-call methods")

    ALLOWED_METHODS = {"__init__", "__post_init__", "bind", "attach"}

    def check_file(self, ctx: FileContext) -> List[Finding]:
        if ctx.module is None or not ctx.module.startswith("repro"):
            return []
        if ctx.module.startswith("repro.telemetry"):
            return []           # the resolver's own home
        resolver_names = {"maybe", "_maybe_tel"}
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.ImportFrom)
                    and (node.module or "").startswith("repro.telemetry")):
                for alias in node.names:
                    if alias.name in ("maybe", "_maybe_tel"):
                        resolver_names.add(alias.asname or alias.name)
        findings: List[Finding] = []

        def visit(node: ast.AST, in_loop: bool,
                  method_of_class: Optional[str]) -> None:
            for child in ast.iter_child_nodes(node):
                child_loop = in_loop or isinstance(
                    child, (ast.For, ast.While, ast.AsyncFor))
                child_method = method_of_class
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    if isinstance(node, ast.ClassDef):
                        child_method = child.name
                    else:
                        child_method = None
                    child_loop = False
                elif isinstance(child, ast.ClassDef):
                    child_method = None
                if (isinstance(child, ast.Call)
                        and isinstance(child.func, ast.Name)
                        and child.func.id in resolver_names):
                    if child_loop:
                        findings.append(Finding(
                            path=ctx.rel_path, line=child.lineno,
                            rule=self.id,
                            message="telemetry facade resolved inside a "
                                    "loop; bind self._tel = maybe(...) "
                                    "once at construction"))
                    elif (child_method is not None
                          and child_method not in self.ALLOWED_METHODS):
                        findings.append(Finding(
                            path=ctx.rel_path, line=child.lineno,
                            rule=self.id,
                            message=f"telemetry facade resolved per-call "
                                    f"in method {child_method}(); bind "
                                    f"once in __init__/bind"))
                visit(child, child_loop, child_method)

        visit(ctx.tree, False, None)
        return findings
