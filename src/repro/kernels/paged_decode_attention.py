"""Paged flash-decode Pallas kernels: ONE query token per sequence
against a *paged* KV (or MLA latent) cache, gathered through per-sequence
block tables instead of a contiguous ``(B, C, Hkv, D)`` cache.

Reuses the online-softmax structure of ``kernels/decode_attention.py``
(grid over key blocks, running max / sum / accumulator scratch), but the
key block for grid step ``p`` is page ``block_tables[b, p]`` of a global
``(P, page_size, ...)`` page array — the block table rides in as a
scalar-prefetch operand so the BlockSpec index map can compute the DMA
source before the kernel body runs.  Sequences mask by *logical* token
index: token ``t`` of sequence ``b`` lives at page ``t // page_size``
slot ``t % page_size`` and is valid iff ``t < lengths[b]`` (and inside
the sliding window, when one is set).

Two variants:

  * :func:`paged_decode_attention` — GQA: the query's G = H/Hkv grouped
    heads stay together in VMEM so each page is read once per kv head.
  * :func:`paged_mla_decode_attention` — DeepSeek MLA with matrix
    absorption: queries arrive already projected into latent space
    (``q_c = q_nope @ w_uk``), scores are taken against the compressed
    ``c_kv``/``k_rope`` page arrays directly, and the context returned
    is latent-space (caller applies ``w_uv``); all H heads share every
    page read since MLA caches are head-free.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA over paged KV
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, ps: int, scale: float,
                  soft_cap: float, window: Optional[int]):
    b = pl.program_id(0)
    p = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)            # (ps, D)
    v = v_ref[0, :, 0].astype(jnp.float32)            # (ps, Dv)
    tok = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    ok = tok < length                                 # (1, ps)
    if window is not None:
        ok &= (length - 1 - tok) < window
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if soft_cap:
        s = jnp.tanh(s / soft_cap) * soft_cap
    s = jnp.where(ok, s, NEG_INF)                     # (G, ps)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    pw = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pw, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pw, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(p == np_ - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("soft_cap", "window",
                                             "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *, soft_cap: float = 0.0,
                           window: Optional[int] = None,
                           interpret: bool = True) -> jax.Array:
    """q (B,H,D); k/v_pages (P, page_size, Hkv, D); block_tables
    (B, pages_per_seq) i32 page ids (pad rows past a sequence's pages
    with any in-bounds id — they mask out); lengths (B,) i32 valid
    tokens -> (B,H,Dv)."""
    B, H, D = q.shape
    ps, Hkv = k_pages.shape[1], k_pages.shape[2]
    Dv = v_pages.shape[-1]
    G = H // Hkv
    pages_per_seq = block_tables.shape[1]
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, pages_per_seq)
    kernel = functools.partial(
        _paged_kernel, ps=ps, scale=1.0 / math.sqrt(D),
        soft_cap=soft_cap, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D),
                             lambda b, h, p, bt, ln: (b, h, 0, 0)),
                pl.BlockSpec((1, ps, 1, D),
                             lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
                pl.BlockSpec((1, ps, 1, Dv),
                             lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dv),
                                   lambda b, h, p, bt, ln: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, Dv), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, Dv)


# ---------------------------------------------------------------------------
# MLA (absorbed) over paged latents
# ---------------------------------------------------------------------------

def _paged_mla_kernel(bt_ref, len_ref, qc_ref, qr_ref, ckv_ref, kr_ref,
                      o_ref, acc_ref, m_ref, l_ref, *, ps: int,
                      scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    qc = qc_ref[0].astype(jnp.float32)                # (H, R)
    qr = qr_ref[0].astype(jnp.float32)                # (H, Dr)
    ckv = ckv_ref[0].astype(jnp.float32)              # (ps, R)
    kr = kr_ref[0].astype(jnp.float32)                # (ps, Dr)
    tok = p * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    ok = tok < length
    s = (jax.lax.dot_general(qc, ckv, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)) * scale
    s = jnp.where(ok, s, NEG_INF)                     # (H, ps)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    pw = jnp.exp(s - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pw, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        pw, ckv, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(p == np_ - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_mla_decode_attention(q_c: jax.Array, q_rope: jax.Array,
                               ckv_pages: jax.Array, krope_pages: jax.Array,
                               block_tables: jax.Array, lengths: jax.Array,
                               *, scale: float,
                               interpret: bool = True) -> jax.Array:
    """Absorbed-MLA paged decode.  q_c (B,H,R) latent-space queries;
    q_rope (B,H,Dr); ckv/krope_pages (P, page_size, R|Dr); block_tables
    (B, pages_per_seq); lengths (B,).  ``scale`` is the *full* qk scale
    ``1/sqrt(nope_dim + rope_dim)``.  Returns latent-space context
    (B,H,R) — apply ``w_uv`` outside."""
    B, H, R = q_c.shape
    ps = ckv_pages.shape[1]
    Dr = krope_pages.shape[-1]
    pages_per_seq = block_tables.shape[1]
    grid = (B, pages_per_seq)
    kernel = functools.partial(_paged_mla_kernel, ps=ps, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, R), lambda b, p, bt, ln: (b, 0, 0)),
                pl.BlockSpec((1, H, Dr), lambda b, p, bt, ln: (b, 0, 0)),
                pl.BlockSpec((1, ps, R),
                             lambda b, p, bt, ln: (bt[b, p], 0, 0)),
                pl.BlockSpec((1, ps, Dr),
                             lambda b, p, bt, ln: (bt[b, p], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, R),
                                   lambda b, p, bt, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, R), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, R), q_c.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q_c, q_rope, ckv_pages, krope_pages)
