"""Contract checker: AST-based invariant linter for this repo.

Run ``python -m repro.analysis`` (CI does, as a hard gate).  The rules
and the invariants behind them are documented in CONTRACTS.md at the
repo root; suppress a sanctioned violation inline with
``# contract: ok RULE001`` and document the site there.
"""
from repro.analysis.core import (AnalysisResult, AstCache, FileContext,
                                 Finding, Project, Rule, default_rules,
                                 run_analysis)
from repro.analysis.determinism import (FreshRngInFaultPathRule,
                                        GlobalRngRule, WallClockRule)
from repro.analysis.events_rules import EventEffectsRule
from repro.analysis.imports import JaxFreeImportRule, LazyFacadeRule
from repro.analysis.telemetry_rules import (NonPerturbationRule,
                                            TelemetryBindOnceRule)

__all__ = [
    "AnalysisResult", "AstCache", "FileContext", "Finding", "Project",
    "Rule", "default_rules", "run_analysis",
    "FreshRngInFaultPathRule", "JaxFreeImportRule", "LazyFacadeRule", "GlobalRngRule",
    "WallClockRule", "NonPerturbationRule", "TelemetryBindOnceRule",
    "EventEffectsRule",
]
