"""Trace one reactive co-simulation run end to end: drift onset ->
retraining burst -> latency recluster, with every control-plane span,
metric, and orchestration decision captured by the telemetry layer.

Runs the combined churn scenario under the budget-capped reactive
policy with a ``Telemetry`` sink attached, then dumps:

  trace_reactive.json    Chrome/Perfetto trace (open in ui.perfetto.dev:
                         rounds / epochs / aggregation windows on the
                         sim-time track, deployment swaps on tid 50,
                         drift / failure instants as markers)
  trace_reactive.jsonl   the same spans as JSONL, one record per line
  audit_reactive.jsonl   the decision audit: one record per
                         orchestration action with trigger, evidence,
                         budget charge, and outcome

and prints the audit table plus the headline registry metrics.  The
run itself is bit-identical to an uninstrumented one — telemetry never
draws RNG or schedules events.

  PYTHONPATH=src python examples/trace_reactive_run.py
  PYTHONPATH=src python examples/trace_reactive_run.py --out results \
      --duration 180
"""
import argparse
import os

from repro.sim.scenarios import SCENARIOS, run_scenario
from repro.telemetry import Telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=".",
                    help="directory for trace/audit artifacts")
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--scenario", default="churn",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--policy", default="budgeted",
                    choices=("reactive", "budgeted"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    tel = Telemetry()
    res = run_scenario(SCENARIOS[args.scenario](), args.policy,
                       seed=args.seed, duration_s=args.duration,
                       telemetry=tel)

    trace = os.path.join(args.out, "trace_reactive.json")
    tel.write_trace(trace)
    tel.write_trace_jsonl(os.path.join(args.out, "trace_reactive.jsonl"))
    tel.audit.write_jsonl(os.path.join(args.out, "audit_reactive.jsonl"))

    print(f"=== {args.scenario} / {args.policy}: p95 {res.p95:.2f} ms, "
          f"{res.rounds_completed} rounds, {res.reclusters} reclusters, "
          f"{res.n_requests} requests ===")
    print(f"\nwrote {trace} ({len(tel.tracer.spans)} spans, "
          f"{len(tel.tracer.instants)} instants) — open in "
          f"ui.perfetto.dev")

    print("\ndecision audit (trigger -> outcome):")
    print(f"  {'t':>7s}  {'action':18s} {'trigger':24s} "
          f"{'outcome':9s} {'cost':>6s}  evidence")
    for rec in tel.audit.records:
        ev = ";".join(f"{k}={v:g}" if isinstance(v, float)
                      else f"{k}={v}" for k, v in rec.evidence.items())
        print(f"  {rec.t:7.1f}  {rec.action:18s} {rec.trigger:24s} "
              f"{rec.outcome:9s} {rec.cost:6.1f}  {ev}")
    counts = tel.audit.counts()
    print("  totals: " + "  ".join(f"{k}={v}" for k, v in counts.items()
                                   if v))

    m = tel.metrics
    print("\nregistry headline:")
    for name in ("requests.total", "training.rounds_completed",
                 "training.epochs_completed", "reconfig.swaps",
                 "reconfig.cost_spent", "alarms.latency",
                 "alarms.accuracy", "events.drift_onset"):
        print(f"  {name:28s} {m.value(name):g}")
    h = m.get("request.latency_ms")
    if h is not None:
        print(f"  request.latency_ms           p50={h.quantile(50):.2f} "
              f"p95={h.quantile(95):.2f} (n={h.count})")


if __name__ == "__main__":
    main()
