"""Cluster topology produced by an HFLOP solution — the bridge between the
placement layer (core), the FL runtime (fl/), the inference router
(routing/) and the TPU mesh mapping (launch/)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.hflop import HFLOPInstance, HFLOPSolution


@dataclass
class ClusterTopology:
    """assign[i] = edge aggregator of device i (-1: not participating)."""
    assign: np.ndarray
    n_devices: int
    n_edges: int
    lam: np.ndarray                     # per-device inference rates
    r: np.ndarray                       # per-edge serving capacities
    l: int = 2                          # local rounds per global round

    @classmethod
    def from_solution(cls, inst: HFLOPInstance,
                      sol: HFLOPSolution) -> "ClusterTopology":
        return cls(assign=np.asarray(sol.assign), n_devices=inst.n,
                   n_edges=inst.m, lam=inst.lam, r=inst.r, l=inst.l)

    @classmethod
    def flat(cls, n_devices: int, lam: Optional[np.ndarray] = None
             ) -> "ClusterTopology":
        """Degenerate topology for centralized FL (no edge aggregators)."""
        return cls(assign=np.full(n_devices, -1), n_devices=n_devices,
                   n_edges=0,
                   lam=lam if lam is not None else np.zeros(n_devices),
                   r=np.zeros(0), l=1)

    @property
    def open_edges(self) -> np.ndarray:
        return np.unique(self.assign[self.assign >= 0])

    def members(self, j: int) -> np.ndarray:
        return np.nonzero(self.assign == j)[0]

    def clusters(self) -> Dict[int, np.ndarray]:
        return {int(j): self.members(int(j)) for j in self.open_edges}

    def cluster_loads(self) -> Dict[int, float]:
        return {int(j): float(np.sum(self.lam[self.members(int(j))]))
                for j in self.open_edges}

    def participant_count(self) -> int:
        return int(np.sum(self.assign >= 0))

    def describe(self) -> str:
        lines = [f"ClusterTopology: {self.participant_count()}/"
                 f"{self.n_devices} devices, "
                 f"{len(self.open_edges)} aggregators, l={self.l}"]
        for j, mem in self.clusters().items():
            load = float(np.sum(self.lam[mem]))
            cap = self.r[j] if self.r.size else float("inf")
            lines.append(f"  edge {j}: {len(mem)} devices, "
                         f"load {load:.2f}/{cap:.2f} req/s")
        return "\n".join(lines)
