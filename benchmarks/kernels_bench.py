"""Per-kernel microbenchmarks (interpret mode on CPU — correctness-path
timing only; TPU wall times come from the roofline model, since interpret
mode executes the kernel body in Python)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import emit, time_us

R = np.random.default_rng(0)


def _a(shape, dtype=jnp.float32, s=1.0):
    return jnp.asarray(R.normal(size=shape) * s, dtype)


def run():
    q = _a((4, 256, 64))
    k = _a((4, 256, 64))
    v = _a((4, 256, 64))
    f = lambda: jax.block_until_ready(
        ops.flash_attention(q, k, v, bq=128, bk=128))
    r = lambda: jax.block_until_ready(ref.flash_attention_ref(q, k, v))
    emit("kernel_flash_attn_256", time_us(f), f"ref_us={time_us(r):.0f}")

    qd = _a((2, 8, 64))
    kd = _a((2, 512, 2, 64))
    vd = _a((2, 512, 2, 64))
    valid = jnp.ones((2, 512), bool)
    f = lambda: jax.block_until_ready(ops.decode_attention(qd, kd, vd, valid))
    r = lambda: jax.block_until_ready(ref.decode_attention_ref(qd, kd, vd,
                                                               valid))
    emit("kernel_decode_attn_512", time_us(f), f"ref_us={time_us(r):.0f}")

    xw = _a((16, 12, 384))
    h0 = _a((16, 128))
    wh = _a((128, 384), s=0.1)
    f = lambda: jax.block_until_ready(ops.gru_seq(xw, h0, wh))
    r = lambda: jax.block_until_ready(ref.gru_seq_ref(xw, h0, wh))
    emit("kernel_gru_seq_16x12", time_us(f), f"ref_us={time_us(r):.0f}")

    st = _a((20, 150_000))
    w = jnp.ones(20)
    f = lambda: jax.block_until_ready(ops.fedavg_reduce(st, w))
    r = lambda: jax.block_until_ready(ref.fedavg_reduce_ref(st, w))
    emit("kernel_fedavg_150k", time_us(f), f"ref_us={time_us(r):.0f}")

    lg = _a((1024, 64))
    f = lambda: jax.block_until_ready(ops.topk_router(lg, 6))
    r = lambda: jax.block_until_ready(ref.topk_router_ref(lg, 6))
    emit("kernel_topk_router_1k", time_us(f), f"ref_us={time_us(r):.0f}")

    x = _a((2, 128, 4, 16))
    dt = jnp.asarray(R.uniform(0.01, 0.2, (2, 128, 4)), jnp.float32)
    A = jnp.asarray(-R.uniform(0.5, 2.0, 4), jnp.float32)
    Bm, Cm = _a((2, 128, 8)), _a((2, 128, 8))
    f = lambda: jax.block_until_ready(
        ops.mamba_chunk_scan(x, dt, A, Bm, Cm, chunk=32))
    emit("kernel_mamba_scan_128", time_us(f), "")


if __name__ == "__main__":
    run()
