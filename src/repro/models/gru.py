"""The paper's own model (§V-B1): stacked GRU for univariate traffic-speed
forecasting on METR-LA-style windows.

2 layers, hidden 128, batch 16, lr 1e-4 in the paper; serialized size
~594 KB — the payload of every HFL model exchange (§V-D cost model).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamBuilder


def init_params(rng, cfg: ModelConfig):
    pb = ParamBuilder(rng, dtype=jnp.float32)
    h = cfg.rnn_hidden
    for i in range(cfg.rnn_layers):
        din = 1 if i == 0 else h
        # fused gates: reset, update, candidate
        pb.param(f"gru/{i}/w_x", (din, 3 * h), (None, "mlp"))
        pb.param(f"gru/{i}/w_h", (h, 3 * h), (None, "mlp"))
        pb.param(f"gru/{i}/b", (3 * h,), ("mlp",), init="zeros")
    pb.param("head/w", (h, 1), ("mlp", None))
    pb.param("head/b", (1,), (None,), init="zeros")
    return pb.build()


def _gru_layer(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    """x (B,T,din) -> (B,T,h)."""
    B, T, _ = x.shape
    h_dim = p["w_h"].shape[0]
    xw = jnp.einsum("btd,de->bte", x, p["w_x"]) + p["b"]

    def step(h, xt):
        hw = h @ p["w_h"]
        xr, xz, xn = jnp.split(xt, 3, axis=-1)
        hr, hz, hn = jnp.split(hw, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        h2 = (1.0 - z) * n + z * h
        return h2, h2

    h0 = jnp.zeros((B, h_dim), x.dtype)
    _, hs = jax.lax.scan(step, h0, xw.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2)


def forward(params, cfg: ModelConfig, windows: jax.Array) -> jax.Array:
    """windows (B,T,1) -> prediction (B,1) of the next value."""
    x = windows
    for i in range(cfg.rnn_layers):
        x = _gru_layer(params["gru"][str(i)], x)
    last = x[:, -1, :]
    return last @ params["head"]["w"] + params["head"]["b"]


def mse_loss(params, cfg: ModelConfig, windows: jax.Array,
             targets: jax.Array) -> jax.Array:
    pred = forward(params, cfg, windows)
    return jnp.mean(jnp.square(pred - targets))


def decode_step(params, cfg: ModelConfig, windows: jax.Array, pos=None,
                cache=None):
    """Inference = one forward over the window (the paper's per-request
    unit of work)."""
    return forward(params, cfg, windows), cache
