"""Metrics registry: counters, gauges, histograms, text annotations.

The registry is the quantitative half of the continuum telemetry layer
(`repro.telemetry`): control-plane handlers count events, the reactive
loop gauges budget state, and the vectorized request plane records
whole windows at a time through the **bulk** histogram/counter APIs —
a handful of vectorized passes per window (an integer-grid bucket LUT
replaces the per-element binary search on the default latency edges)
instead of per-request Python calls, so enabled-mode overhead on the
batched engine stays in the single-digit percent range (gated in
``scripts/ci.sh``).

Instruments are created lazily on first use and identified by dotted
names (``requests.rule.R3-overflow``, ``reconfig.budget_spent``); the
same name always returns the same instrument.  Exports:
:meth:`MetricsRegistry.snapshot` (plain JSON-able dict) and
:meth:`MetricsRegistry.to_prometheus` (text exposition format, dots
sanitized to underscores).

Determinism contract (shared with the whole telemetry layer): nothing
here draws randomness, schedules events, or mutates anything outside
its own arrays — recording is observation only.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Union

import numpy as np

#: default latency-histogram bucket upper bounds (ms) — geometric, so
#: one array covers on-device fast paths and cloud round trips alike.
DEFAULT_LATENCY_EDGES_MS = (
    1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0,
    1000.0, 2000.0, 5000.0)


class Counter:
    """Monotonically increasing scalar (float amounts allowed — budget
    spend is metered in edge-compute-seconds)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)


class Text:
    """String annotation (non-numeric benchmark fields, build info)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = ""

    def set(self, value: str) -> None:
        self.value = str(value)


class Histogram:
    """Fixed-bucket histogram with bulk columnar recording.

    ``edges`` are ascending bucket *upper* bounds; values above the
    last edge land in the overflow (+Inf) bucket.  ``observe_array``
    merges a whole column in one ``searchsorted`` + ``bincount`` pass
    and is exactly equivalent to scalar ``observe`` per element
    (bucket counts are integer arithmetic; only the float ``sum`` can
    differ in the last bits from the different add order — asserted as
    a hypothesis property in ``tests/test_properties.py``)."""

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max",
                 "_lut", "_lut_top", "_lut_max", "_lut_starts")

    #: LUT fast-path cap: integer edge grids up to this top edge
    #: precompute a value->bucket table (8 bytes/entry).
    _LUT_MAX_EDGE = 1_000_000

    def __init__(self, name: str, edges=DEFAULT_LATENCY_EDGES_MS):
        self.name = name
        self.edges = np.asarray(edges, dtype=np.float64)
        if self.edges.ndim != 1 or self.edges.size == 0 or np.any(
                np.diff(self.edges) <= 0):
            raise ValueError(f"histogram {name!r}: edges must be a "
                             f"non-empty ascending 1-D sequence")
        self.counts = np.zeros(self.edges.size + 1, dtype=np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        # bulk fast path: for non-negative *integer* edges (the default
        # latency grid), searchsorted(edges, v, "left") equals
        # lut[ceil(v)] — an integer edge e satisfies e >= v exactly when
        # e >= ceil(v).  Rather than gathering through the LUT we
        # bincount the integerized values directly (ceil + an int32
        # cast, both SIMD) and fold the fine-grained counts into the
        # buckets with one reduceat over the LUT's step starts — ~6x
        # cheaper than the per-element binary search on request-plane
        # windows.
        e0 = float(self.edges[0])
        top = float(self.edges[-1])
        if (e0 >= 0.0 and top <= self._LUT_MAX_EDGE
                and np.all(self.edges == np.floor(self.edges))):
            grid = np.arange(int(top) + 2, dtype=np.float64)
            self._lut = np.searchsorted(self.edges, grid,
                                        side="left").astype(np.int64)
            self._lut_top = top
            self._lut_max = float(int(top) + 1)   # maps to overflow
            # first ceil-value belonging to each bucket; integer edges
            # ascend by >= 1, so every bucket 0..edges.size appears
            # exactly once and len == counts.size.
            self._lut_starts = np.searchsorted(
                self._lut, np.arange(self.counts.size), side="left")
        else:
            self._lut = None
            self._lut_top = 0.0
            self._lut_max = 0.0
            self._lut_starts = None

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[int(np.searchsorted(self.edges, v, side="left"))] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def observe_array(self, values: np.ndarray) -> None:
        v = np.asarray(values, dtype=np.float64).ravel()
        if v.size == 0:
            return
        mn = float(np.min(v))
        mx = float(np.max(v))
        if self._lut is not None:
            if mn >= 0.0 and mx <= self._lut_top:
                k = np.ceil(v).astype(np.int32)
            else:
                k = np.ceil(np.minimum(np.maximum(v, 0.0),
                                       self._lut_max)).astype(np.int32)
            ck = np.bincount(k, minlength=self._lut.size)
            self.counts += np.add.reduceat(ck, self._lut_starts)
        else:
            idx = np.searchsorted(self.edges, v, side="left")
            self.counts += np.bincount(idx, minlength=self.counts.size)
        self.count += int(v.size)
        self.sum += float(np.sum(v))
        self.min = min(self.min, mn)
        self.max = max(self.max, mx)

    def quantile(self, p: float) -> float:
        """Approximate p-th percentile (p in [0, 100]) by linear
        interpolation inside the containing bucket; NaN when empty."""
        if self.count == 0:
            return math.nan
        target = self.count * p / 100.0
        cum = np.cumsum(self.counts)
        k = int(np.searchsorted(cum, target, side="left"))
        lo = 0.0 if k == 0 else float(self.edges[k - 1])
        hi = (float(self.edges[k]) if k < self.edges.size
              else max(self.max, lo))
        within = self.counts[k]
        frac = ((target - (cum[k - 1] if k > 0 else 0)) / within
                if within > 0 else 0.0)
        return lo + min(max(frac, 0.0), 1.0) * (hi - lo)

    def snapshot(self) -> Dict[str, object]:
        buckets = {f"le_{e:g}": int(c)
                   for e, c in zip(self.edges, self.counts[:-1])}
        buckets["le_inf"] = int(self.counts[-1])
        return {"count": int(self.count), "sum": float(self.sum),
                "min": (float(self.min) if self.count else math.nan),
                "max": (float(self.max) if self.count else math.nan),
                "buckets": buckets}


Instrument = Union[Counter, Gauge, Histogram, Text]


def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if ch.isalnum() or ch == "_" else "_")
    s = "".join(out)
    return ("_" + s) if s and s[0].isdigit() else s


def _prom_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return f"{v:g}"


class MetricsRegistry:
    """Lazily created, name-keyed instruments.  Asking for an existing
    name with a different type is a bug and raises."""

    def __init__(self) -> None:
        self._instruments: Dict[str, Instrument] = {}

    def _get(self, name: str, cls, *args) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, *args)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(inst).__name__}, not {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def text(self, name: str) -> Text:
        return self._get(name, Text)

    def histogram(self, name: str,
                  edges=DEFAULT_LATENCY_EDGES_MS) -> Histogram:
        return self._get(name, Histogram, edges)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def value(self, name: str, default: float = 0.0) -> float:
        """Scalar value of a counter/gauge, ``default`` when absent —
        the convenience read for tests and benchmark reporters."""
        inst = self._instruments.get(name)
        if inst is None or isinstance(inst, Histogram):
            return default
        return inst.value

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "texts": {}, "histograms": {}}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Counter):
                out["counters"][name] = float(inst.value)
            elif isinstance(inst, Gauge):
                out["gauges"][name] = float(inst.value)
            elif isinstance(inst, Text):
                out["texts"][name] = inst.value
            else:
                out["histograms"][name] = inst.snapshot()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (``repro_`` prefix, dots
        sanitized to underscores, cumulative histogram buckets)."""
        lines: List[str] = []
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            pname = "repro_" + _prom_name(name)
            if isinstance(inst, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_prom_value(inst.value)}")
            elif isinstance(inst, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {_prom_value(inst.value)}")
            elif isinstance(inst, Text):
                lines.append(f"# TYPE {pname}_info gauge")
                lines.append(f'{pname}_info{{value="{inst.value}"}} 1')
            else:
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for e, c in zip(inst.edges, inst.counts[:-1]):
                    cum += int(c)
                    lines.append(f'{pname}_bucket{{le="{e:g}"}} {cum}')
                cum += int(inst.counts[-1])
                lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{pname}_sum {_prom_value(inst.sum)}")
                lines.append(f"{pname}_count {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")
