"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; interpret
mode executes the kernel bodies in Python for correctness validation).
On real TPU hardware pass ``interpret=False`` — same BlockSpecs, same
code."""
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fedavg_reduce import fedavg_reduce
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gru_cell import gru_seq
from repro.kernels.mamba_scan import mamba_chunk_scan
from repro.kernels.paged_decode_attention import (
    paged_decode_attention, paged_mla_decode_attention)
from repro.kernels.topk_router import topk_router

__all__ = ["decode_attention", "fedavg_reduce", "flash_attention",
           "gru_seq", "mamba_chunk_scan", "paged_decode_attention",
           "paged_mla_decode_attention", "topk_router"]
