"""The closed reactive loop, end to end (paper §III + co-sim subsystem):

  1. train the paper's GRU with continual HFL on synthetic traffic data
  2. inject concept drift (``data.traffic.inject_drift``) — the trained
     model's validation MSE rises on the drifted regime
  3. co-simulate serving + training on one event timeline: the drift
     fires the accuracy alarm, the controller launches a retraining
     burst, the burst's compute steals serving capacity (interference
     spike), the latency monitor catches the spike, and HFLOP
     re-clustering recovers most of it

  PYTHONPATH=src python examples/reactive_orchestration.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.topology import ClusterTopology
from repro.data import generate, inject_drift, select_fl_sensors
from repro.data.traffic import STEPS_PER_DAY, windows_for_sensor
from repro.fl import ContinualHFL, HFLRunConfig
from repro.fl.client import ClientBatch, eval_clients
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.sim import (AccuracyModel, CoSim, CoSimConfig, ReactiveLoop,
                       ReactivePolicy)

import jax.numpy as jnp


def trained_mse_before_after_drift(seed=0):
    """Train briefly pre-drift, then measure val MSE on clean vs
    drifted data — the real numbers that parameterize the co-sim's
    accuracy telemetry."""
    cfg = get_config("gru-traffic").reduced()
    ds = generate(num_days=40, n_sensors=32, seed=seed)
    sensors = select_fl_sensors(ds, per_cluster=3, seed=seed)
    n = len(sensors)
    topo = ClusterTopology(assign=np.arange(n) % 4, n_devices=n, n_edges=4,
                           lam=np.ones(n), r=np.full(4, 10.0), l=2)
    run = HFLRunConfig(rounds=2, local_epochs=2, max_batches=10,
                       train_days=14, val_days=3, seed=seed)
    hfl = ContinualHFL(cfg, ds, sensors, topo, run, mode="hier")
    res = hfl.run_rounds(progress=False)
    base_mse = float(res.mse[-1].mean())

    # drift sets in right at the validation window
    drift_start = 14 * STEPS_PER_DAY
    drifted = inject_drift(ds, drift_start, severity=0.35)
    Xs, ys = [], []
    for s in sensors:
        X, y = windows_for_sensor(drifted, int(s), drift_start,
                                  drift_start + 3 * STEPS_PER_DAY,
                                  run.history)
        Xs.append(X[:256])
        ys.append(y[:256])
    val = ClientBatch(X=jnp.asarray(np.stack(Xs)),
                      y=jnp.asarray(np.stack(ys)))
    drift_mse = float(np.mean(eval_clients(hfl.params, val, cfg=cfg)))
    return base_mse, drift_mse


def main():
    print("=== 1. continual HFL training + drift impact on accuracy ===")
    base_mse, drift_mse = trained_mse_before_after_drift()
    print(f"val MSE clean {base_mse:.4f} -> drifted {drift_mse:.4f} "
          f"({drift_mse / base_mse:.1f}x)")

    print("\n=== 2. co-simulation: drift -> alarm -> burst -> recovery ===")
    rng = np.random.default_rng(0)
    n, m = 20, 4
    loc = np.repeat(np.arange(m), n // m)
    lam = rng.uniform(2.0, 4.0, n)
    lam[loc == 0] *= 3.0                     # hot zone
    r = np.full(m, lam.sum() / m * 1.35)
    topo = ClusterTopology(assign=loc, n_devices=n, n_edges=m,
                           lam=lam, r=r, l=2)

    ctl = LearningController(
        inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=2,
        accuracy_threshold=(base_mse + drift_mse) / 2)
    ctl.deployment = Deployment.from_topology(topo)  # static initial deploy
    loop = ReactiveLoop(
        ctl,
        accuracy=AccuracyModel(base_mse=base_mse, drift_mse=drift_mse,
                               ramp_s=40.0, recovery_per_round=0.5),
        policy=ReactivePolicy(p95_threshold_ms=20.0, burst_rounds=6))

    cfg = CoSimConfig(duration_s=300.0, seed=0)
    cosim = CoSim(topo, cfg, reactive=loop)   # no background training
    cosim.schedule_drift(t=60.0)
    res = cosim.run()

    print(f"requests served: {len(res.log.t)}, "
          f"training rounds completed: {res.rounds_completed}, "
          f"reclusterings: {ctl.recluster_count}")
    print("\nreactive-loop decisions:")
    for t, action in res.actions:
        print(f"  t={t:6.1f}s  {action}")

    print("\np95 latency timeline (20 s windows):")
    for t0, p95 in res.log.windowed_percentile(20.0, 95):
        bar = "" if np.isnan(p95) else "#" * int(min(p95, 120) / 2)
        print(f"  {t0:5.0f}s  {p95:7.2f} ms  {bar}")

    print("\nmodeled val MSE timeline (every 30 s):")
    for t, mse in res.mse_series[::15]:
        print(f"  {t:5.0f}s  {mse:.4f}"
              + ("  <- above alarm threshold"
                 if mse > ctl.accuracy_threshold else ""))

    pre = res.log.latency_ms[res.log.t < 60.0]
    win = res.log.windowed_percentile(20.0, 95)
    filled = win[~np.isnan(win[:, 1])]           # empty windows are NaN rows
    print(f"\npre-drift p95 {np.percentile(pre, 95):.2f} ms; "
          f"peak window p95 {filled[:, 1].max():.2f} ms; "
          f"final window p95 {filled[-1, 1]:.2f} ms")


if __name__ == "__main__":
    main()
