"""zamba2-style hybrid assembly: a stack of Mamba2 blocks with ONE shared
transformer block (attention + MLP, single parameter set) applied after
every ``shared_attn_every`` Mamba2 layers [arXiv:2411.15242].

Simplifications vs the released checkpoint, recorded in DESIGN.md:
the per-invocation LoRA adapters on the shared block and the
concat-with-embedding input trick are omitted; the shared block consumes
the running residual stream directly.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import ParamBuilder, stack_axes, stack_params, to_dtype
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embedding, init_mlp, init_norm,
                                 logits_from_hidden)
from repro.models.rope import rope_frequencies
from repro.models.ssm import (SSMState, init_mamba2, init_ssm_state,
                              mamba2_decode, mamba2_forward)


def _segments(cfg: ModelConfig):
    """Split layer indices into runs of ``shared_attn_every``; the shared
    attention block runs after each *complete* run."""
    k = cfg.shared_attn_every
    L = cfg.num_layers
    segs, start = [], 0
    while start < L:
        end = min(start + k, L)
        segs.append((start, end, end - start == k))
        start = end
    return segs


def init_params(rng, cfg: ModelConfig):
    pb = ParamBuilder(rng, dtype=to_dtype(cfg.param_dtype))
    init_embedding(pb, cfg)
    per = []
    for i in range(cfg.num_layers):
        lb = ParamBuilder(jax.random.fold_in(rng, 3000 + i),
                          dtype=to_dtype(cfg.param_dtype))
        init_norm(lb, "ln", cfg.d_model, cfg.norm)
        init_mamba2(lb, "mamba", cfg.d_model, cfg.ssm)
        per.append(lb.build())
    pb.subtree("mamba_layers", stack_params([p for p, _ in per]),
               stack_axes(per[0][1]))
    # the single shared attention+MLP block
    sb = ParamBuilder(jax.random.fold_in(rng, 9999),
                      dtype=to_dtype(cfg.param_dtype))
    init_norm(sb, "ln1", cfg.d_model, cfg.norm)
    attn.init_gqa(sb, "attn", cfg.d_model, cfg.attention)
    init_norm(sb, "ln2", cfg.d_model, cfg.norm)
    init_mlp(sb, "mlp", cfg.d_model, cfg.d_ff, cfg.act)
    sp, sa = sb.build()
    pb.subtree("shared", sp, sa)
    init_norm(pb, "final_norm", cfg.d_model, cfg.norm)
    return pb.build()


def _mamba_layer(cfg, p, x):
    h = apply_norm(p["ln"], x, cfg.norm, cfg.norm_eps)
    return x + mamba2_forward(p["mamba"], cfg.d_model, cfg.ssm, h)


def _shared_block(cfg, p, x, positions, inv_freq, window):
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    x = x + attn.gqa_forward(p["attn"], cfg.attention, h, positions,
                             inv_freq, window=window)
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, cfg.act)


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            extra_embeds=None, remat: str = "layer"
            ) -> Tuple[jax.Array, jax.Array]:
    x = embed_tokens(params, cfg, tokens)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    a = cfg.attention
    inv_freq = rope_frequencies(a.head_dim, a.rope_theta, a.rope_fraction)
    window = a.window if a.window else None

    def body(xc, p):
        return _mamba_layer(cfg, p, xc), None

    body_fn = jax.checkpoint(body) if remat != "none" else body
    for (s, e, complete) in _segments(cfg):
        seg = jax.tree.map(lambda t: t[s:e], params["mamba_layers"])
        x, _ = jax.lax.scan(body_fn, x, seg)
        if complete:
            x = _shared_block(cfg, params["shared"], x, positions,
                              inv_freq, window)
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    if dtype is None:
        from repro.models.common import to_dtype
        dtype = to_dtype(cfg.dtype)
    a = cfg.attention
    cap = min(max_len, a.window) if a.window else max_len
    states = [init_ssm_state(batch, cfg.d_model, cfg.ssm)
              for _ in range(cfg.num_layers)]
    shared_caches = {
        str(k): attn.init_kv_cache(batch, cap, a.num_kv_heads, a.head_dim,
                                   dtype)
        for k, (s, e, complete) in enumerate(_segments(cfg)) if complete}
    return {
        "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *states),
        "shared": shared_caches,
    }


def decode_step(params, cfg: ModelConfig, tokens: jax.Array, pos: jax.Array,
                cache, extra_embeds=None):
    x = embed_tokens(params, cfg, tokens)
    a = cfg.attention
    inv_freq = rope_frequencies(a.head_dim, a.rope_theta, a.rope_fraction)
    window = a.window if a.window else None

    def body(xc, xs):
        p, st = xs
        h = apply_norm(p["ln"], xc, cfg.norm, cfg.norm_eps)
        y, st2 = mamba2_decode(p["mamba"], cfg.d_model, cfg.ssm, h, st)
        return xc + y, st2

    new_shared = {}
    new_states = []
    for k, (s, e, complete) in enumerate(_segments(cfg)):
        seg_p = jax.tree.map(lambda t: t[s:e], params["mamba_layers"])
        seg_c = jax.tree.map(lambda t: t[s:e], cache["mamba"])
        x, st_out = jax.lax.scan(body, x, (seg_p, seg_c))
        new_states.append(st_out)
        if complete:
            sp = params["shared"]
            h = apply_norm(sp["ln1"], x, cfg.norm, cfg.norm_eps)
            y, c2 = attn.gqa_decode(sp["attn"], a, h, pos,
                                    cache["shared"][str(k)], inv_freq,
                                    window=window)
            x = x + y
            h = apply_norm(sp["ln2"], x, cfg.norm, cfg.norm_eps)
            x = x + apply_mlp(sp["mlp"], h, cfg.act)
            new_shared[str(k)] = c2
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    new_mamba = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *new_states)
    return logits_from_hidden(params, cfg, x), {"mamba": new_mamba,
                                                "shared": new_shared}
