"""Mixture-of-Experts layer: shared + routed experts, top-k routing.

Dispatch is *sort-based with capacity* (megablocks-style adapted to XLA):
token->expert assignments are sorted by expert id, each expert processes a
fixed-capacity contiguous slice via ``lax.dynamic_slice`` inside a scan.
This avoids the O(tokens x experts x capacity) one-hot dispatch tensors of
GShard-style einsum dispatch while remaining a static-shape program, and
maps onto the TPU as E sequential (capacity, d) x (d, d_ff) matmuls whose
d_ff dimension is sharded over the "model" mesh axis.

Tokens beyond an expert's capacity are dropped (contribute 0); the
load-balance auxiliary loss pushes the router toward uniform load.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import ParamBuilder, shard
from repro.models.layers import apply_mlp, init_mlp


def init_moe(pb: ParamBuilder, path: str, d_model: int, moe: MoEConfig,
             act: str) -> None:
    E = moe.num_experts
    pb.param(f"{path}/router", (d_model, E), ("embed", None),
             dtype=jnp.float32)
    if act == "silu":
        pb.param(f"{path}/wi_gate", (E, d_model, moe.d_expert),
                 ("expert", "embed", "mlp"))
        pb.param(f"{path}/wi_up", (E, d_model, moe.d_expert),
                 ("expert", "embed", "mlp"))
    else:
        pb.param(f"{path}/wi", (E, d_model, moe.d_expert),
                 ("expert", "embed", "mlp"))
    pb.param(f"{path}/wo", (E, moe.d_expert, d_model),
             ("expert", "mlp", "embed"))
    shared = moe.d_shared if moe.d_shared else moe.num_shared * moe.d_expert
    if shared:
        init_mlp(pb, f"{path}/shared", d_model, shared, act)


def _capacity(num_tokens: int, moe: MoEConfig) -> int:
    c = math.ceil(num_tokens * moe.top_k / moe.num_experts
                  * moe.capacity_factor)
    c = max(8, -(-c // 8) * 8)  # round up to 8
    return min(c, num_tokens * moe.top_k)  # never above total assignments


def apply_moe(p: Dict[str, Any], moe: MoEConfig, x: jax.Array, act: str,
              ) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    t = B * S
    E, K = moe.num_experts, moe.top_k
    xf = x.reshape(t, d)

    # --- routing (fp32) ---
    logits = (xf.astype(jnp.float32) @ p["router"])          # (t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                     # (t, K)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (t * K)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P) * moe.aux_loss_coef

    # --- sort token-slot assignments by expert ---
    flat_e = topi.reshape(t * K)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), K)
    flat_w = topw.reshape(t * K)
    order = jnp.argsort(flat_e)
    sort_e = flat_e[order]
    sort_tok = flat_tok[order]
    sort_w = flat_w[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    C = _capacity(t, moe)
    # pad so dynamic_slice never clamps its start (clamping would shift
    # the slice away from the idx bookkeeping below)
    gathered = jnp.concatenate(
        [xf[sort_tok], jnp.zeros((C, d), xf.dtype)], axis=0)  # (t*K + C, d)
    use_gate = "wi_gate" in p

    def one_expert(out_flat, inputs):
        if use_gate:
            w1g, w1u, w2, st, cnt = inputs
        else:
            w1, w2, st, cnt = inputs
        xs = jax.lax.dynamic_slice(gathered, (st, jnp.int32(0)), (C, d))
        idx = st + jnp.arange(C, dtype=jnp.int32)
        valid = (jnp.arange(C) < cnt) & (idx < t * K)
        idx = jnp.minimum(idx, t * K - 1)
        toks = jnp.where(valid, sort_tok[idx], t)            # t = trash row
        ws = jnp.where(valid, sort_w[idx], 0.0)
        if use_gate:
            g = xs @ w1g
            u = xs @ w1u
            h = jax.nn.silu(g.astype(jnp.float32)).astype(xs.dtype) * u
        else:
            h = jax.nn.gelu((xs @ w1).astype(jnp.float32)).astype(xs.dtype)
        y = (h @ w2) * ws[:, None].astype(xs.dtype)
        return out_flat.at[toks].add(y), None

    out_flat = jnp.zeros((t + 1, d), x.dtype)                # +1 trash row
    if use_gate:
        xs_stack = (p["wi_gate"], p["wi_up"], p["wo"], starts, counts)
    else:
        xs_stack = (p["wi"], p["wo"], starts, counts)
    out_flat, _ = jax.lax.scan(one_expert, out_flat, xs_stack)
    out = out_flat[:t].reshape(B, S, d)
    out = shard(out, "batch", "seq", "embed_act")

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, act)
    return out, aux
