"""The in-house LP/MILP solver against hand-checkable problems."""
import numpy as np
import pytest

from repro.core.milp import solve_lp, solve_milp


def test_lp_basic():
    # max x+y st x<=2, y<=3  -> min -(x+y) = -5
    res = solve_lp(np.array([-1.0, -1.0]),
                   np.array([[1.0, 0.0], [0.0, 1.0]]),
                   np.array([2.0, 3.0]))
    assert res.status == "optimal"
    assert res.obj == pytest.approx(-5.0)


def test_lp_negative_rhs_phase1():
    # min x st x >= 2 (i.e. -x <= -2), x <= 5
    res = solve_lp(np.array([1.0]), np.array([[-1.0]]), np.array([-2.0]),
                   ub=np.array([5.0]))
    assert res.status == "optimal"
    assert res.obj == pytest.approx(2.0)


def test_lp_infeasible():
    # x >= 3 and x <= 1
    res = solve_lp(np.array([1.0]), np.array([[-1.0], [1.0]]),
                   np.array([-3.0, 1.0]))
    assert res.status == "infeasible"


def test_lp_random_feasibility():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n, m = 8, 12
        A = rng.normal(size=(m, n))
        b = np.abs(rng.normal(size=m)) + 0.5
        c = rng.normal(size=n)
        res = solve_lp(c, A, b, ub=np.ones(n))
        assert res.status == "optimal"
        x = res.x
        assert np.all(A @ x <= b + 1e-7)
        assert np.all(x >= -1e-9) and np.all(x <= 1 + 1e-9)


def test_milp_knapsack():
    # max 10a+6b+4c st 5a+4b+3c <= 8, binary -> optimal {a,c}=14? check:
    # {a,b}: w=9 infeasible; {a,c}: w=8 val 14; {b,c}: w=7 val 10 -> 14
    c = -np.array([10.0, 6.0, 4.0])
    A = np.array([[5.0, 4.0, 3.0]])
    b = np.array([8.0])
    res = solve_milp(c, A, b)
    assert res.status == "optimal"
    assert -res.obj == pytest.approx(14.0)
    assert np.allclose(res.x, [1, 0, 1])


def test_milp_equality_via_pairs():
    # min x1+2x2 st x1+x2 = 1 (as <= and >=), binary
    c = np.array([1.0, 2.0])
    A = np.array([[1.0, 1.0], [-1.0, -1.0]])
    b = np.array([1.0, -1.0])
    res = solve_milp(c, A, b)
    assert res.status == "optimal"
    assert res.obj == pytest.approx(1.0)
    assert np.allclose(res.x, [1, 0])
