"""Paper Fig. 7: inference response times under continual training for
(a) flat/centralized FL, (b) location-based hierarchical clustering,
(c) HFLOP (inference-load-aware) clustering.

Scenario: 20 devices in 4 geographic clusters, but request load is
*skewed by location* (one hot zone) — exactly the case where
location-only clustering overloads one edge and spills to the cloud
while HFLOP balances by capacity.  Paper reference values:
flat 79.07+-15.94 ms, hier 17.72+-24.26 ms, HFLOP 9.89+-4.63 ms."""
from __future__ import annotations

import numpy as np

from repro.core import HFLOPInstance, solve_heuristic
from repro.routing import SimConfig, compare_methods
from benchmarks.common import emit


def build_scenario(seed=0, n=20, m=4, hot_factor=3.0, cap_slack=1.35):
    # one definition of the hot-zone continuum, shared with the
    # scenario engine (identical draws)
    from repro.sim.scenarios import hot_zone_topology
    _, loc, lam, r = hot_zone_topology(seed=seed, n=n, m=m,
                                       hot=hot_factor, slack=cap_slack)
    c_d = np.ones((n, m))
    c_d[np.arange(n), loc] = 0.0
    inst = HFLOPInstance(c_d, np.ones(m), lam, r, l=2)
    return inst, loc


def run(duration_s=240.0, seed=0):
    inst, loc = build_scenario(seed)
    hflop = solve_heuristic(inst)
    cfg = SimConfig(duration_s=duration_s, seed=seed)
    logs = compare_methods(inst, {"flat": None, "hier_location": loc,
                                  "hflop": hflop.assign}, cfg)
    out = {}
    for name, log in logs.items():
        mean, std = log.mean_latency(), log.std_latency()
        cloud = log.tier_fractions()["cloud"]
        pct = log.latency_percentiles()
        emit(f"fig7_{name}", mean * 1000,
             f"mean_ms={mean:.2f};std_ms={std:.2f};cloud_frac={cloud:.3f};"
             f"p50={pct['p50']:.2f};p95={pct['p95']:.2f};"
             f"p99={pct['p99']:.2f}")
        out[name] = (mean, std, cloud)
    return out


if __name__ == "__main__":
    r = run()
    print("\npaper reference: flat 79.07+-15.94 | hier 17.72+-24.26 | "
          "hflop 9.89+-4.63 (ms)")
