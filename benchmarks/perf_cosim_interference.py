"""Training–inference interference benchmark (co-simulation subsystem).

Runs the Fig. 7 hot-zone scenario three ways on the unified event core:

  serving-only      no training rounds (the isolated-inference baseline)
  training-on       continual HFL rounds share every node's compute:
                    devices mid-epoch offload (R1), edges aggregate with
                    stretched service times, overflow spills to the cloud
  training+reactive same workload, but the reactive loop watches p95
                    telemetry and drives ``on_capacity_change`` ->
                    HFLOP re-clusters around the training-degraded
                    bottleneck (with a modeled migration cost)

Reports p50/p95/p99 per mode and the fraction of the interference-
induced p95 gap the reactive loop recovers.  Deterministic under a
fixed seed.  Optional ``--measure`` calibrates service times from real
``ReplicaPool`` engine timings instead of the constant model.
"""
from __future__ import annotations

import argparse
from typing import Dict

from repro.core.topology import ClusterTopology
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.sim import CoSim, CoSimConfig, ReactiveLoop, ReactivePolicy

from benchmarks.common import emit
from benchmarks.fig7_inference_latency import build_scenario


def run(duration_s: float = 240.0, seed: int = 0,
        p95_threshold_ms: float = 20.0, measure: bool = False,
        ) -> Dict[str, Dict[str, float]]:
    inst, loc = build_scenario(seed)
    topo = ClusterTopology(assign=loc, n_devices=inst.n, n_edges=inst.m,
                           lam=inst.lam, r=inst.r, l=inst.l)
    cfg = CoSimConfig(duration_s=duration_s, seed=seed)
    if measure:
        from repro.routing import LatencyModel
        from repro.serving import ReplicaPool
        cfg.latency = LatencyModel.from_measurements(
            ReplicaPool().measure())
    # continual training: back-to-back rounds for the whole horizon
    # (the same timeline the scenario engine uses)
    from repro.sim.scenarios import continual_training
    sched = continual_training(duration_s, l=topo.l)

    results = {}
    results["serving_only"] = CoSim(topo, cfg).run()
    results["training_on"] = CoSim(topo, cfg, schedule=sched).run()

    inv = Inventory.from_arrays(inst.lam, inst.r, lan_edge=loc)
    ctl = LearningController(inventory=inv, l=topo.l)
    ctl.deployment = Deployment.from_topology(topo)  # static initial deploy
    loop = ReactiveLoop(ctl, policy=ReactivePolicy(
        p95_threshold_ms=p95_threshold_ms))
    results["training_reactive"] = CoSim(topo, cfg, schedule=sched,
                                         reactive=loop).run()

    out = {}
    for name, res in results.items():
        pct = res.log.latency_percentiles()
        cloud = res.log.tier_fractions()["cloud"]
        emit(f"cosim_{name}", pct["p95"] * 1000,
             f"p50={pct['p50']:.2f};p95={pct['p95']:.2f};"
             f"p99={pct['p99']:.2f};cloud_frac={cloud:.3f};"
             f"rounds={res.rounds_completed}")
        out[name] = pct
    gap = out["training_on"]["p95"] - out["serving_only"]["p95"]
    rec = out["training_on"]["p95"] - out["training_reactive"]["p95"]
    frac = rec / gap if gap > 0 else 0.0
    emit("cosim_p95_gap_recovered", frac * 1e6,
         f"recovered_frac={frac:.3f};gap_ms={gap:.2f};"
         f"reclusters={ctl.recluster_count}")
    out["recovered_frac"] = frac
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=240.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI smoke (short horizon)")
    ap.add_argument("--measure", action="store_true",
                    help="calibrate service times from real engines")
    args = ap.parse_args()
    duration = 60.0 if args.smoke else args.duration
    out = run(duration_s=duration, seed=args.seed, measure=args.measure)
    print(f"\np95 serving-only {out['serving_only']['p95']:.2f} ms | "
          f"training-on {out['training_on']['p95']:.2f} ms | "
          f"+reactive {out['training_reactive']['p95']:.2f} ms "
          f"(recovered {out['recovered_frac']:.0%} of the gap)")


if __name__ == "__main__":
    main()
