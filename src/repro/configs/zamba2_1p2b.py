"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig, SSMConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        source="arXiv:2411.15242",
        num_layers=38,
        d_model=2048,
        d_ff=8192,
        vocab_size=32_000,
        attention=AttentionConfig(
            kind="full",           # the shared block is full attention...
            num_heads=32,
            num_kv_heads=32,
            head_dim=64,
            window=4096,           # ...but long_500k mode uses this window
            rope_theta=10_000.0,
        ),
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                      chunk=128, ngroups=1),
        shared_attn_every=6,       # shared transformer block applied every 6 mamba layers
        tie_embeddings=True,
    ),
    run=RunConfig(microbatches=1, remat="layer", max_cache_len=524_288),
)
