"""ShapeDtypeStruct input specs for every (architecture x input shape)
combination — weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import ModelApi

SDS = jax.ShapeDtypeStruct


def model_batch_specs(cfg: ArchConfig, shape: InputShape,
                      with_labels: bool = True) -> Dict[str, SDS]:
    """Batch specs for train (with labels) / prefill (without)."""
    m = cfg.model
    B, S = shape.global_batch, shape.seq_len
    if m.family == "rnn":
        return {"windows": SDS((B, 12, 1), jnp.float32),
                "targets": SDS((B, 1), jnp.float32)}
    out: Dict[str, SDS] = {}
    if m.family == "vlm":
        P = m.frontend.num_positions
        out["patches"] = SDS((B, P, m.frontend.embed_dim), jnp.bfloat16)
        out["tokens"] = SDS((B, S - P), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, S - P), jnp.int32)
    elif m.family == "audio":
        F = m.frontend.num_positions
        out["frames"] = SDS((B, F, m.frontend.embed_dim), jnp.bfloat16)
        out["tokens"] = SDS((B, S), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, S), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
        if with_labels:
            out["labels"] = SDS((B, S), jnp.int32)
    return out


def param_specs_and_axes(api: ModelApi) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct param tree, logical-axes tree) without
    allocating: the axes tree is captured as a tracing side effect."""
    holder = {}

    def init_only_params(rng):
        p, ax = api.init_params(rng)
        holder["axes"] = ax
        return p

    p_struct = jax.eval_shape(init_only_params, jax.random.key(0))
    return p_struct, holder["axes"]


def cache_specs(api: ModelApi, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: api.init_cache(batch, max_len))


def decode_token_specs(cfg: ArchConfig, shape: InputShape
                       ) -> Tuple[SDS, SDS]:
    B = shape.global_batch
    if cfg.model.family == "rnn":
        return SDS((B, 12, 1), jnp.float32), SDS((), jnp.int32)
    return SDS((B, 1), jnp.int32), SDS((), jnp.int32)
