"""DET002 suppressed fixture: sanctioned raw read."""
import time


def stamp():
    # contract: ok DET002
    return time.perf_counter()
