"""Tiered serving subsystem: prefill parity, continuous-batching
scheduler slot reuse/eviction, ReplicaPool per-tier dispatch, and the
calibrated latency bridge into the routing simulator."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.routing import CalibratedLatencyModel, LatencyModel, SimConfig, \
    simulate
from repro.serving import (ContinuousBatchingScheduler, EngineMeasurement,
                           ReplicaPool, Request, ServeEngine, TierSpec,
                           batched_arrivals, bucket_len, lm_tiers,
                           poisson_requests)
from repro.serving.workload import RequestEvent


def _fp32(cfg):
    model = dataclasses.replace(cfg.model, dtype="float32",
                                param_dtype="float32")
    if model.moe is not None:
        model = dataclasses.replace(model, moe=dataclasses.replace(
            model.moe, capacity_factor=float(model.moe.num_experts)))
    return dataclasses.replace(cfg, model=model)


def _api_params(arch, fp32=True, **model_overrides):
    cfg = get_config(arch).reduced()
    if fp32:
        cfg = _fp32(cfg)
    if model_overrides:
        cfg = dataclasses.replace(
            cfg, model=dataclasses.replace(cfg.model, **model_overrides))
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    return cfg, api, params


# ---------------------------------------------------------------------------
# prefill parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["stablelm-1.6b", "deepseek-v2-lite-16b"])
def test_prefill_matches_sequential_decode(arch):
    """One-shot prefill must reproduce (a) the full forward logits and
    (b) the cache state S sequential decode steps would have built."""
    cfg, api, params = _api_params(arch)
    rng = np.random.default_rng(0)
    B, S, extra = 2, 12, 4
    tokens = jnp.asarray(
        rng.integers(0, cfg.model.vocab_size, (B, S + extra)), jnp.int32)
    full_logits, _ = api.forward(params, {"tokens": tokens[:, :S]})
    pf_logits, pf_cache = api.prefill(params, tokens[:, :S],
                                      api.init_cache(B, S + extra))
    np.testing.assert_allclose(np.asarray(pf_logits),
                               np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)
    # continuation from the prefilled cache == fully sequential decode
    cache_seq = api.init_cache(B, S + extra)
    for t in range(S + extra):
        seq_logits, cache_seq = api.decode_step(
            params, tokens[:, t:t + 1], jnp.int32(t), cache_seq)
    cache = pf_cache
    for t in range(S, S + extra):
        cont_logits, cache = api.decode_step(
            params, tokens[:, t:t + 1], jnp.int32(t), cache)
    np.testing.assert_allclose(np.asarray(cont_logits),
                               np.asarray(seq_logits),
                               atol=2e-3, rtol=2e-3)


def test_prefill_padded_prompt_and_ring_overflow():
    """Right-padded prompts must not pollute the cache, including when
    the prompt overflows a sliding-window ring cache."""
    cfg, api, params = _api_params("h2o-danube-1.8b")
    a = dataclasses.replace(cfg.model.attention, window=4)
    cfg = dataclasses.replace(cfg, model=dataclasses.replace(
        cfg.model, attention=a))
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    rng = np.random.default_rng(1)
    B, S, extra = 2, 10, 3
    tokens = jnp.asarray(
        rng.integers(0, cfg.model.vocab_size, (B, S + extra)), jnp.int32)
    cache_seq = api.init_cache(B, S + extra)
    for t in range(S + extra):
        seq_logits, cache_seq = api.decode_step(
            params, tokens[:, t:t + 1], jnp.int32(t), cache_seq)
    padded = jnp.concatenate([tokens[:, :S], jnp.zeros((B, 6), jnp.int32)],
                             axis=1)
    _, cache = api.prefill(params, padded, api.init_cache(B, S + extra),
                           length=S)
    for t in range(S, S + extra):
        cont_logits, cache = api.decode_step(
            params, tokens[:, t:t + 1], jnp.int32(t), cache)
    np.testing.assert_allclose(np.asarray(cont_logits),
                               np.asarray(seq_logits),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "xlstm-125m"])
def test_engine_generate_matches_seed_path(arch):
    """Engine-level greedy parity: prefill + continuous-batching decode
    produces the exact tokens of the seed token-by-token path (covers
    both the one-shot prefill and the fused-scan fallback)."""
    cfg = get_config(arch).reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    eng = ServeEngine(cfg, params, batch_size=3, max_len=64)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.model.vocab_size, (2, 13)), jnp.int32)
    out_new = np.asarray(eng.generate(prompt, steps=5))
    out_seq = np.asarray(eng.generate_sequential(prompt, steps=5))
    assert out_new.shape == (2, 5)
    np.testing.assert_array_equal(out_new, out_seq)


def test_bucket_len():
    assert bucket_len(1) == 8
    assert bucket_len(8) == 8
    assert bucket_len(9) == 16
    assert bucket_len(128) == 128
    assert bucket_len(129) == 256


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine():
    cfg = get_config("stablelm-1.6b").reduced()
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    return cfg, ServeEngine(cfg, params, batch_size=2, max_len=64)


def test_scheduler_slot_reuse_and_eviction(small_engine):
    """More requests than slots: every request completes, slots are
    recycled, and TTFT/TPOT accounting is populated."""
    cfg, eng = small_engine
    rng = np.random.default_rng(0)
    reqs = [Request(id=k, arrival_s=0.01 * k,
                    prompt=rng.integers(0, cfg.model.vocab_size, 6),
                    max_new_tokens=3)
            for k in range(6)]
    sched = ContinuousBatchingScheduler(eng)
    stats = sched.run(reqs)
    assert len(sched.completed) == 6
    assert all(len(r.tokens) == 3 for r in sched.completed)
    # 6 requests through 2 slots -> at least 4 admissions reuse a slot
    assert stats.slot_reuses >= 4
    assert stats.peak_occupancy <= eng.batch_size
    assert not sched.active and len(eng.free_slots) == eng.batch_size
    assert stats.ttft_ms.shape == (6,)
    assert (stats.ttft_ms > 0).all() and (stats.tpot_ms > 0).all()
    assert stats.tokens_generated == 18


def test_scheduler_interleaves_mid_generation_admission(small_engine):
    """A request admitted while another is mid-generation shares the
    decode program and still matches solo greedy generation."""
    cfg, eng = small_engine
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.model.vocab_size, 9)
    p2 = rng.integers(0, cfg.model.vocab_size, 5)
    solo1 = np.asarray(eng.generate(jnp.asarray(p1)[None], steps=6))[0]
    solo2 = np.asarray(eng.generate(jnp.asarray(p2)[None], steps=4))[0]
    reqs = [Request(id=0, arrival_s=0.0, prompt=p1, max_new_tokens=6),
            Request(id=1, arrival_s=1e9, prompt=p2, max_new_tokens=4)]
    # force req 1 to arrive mid-generation: admit 0, decode twice, then 1
    sched = ContinuousBatchingScheduler(eng)
    sched.submit(reqs[0])
    now = sched._admit_ready(0.0)
    now = sched._decode_once(now)
    now = sched._decode_once(now)
    reqs[1].arrival_s = now
    sched.submit(reqs[1])
    while sched.queue or sched.active:
        now = sched._admit_ready(now)
        if sched.active:
            now = sched._decode_once(now)
    done = {r.id: r for r in sched.completed}
    np.testing.assert_array_equal(done[0].tokens, solo1)
    np.testing.assert_array_equal(done[1].tokens, solo2)


def test_measure_preserves_inflight_sequences(small_engine):
    """Calibration mid-serving must not disturb active slots: tokens
    after a measure() call match an uninterrupted generation."""
    cfg, eng = small_engine
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.model.vocab_size, 8)
    expected = np.asarray(eng.generate(jnp.asarray(prompt)[None],
                                       steps=6))[0]
    slot = eng.acquire_slot()
    toks = [eng.admit(prompt, slot=slot)]
    toks.append(int(eng.decode()[slot]))
    eng.measure(prompt_len=8, decode_steps=2)        # mid-flight
    for _ in range(4):
        toks.append(int(eng.decode()[slot]))
    eng.evict(slot)
    np.testing.assert_array_equal(np.asarray(toks), expected)


def test_generate_refuses_busy_engine(small_engine):
    """generate() owns the whole engine; with sequences active it must
    refuse instead of silently advancing them."""
    cfg, eng = small_engine
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.model.vocab_size, 6)
    slot = eng.acquire_slot()
    eng.admit(prompt, slot=slot)
    with pytest.raises(RuntimeError, match="active sequences"):
        eng.generate(jnp.asarray(prompt)[None], steps=2)
    eng.evict(slot)


# ---------------------------------------------------------------------------
# replica pool
# ---------------------------------------------------------------------------

def test_replica_pool_per_tier_dispatch():
    """Each tier owns its engine with its own concurrency cap; dispatch
    routes work to the right replica (LM tiers decode tokens, the paper's
    GRU tier serves one forward per request)."""
    pool = ReplicaPool(lm_tiers("stablelm-1.6b", max_len=64))
    assert pool.tiers == ("device", "edge", "cloud")
    assert pool.concurrency("device") == 1
    assert pool.concurrency("edge") == 4
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 1024, (2, 6))
    out_edge = pool.dispatch("edge", prompts, steps=3)
    assert out_edge.shape == (2, 3)
    assert pool.engine("edge") is not pool.engine("cloud")
    assert pool.engine("edge").batch_size == 4
    # rnn tier: per-request forward
    gru_pool = ReplicaPool([TierSpec("device", arch="gru-traffic",
                                     batch_size=2)])
    pred = gru_pool.dispatch("device", rng.normal(size=(2, 12, 1)))
    assert pred.shape == (2, 1)
    with pytest.raises(TypeError):
        gru_pool.engine("device")
    with pytest.raises(ValueError):
        ReplicaPool([TierSpec("fog")])


def test_deployment_carries_replica_pool():
    from repro.orchestration import (Inventory, LearningController,
                                     random_inventory)
    from repro.serving import DEFAULT_TIERS
    inv = random_inventory(n=8, m=2, seed=0, capacity_slack=3.0)
    ctl = LearningController(inventory=inv, l=2,
                             serving_tiers=DEFAULT_TIERS)
    dep = ctl.deploy()
    assert dep.replica_pool is not None
    assert [s for s in dep.inference_services
            if s.startswith("replica/")] == [
        "replica/device", "replica/edge", "replica/cloud"]
    # without serving tiers the deployment stays pool-free (default)
    dep2 = LearningController(inventory=inv, l=2).deploy()
    assert dep2.replica_pool is None


# ---------------------------------------------------------------------------
# calibration bridge
# ---------------------------------------------------------------------------

def _meas(prefill, tpot, slots):
    return EngineMeasurement(prefill_ms=prefill, decode_ms_per_token=tpot,
                             batch_size=slots, prompt_len=16,
                             decode_steps=8)


def test_from_measurements_service_times_and_occupancy():
    lat = LatencyModel.from_measurements(
        {"edge": _meas(4.0, 0.5, 4), "cloud": _meas(2.0, 0.25, 16)},
        decode_tokens=8)
    assert isinstance(lat, CalibratedLatencyModel)
    assert lat.infer_ms("edge") == pytest.approx(4.0 + 8 * 0.5)
    assert lat.infer_ms("cloud") == pytest.approx(2.0 + 8 * 0.25)
    # within the slot budget service time is flat; beyond it requests
    # time-share the decode program
    assert lat.infer_ms("edge", occupancy=3) == pytest.approx(8.0)
    assert lat.infer_ms("edge", occupancy=7) == pytest.approx(16.0)
    # unmeasured tier falls back to the constant closed-form model
    assert lat.infer_ms("device") == LatencyModel().infer_ms("device")
    # network RTT behaviour is inherited untouched
    rng = np.random.default_rng(0)
    assert 8.0 <= float(lat.rtt("edge", rng)) <= 10.0


def test_simulator_calibrated_mode():
    """The simulator runs with engine-measured service times; the
    constant model stays the default and produces different latencies."""
    from repro.core.topology import ClusterTopology
    topo = ClusterTopology(assign=np.arange(12) % 3, n_devices=12,
                           n_edges=3, lam=np.full(12, 2.0),
                           r=np.full(3, 10.0), l=2)
    lat = LatencyModel.from_measurements(
        {"device": _meas(6.0, 0.0, 1), "edge": _meas(3.0, 0.0, 4),
         "cloud": _meas(1.0, 0.0, 16)})
    calib = simulate(topo, SimConfig(duration_s=30, seed=1, latency=lat))
    const = simulate(topo, SimConfig(duration_s=30, seed=1))
    assert len(calib.latency_ms) == len(const.latency_ms)
    assert calib.mean_latency() != pytest.approx(const.mean_latency())
    assert np.isfinite(calib.latency_ms).all()


def test_replica_pool_measure_feeds_latency_model():
    pool = ReplicaPool()                     # paper GRU at every tier
    lat = LatencyModel.from_measurements(pool.measure())
    for tier in pool.tiers:
        assert lat.infer_ms(tier) > 0.0
        assert lat.infer_ms(tier, occupancy=100) > lat.infer_ms(tier)


# ---------------------------------------------------------------------------
# workload flush semantics
# ---------------------------------------------------------------------------

def test_batched_arrivals_flushes_at_deadline():
    """A batch whose oldest member exceeds max_wait_s leaves at the
    deadline; the late arrival opens a NEW batch instead of riding along
    with (and further delaying) the stale one."""
    ev = [RequestEvent(0.00, 0), RequestEvent(0.01, 1),
          RequestEvent(0.20, 2)]
    batches = list(batched_arrivals(ev, batch_size=8, max_wait_s=0.05))
    assert len(batches) == 2
    t0, d0 = batches[0]
    assert t0 == pytest.approx(0.05)         # deadline, not 0.20
    assert list(d0) == [0, 1]
    t1, d1 = batches[1]
    assert list(d1) == [2] and t1 == pytest.approx(0.25)


def test_batched_arrivals_full_batch_and_conservation():
    lam = np.array([5.0, 10.0])
    ev = poisson_requests(lam, duration_s=10, seed=0)
    batches = list(batched_arrivals(ev, batch_size=4, max_wait_s=0.05))
    assert sum(len(b[1]) for b in batches) == len(ev)
    for t, devs in batches:
        assert len(devs) <= 4
    # emission times never precede the last member's arrival
    k = 0
    for t, devs in batches:
        assert t >= ev[k + len(devs) - 1].t - 1e-12
        k += len(devs)


def test_replica_pool_health_and_failover():
    """Down tiers re-route up the hierarchy (device->edge->cloud),
    degraded tiers still serve, a fully-down chain raises, and
    mark_down drains a built engine's in-flight rows leak-free."""
    from repro.serving import FAILOVER_ORDER, PagedServeEngine
    from repro.serving.replica import DEFAULT_TIERS

    pool = ReplicaPool(DEFAULT_TIERS)
    assert [pool.health(t) for t in pool.tiers] == ["healthy"] * 3
    assert pool.resolve_tier("edge") == "edge"
    pool.set_health("edge", "degraded")        # degraded still serves
    assert pool.resolve_tier("edge") == "edge"
    pool.set_health("edge", "down")
    assert pool.resolve_tier("edge") == "cloud"
    assert pool.resolve_tier("device") == "device"
    pool.set_health("device", "down")
    assert pool.resolve_tier("device") == "cloud"
    pool.set_health("cloud", "down")
    with pytest.raises(RuntimeError, match="failover chain"):
        pool.resolve_tier("device")
    pool.mark_up("edge")
    assert pool.resolve_tier("device") == "edge"
    assert pool.failovers == 3
    with pytest.raises(ValueError):
        pool.set_health("edge", "on-fire")
    assert FAILOVER_ORDER["cloud"] == ()

    # crash with traffic in flight: engine drained, pages conserved
    lm = ReplicaPool(
        [TierSpec("edge", arch="stablelm-1.6b", batch_size=2, max_len=64,
                  paged=True, page_size=8)],
        shared_params=None)
    lm.specs["edge"] = dataclasses.replace(lm.specs["edge"], reduced=True)
    eng = lm.engine("edge")
    assert isinstance(eng, PagedServeEngine)
    slot = eng.acquire_slot()
    eng.admit(np.arange(10) % 50, slot=slot, reserve_tokens=4)
    assert eng.active_slots == 1
    drained = lm.mark_down("edge")
    assert drained == [slot] and eng.active_slots == 0
    assert eng.pool.free_pages == eng.num_pages
    assert lm.health("edge") == "down"
