"""stablelm-1.6b [dense] — 24L d_model=2048 32H (GQA kv=32) d_ff=5632
vocab=100352, partial rotary (25%), LayerNorm.
[hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import (ArchConfig, AttentionConfig, ModelConfig,
                                RunConfig)

CONFIG = ArchConfig(
    model=ModelConfig(
        name="stablelm-1.6b",
        family="dense",
        source="hf:stabilityai/stablelm-2-1_6b",
        num_layers=24,
        d_model=2048,
        d_ff=5632,
        vocab_size=100_352,
        norm="layernorm",
        attention=AttentionConfig(
            kind="full",
            num_heads=32,
            num_kv_heads=32,
            head_dim=64,
            rope_theta=10_000.0,
            rope_fraction=0.25,
        ),
    ),
    run=RunConfig(microbatches=1, remat="layer"),
)
