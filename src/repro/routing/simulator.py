"""Discrete-event simulator for inference serving during (continual) HFL
training — reproduces the paper's Fig. 7 (response times) and Fig. 8
(end-to-end latency vs compute speedup and request-rate scaling).

Each device emits a Poisson request stream at rate lambda_i (shared
generator: ``serving.workload.poisson_requests``).  Requests are routed
by rules R1-R3 (``repro.routing.rules``); edges have finite concurrent-
processing capacity derived from r_j; the cloud is infinite.

Since the co-simulation subsystem landed, this module is a thin
inference-only configuration of the shared event core
(``repro.sim.events``): :class:`RequestProcessor` holds the routing +
service logic, and :func:`simulate` wires it to a coin-flip training
signal (``busy_fraction``).  ``repro.sim.cosim`` reuses the same
processor but drives the busy flag from an actual training round
timeline and the service times through an interference model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.topology import ClusterTopology
from repro.routing.latency import LatencyModel
from repro.routing.rules import EdgeState, RouteDecision, route_request
from repro.serving.workload import poisson_requests
from repro.sim.events import Event, EventKind, Simulation


@dataclass
class RequestLog:
    t: np.ndarray                    # arrival times (s)
    device: np.ndarray
    tier: np.ndarray                 # 0=device 1=edge 2=cloud
    rule: List[str]
    latency_ms: np.ndarray

    def mean_latency(self) -> float:
        """Mean end-to-end latency in ms (NaN on an empty log)."""
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.mean(self.latency_ms))

    def std_latency(self) -> float:
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.std(self.latency_ms))

    def percentile_latency(self, p: float) -> float:
        """p-th percentile of end-to-end latency in ms (p in [0, 100]);
        NaN on an empty log — short smoke runs can legitimately serve
        zero requests, and reporting must not crash on them."""
        if self.latency_ms.size == 0:
            return math.nan
        return float(np.percentile(self.latency_ms, p))

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p95/p99 summary (``tier_fractions``-style dict, in ms)."""
        return {f"p{p:g}": self.percentile_latency(p)
                for p in (50, 95, 99)}

    def tier_fractions(self) -> Dict[str, float]:
        names = {0: "device", 1: "edge", 2: "cloud"}
        if self.tier.size == 0:
            return {name: math.nan for name in names.values()}
        out = {}
        for k, name in names.items():
            out[name] = float(np.mean(self.tier == k))
        return out

    def windowed_percentile(self, window_s: float, p: float = 95.0,
                            ) -> np.ndarray:
        """(n_windows, 2) array of [window start, p-th percentile latency]
        — the latency timeline the reactive monitors and examples plot.
        Windows without any arrivals are NaN rows (not silently dropped),
        so the timeline keeps a uniform grid and gaps stay visible."""
        if self.t.size == 0:
            return np.zeros((0, 2))
        edges = np.arange(0.0, float(self.t.max()) + 1e-9, window_s)
        rows = []
        for lo in edges:
            m = (self.t >= lo) & (self.t < lo + window_s)
            val = (float(np.percentile(self.latency_ms[m], p))
                   if np.any(m) else math.nan)
            rows.append((lo, val))
        return np.asarray(rows)


@dataclass
class SimConfig:
    duration_s: float = 300.0
    seed: int = 0
    busy_fraction: float = 1.0       # fraction of time devices train (CL: 1)
    rate_scale: float = 1.0          # Fig. 8b: lambda x 10
    latency: LatencyModel = field(default_factory=LatencyModel)


class RequestProcessor:
    """Routing + service logic for ``REQUEST_ARRIVAL`` events on the
    event core — shared between the inference-only simulator below and
    the training–inference co-simulation (``repro.sim.cosim``).

    Pluggable policies:
      ``busy_fn(device, t)``          -> is the device training right now?
      ``service_fn(device, dec, occ)`` -> service time in ms (defaults to
                                          the latency model's ``infer_ms``)
      ``extra_ms_fn(dec, t, device)`` -> additive penalty (reconfiguration
                                          and handover cost windows in
                                          the co-sim)
    """

    def __init__(self, topo: ClusterTopology, rng: np.random.Generator,
                 latency: Optional[LatencyModel] = None,
                 busy_fn: Optional[Callable[[int, float], bool]] = None,
                 service_fn: Optional[
                     Callable[[int, RouteDecision, int], float]] = None,
                 extra_ms_fn: Optional[
                     Callable[[RouteDecision, float, int], float]] = None):
        self.rng = rng
        self.lat = latency if latency is not None else LatencyModel()
        self.busy_fn = busy_fn or (lambda i, t: False)
        self.service_fn = service_fn
        self.extra_ms_fn = extra_ms_fn
        self.edges: Dict[int, EdgeState] = {}
        self.set_topology(topo)
        self._t: List[float] = []
        self._dev: List[int] = []
        self._tier: List[int] = []
        self._rule: List[str] = []
        self._lat: List[float] = []
        self._tier_code = {"device": 0, "edge": 1, "cloud": 2}

    def set_topology(self, topo: ClusterTopology) -> None:
        """(Re)build admission state — used at start and when the
        reactive loop swaps in a re-clustered deployment.  In-flight
        completions keep a reference to their old ``EdgeState`` (the
        event payload), so they drain harmlessly after a swap."""
        self.topo = topo
        self.edges = {}
        for j in topo.open_edges:
            # capacity is a property of the edge host — it does NOT scale
            # with the request-rate multiplier (the point of Fig. 8b)
            self.edges[int(j)] = EdgeState(
                capacity_rps=float(topo.r[j]) if topo.r.size else np.inf)

    def bind(self, sim: Simulation) -> None:
        sim.on(EventKind.REQUEST_ARRIVAL, self.on_arrival)
        sim.on(EventKind.REQUEST_COMPLETION, self.on_completion)

    def fail_edge(self, edge_id: int) -> None:
        """Edge host died: zero capacity so R3 overflows to the cloud."""
        st = self.edges.get(int(edge_id))
        if st is not None:
            st.capacity_rps = 0.0
            st.tokens = 0.0

    def on_completion(self, sim: Simulation, ev: Event) -> None:
        ev.payload.in_service -= 1

    def on_arrival(self, sim: Simulation, ev: Event) -> None:
        t, i = ev.t, ev.node
        busy = self.busy_fn(i, t)
        dec = route_request(i, busy, self.topo.assign, self.edges, now=t)
        # calibrated mode: service time reflects how many requests the
        # chosen replica already has in flight (constant model ignores it)
        occ = self.edges[dec.edge].in_service if dec.tier == "edge" else 0
        service = (self.service_fn(i, dec, occ) if self.service_fn
                   else self.lat.infer_ms(dec.tier, occupancy=occ))
        if dec.tier == "edge":
            st = self.edges[dec.edge]
            st.admit(t)
            sim.schedule(t + service / 1000.0, EventKind.REQUEST_COMPLETION,
                         node=dec.edge, payload=st)
            net = float(self.lat.rtt("edge", self.rng))
        elif dec.tier == "cloud":
            net = float(self.lat.rtt("cloud", self.rng))
            if dec.hops == 2:        # forwarded via the edge (R3 overflow)
                net += float(self.lat.rtt("edge", self.rng))
        else:
            net = float(self.lat.rtt("device", self.rng))
        if self.extra_ms_fn is not None:
            net += float(self.extra_ms_fn(dec, t, i))
        self._t.append(t)
        self._dev.append(i)
        self._tier.append(self._tier_code[dec.tier])
        self._rule.append(dec.rule)
        self._lat.append(net + service)

    def recent_percentile(self, now: float, window_s: float, p: float,
                          min_requests: int = 1,
                          max_lookback: int = 4096) -> Optional[float]:
        """p-th latency percentile over requests arriving in
        ``[now - window_s, now]`` — the latency monitors' telemetry.
        None when the window holds fewer than ``min_requests``.

        At most the newest ``max_lookback`` requests are scanned (the
        monitor fires every few simulated seconds; rescanning the full
        history each tick would be quadratic).  At arrival rates above
        ``max_lookback / window_s`` req/s the estimate therefore covers
        only the newest part of the window — raise ``max_lookback`` if
        that bias matters for your scenario."""
        ts = np.asarray(self._t[-max_lookback:])
        if ts.size == 0:
            return None
        m = ts >= now - window_s
        if int(m.sum()) < min_requests:
            return None
        return float(np.percentile(np.asarray(self._lat[-max_lookback:])[m],
                                   p))

    def log(self) -> RequestLog:
        return RequestLog(
            t=np.asarray(self._t), device=np.asarray(self._dev, int),
            tier=np.asarray(self._tier, int), rule=self._rule,
            latency_ms=np.asarray(self._lat))


def simulate(topo: ClusterTopology, cfg: SimConfig) -> RequestLog:
    rng = np.random.default_rng(cfg.seed)
    arrivals = poisson_requests(topo.lam * cfg.rate_scale, cfg.duration_s,
                                rng)
    sim = Simulation()
    proc = RequestProcessor(
        topo, rng, latency=cfg.latency,
        busy_fn=lambda i, t: rng.uniform() < cfg.busy_fraction)
    proc.bind(sim)
    for ev in arrivals:
        sim.schedule(ev.t, EventKind.REQUEST_ARRIVAL, node=ev.device)
    sim.run()
    return proc.log()


def compare_methods(inst, assigns: Dict[str, np.ndarray], cfg: SimConfig,
                    ) -> Dict[str, RequestLog]:
    """Run the same workload through several topologies (Fig. 7 setup:
    flat vs location-hierarchical vs HFLOP)."""
    out = {}
    for name, assign in assigns.items():
        if assign is None:           # flat FL
            topo = ClusterTopology.flat(inst.n, lam=inst.lam)
        else:
            topo = ClusterTopology(assign=np.asarray(assign),
                                   n_devices=inst.n, n_edges=inst.m,
                                   lam=inst.lam, r=inst.r, l=inst.l)
        out[name] = simulate(topo, cfg)
    return out
