"""DET001 good fixture: explicit Generator streams only."""
import numpy as np
from numpy.random import default_rng


def sample(n, seed=0):
    rng = np.random.default_rng(seed)
    other = default_rng(seed + 1)
    return rng.normal(size=n) + other.uniform(size=n)
