"""Fixture: EVENT_EFFECTS out of sync with EventKind."""
from enum import IntEnum
from typing import Dict


class EventKind(IntEnum):
    REQUEST_COMPLETION = 0
    DEVICE_MOVE = 1
    ROUND_START = 2
    TELEMETRY = 3          # missing from EVENT_EFFECTS below


class EventEffect(IntEnum):
    NONE = 0
    MUTATES_ROUTING = 1
    READS_LOG = 2


EVENT_EFFECTS: Dict[EventKind, EventEffect] = {
    EventKind.REQUEST_COMPLETION: EventEffect.MUTATES_ROUTING,
    EventKind.DEVICE_MOVE: EventEffect.MUTATES_ROUTING,
    EventKind.ROUND_START: EventEffect.NONE,
    EventKind.ROUND_END: EventEffect.NONE,     # stale: no such member
}
