"""Discrete-event simulator for inference serving during (continual) HFL
training — reproduces the paper's Fig. 7 (response times) and Fig. 8
(end-to-end latency vs compute speedup and request-rate scaling).

Each device emits a Poisson request stream at rate lambda_i.  Requests are
routed by rules R1-R3 (``repro.routing.rules``); edges have finite
concurrent-processing capacity derived from r_j; the cloud is infinite.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.topology import ClusterTopology
from repro.routing.latency import LatencyModel
from repro.routing.rules import EdgeState, RouteDecision, route_request


@dataclass
class RequestLog:
    t: np.ndarray                    # arrival times (s)
    device: np.ndarray
    tier: np.ndarray                 # 0=device 1=edge 2=cloud
    rule: List[str]
    latency_ms: np.ndarray

    def mean_latency(self) -> float:
        return float(np.mean(self.latency_ms))

    def std_latency(self) -> float:
        return float(np.std(self.latency_ms))

    def tier_fractions(self) -> Dict[str, float]:
        names = {0: "device", 1: "edge", 2: "cloud"}
        out = {}
        for k, name in names.items():
            out[name] = float(np.mean(self.tier == k))
        return out


@dataclass
class SimConfig:
    duration_s: float = 300.0
    seed: int = 0
    busy_fraction: float = 1.0       # fraction of time devices train (CL: 1)
    rate_scale: float = 1.0          # Fig. 8b: lambda x 10
    latency: LatencyModel = field(default_factory=LatencyModel)


def simulate(topo: ClusterTopology, cfg: SimConfig) -> RequestLog:
    rng = np.random.default_rng(cfg.seed)
    lat = cfg.latency
    n = topo.n_devices
    rates = topo.lam * cfg.rate_scale

    edges: Dict[int, EdgeState] = {}
    for j in topo.open_edges:
        # capacity is a property of the edge host — it does NOT scale with
        # the request-rate multiplier (that is the point of Fig. 8b)
        edges[int(j)] = EdgeState(capacity_rps=float(topo.r[j])
                                  if topo.r.size else np.inf)

    # generate arrivals
    arrivals = []
    for i in range(n):
        if rates[i] <= 0:
            continue
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rates[i])
            if t > cfg.duration_s:
                break
            arrivals.append((t, i))
    arrivals.sort()

    # event heap for service completions: (time, edge_id)
    completions: List = []
    out_t, out_dev, out_tier, out_rule, out_lat = [], [], [], [], []
    tier_code = {"device": 0, "edge": 1, "cloud": 2}

    for (t, i) in arrivals:
        while completions and completions[0][0] <= t:
            _, j = heapq.heappop(completions)
            edges[j].in_service -= 1
        busy = rng.uniform() < cfg.busy_fraction
        dec = route_request(i, busy, topo.assign, edges, now=t)
        # calibrated mode: service time reflects how many requests the
        # chosen replica already has in flight (constant model ignores it)
        occ = edges[dec.edge].in_service if dec.tier == "edge" else 0
        service = lat.infer_ms(dec.tier, occupancy=occ)
        if dec.tier == "edge":
            edges[dec.edge].admit(t)
            heapq.heappush(completions, (t + service / 1000.0, dec.edge))
            net = float(lat.rtt("edge", rng))
        elif dec.tier == "cloud":
            net = float(lat.rtt("cloud", rng))
            if dec.hops == 2:        # forwarded via the edge (R3 overflow)
                net += float(lat.rtt("edge", rng))
        else:
            net = float(lat.rtt("device", rng))
        out_t.append(t)
        out_dev.append(i)
        out_tier.append(tier_code[dec.tier])
        out_rule.append(dec.rule)
        out_lat.append(net + service)

    return RequestLog(
        t=np.asarray(out_t), device=np.asarray(out_dev, int),
        tier=np.asarray(out_tier, int), rule=out_rule,
        latency_ms=np.asarray(out_lat))


def compare_methods(inst, assigns: Dict[str, np.ndarray], cfg: SimConfig,
                    ) -> Dict[str, RequestLog]:
    """Run the same workload through several topologies (Fig. 7 setup:
    flat vs location-hierarchical vs HFLOP)."""
    out = {}
    for name, assign in assigns.items():
        if assign is None:           # flat FL
            topo = ClusterTopology.flat(inst.n, lam=inst.lam)
        else:
            topo = ClusterTopology(assign=np.asarray(assign),
                                   n_devices=inst.n, n_edges=inst.m,
                                   lam=inst.lam, r=inst.r, l=inst.l)
        out[name] = simulate(topo, cfg)
    return out
