"""Train-step assembly: loss + grad + microbatched accumulation +
optimizer update, over the unified model API.

``make_train_step`` builds the jittable function lowered by the train_4k
dry-run shape.  ``make_hfl_train_step`` builds the hierarchical-FL
variant: parameters carry a leading *cluster* dimension (sharded over the
"pod" mesh axis) and gradients are vmapped per cluster, so local rounds
emit no cross-cluster collectives; ``global_sync`` (fl.collectives) is a
separate program run every l rounds."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.fl.collectives import global_sync
from repro.models import ModelApi
from repro.training.optimizer import AdamW

PyTree = Any


def _split_microbatches(batch: Dict[str, jax.Array], k: int):
    def sp(x):
        return x.reshape((k, x.shape[0] // k) + x.shape[1:])
    return {key: sp(v) for key, v in batch.items()}


def make_train_step(api: ModelApi, cfg: ArchConfig, optimizer: AdamW
                    ) -> Callable:
    k = cfg.run.microbatches

    def train_step(params: PyTree, opt_state, batch: Dict[str, jax.Array]):
        if k <= 1:
            loss, grads = jax.value_and_grad(api.loss)(params, batch)
        else:
            mbs = _split_microbatches(batch, k)

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(api.loss)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (gsum, lsum), _ = jax.lax.scan(acc, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = lsum / k
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss

    return train_step


def make_eval_step(api: ModelApi) -> Callable:
    def eval_step(params, batch):
        return api.loss(params, batch)
    return eval_step


# ---------------------------------------------------------------------------
# hierarchical-FL train step (cluster-replicated params)
# ---------------------------------------------------------------------------

def make_hfl_train_step(api: ModelApi, cfg: ArchConfig, optimizer: AdamW
                        ) -> Callable:
    """params/opt_state carry a leading cluster dim; batch carries a
    matching leading dim.  Local training = vmap over clusters (no
    cross-cluster reduction)."""
    base = make_train_step(api, cfg, optimizer)

    def hfl_local_step(stacked_params, stacked_opt, stacked_batch):
        return jax.vmap(base)(stacked_params, stacked_opt, stacked_batch)

    return hfl_local_step


def hfl_global_round(stacked_params: PyTree,
                     weights=None) -> PyTree:
    """The every-l-rounds parameter sync (one "pod"-axis all-reduce)."""
    return global_sync(stacked_params, weights)
