"""Import-layering rules: who may pull in jax at import time.

The co-simulation / routing / solver / telemetry stack is deliberately
numpy-only so scenario grids, scaling studies, and CI import in
milliseconds and run on jax-free boxes; jax lives behind the training
modules (``repro.fl`` internals, ``repro.models``, ``repro.training``)
and the lazy serving facade.  These rules walk the *eager* import graph
(top-level statements only — function-local and ``TYPE_CHECKING``
imports are free) and fail if a protected module can reach an
accelerator framework at import time.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import (Finding, Project, Rule, eager_imports)

#: accelerator frameworks that must stay out of protected import closures
HEAVY_MODULES = ("jax", "jaxlib", "flax", "optax", "torch", "tensorflow")

#: namespaces that must import jax-free (prefix match on dotted name)
PROTECTED_NAMESPACES = (
    "repro.routing",
    "repro.sim",
    "repro.core",
    "repro.telemetry",
    "repro.configs",
    "repro.fl.schedule",
)

#: lazy facades: their own eager body must stay jax-free even though the
#: names they re-export resolve to jax-backed modules on attribute access
LAZY_FACADES = ("repro.serving", "repro.fl")


def _resolve_relative(importer: str, is_pkg: bool, name: str) -> str:
    """Resolve a leading-dots import name against the importing module."""
    if not name.startswith("."):
        return name
    level = len(name) - len(name.lstrip("."))
    remainder = name[level:]
    parts = importer.split(".")
    if not is_pkg:
        parts = parts[:-1]
    # one leading dot = current package; each extra dot goes up one
    parts = parts[: len(parts) - (level - 1)] if level > 1 else parts
    base = ".".join(parts)
    return base + ("." + remainder if remainder else "")


class _ImportGraph:
    """Eager import edges between internal (``repro.*``) modules, plus
    the heavy third-party modules each file names directly."""

    def __init__(self, project: Project):
        self.project = project
        # module -> [(target module name, line)]
        self.edges: Dict[str, List[Tuple[str, int]]] = {}
        # module -> [(heavy root, line)]
        self.heavy: Dict[str, List[Tuple[str, int]]] = {}
        for path in project.iter_paths():
            ctx = project.context(path)
            mod = ctx.module or ""
            is_pkg = path.endswith("__init__.py")
            edges: List[Tuple[str, int]] = []
            heavy: List[Tuple[str, int]] = []
            for name, line in eager_imports(ctx.tree):
                name = _resolve_relative(mod, is_pkg, name)
                root = name.split(".")[0]
                if root in HEAVY_MODULES:
                    heavy.append((root, line))
                    continue
                internal = self._to_internal(name)
                if internal is not None:
                    edges.append((internal, line))
            self.edges[mod] = edges
            self.heavy[mod] = heavy

    def _to_internal(self, name: str) -> Optional[str]:
        """Longest prefix of ``name`` that is an internal module (so
        ``from repro.fl.schedule import RoundWindow`` maps to
        ``repro.fl.schedule``, not a non-module attribute)."""
        if not name.startswith("repro"):
            return None
        parts = name.split(".")
        while parts:
            cand = ".".join(parts)
            if cand in self.project_modules:
                return cand
            parts = parts[:-1]
        return None

    @property
    def project_modules(self) -> Set[str]:
        cached = getattr(self, "_modules", None)
        if cached is None:
            cached = {self.project.module_name(p)
                      for p in self.project.iter_paths()}
            # importing a submodule also imports its ancestor packages
            self._modules = cached
        return cached

    def ancestors(self, module: str) -> List[str]:
        parts = module.split(".")
        return [".".join(parts[:i]) for i in range(1, len(parts))]

    def heavy_chain(self, start: str) -> Optional[List[str]]:
        """Shortest eager-import chain from ``start`` to a heavy module,
        as ``[start, ..., leaf, heavy_root]``; None if unreachable."""
        seen = {start}
        queue: List[List[str]] = [[start]]
        while queue:
            chain = queue.pop(0)
            mod = chain[-1]
            heavy = self.heavy.get(mod)
            if heavy:
                return chain + [heavy[0][0]]
            nxt: List[str] = []
            for target, _line in self.edges.get(mod, ()):  # direct edges
                nxt.append(target)
                nxt.extend(self.ancestors(target))  # pkg __init__ runs too
            for target in nxt:
                if target not in seen and target in self.edges:
                    seen.add(target)
                    queue.append(chain + [target])
        return None


def _is_protected(module: str, namespaces: Sequence[str]) -> bool:
    return any(module == ns or module.startswith(ns + ".")
               for ns in namespaces)


class JaxFreeImportRule(Rule):
    """LAYER001: protected namespaces must be jax-free at import time."""

    id = "LAYER001"
    name = "jax-free-import"
    description = ("repro.routing/sim/core/telemetry/configs and "
                   "repro.fl.schedule must not reach "
                   f"{'/'.join(HEAVY_MODULES[:2])}/... through their "
                   "eager import closure")
    namespaces = PROTECTED_NAMESPACES

    def check_project(self, project: Project) -> List[Finding]:
        graph = _ImportGraph(project)
        findings: List[Finding] = []
        for path in project.iter_paths():
            ctx = project.context(path)
            mod = ctx.module or ""
            if not _is_protected(mod, self.namespaces):
                continue
            for root, line in graph.heavy.get(mod, ()):  # direct import
                findings.append(Finding(
                    path=ctx.rel_path, line=line, rule=self.id,
                    message=f"protected module {mod} imports {root} "
                            f"at import time"))
            for target, line in graph.edges.get(mod, ()):  # transitive
                for hop in [target] + graph.ancestors(target):
                    chain = graph.heavy_chain(hop)
                    if chain is not None:
                        findings.append(Finding(
                            path=ctx.rel_path, line=line, rule=self.id,
                            message=(f"protected module {mod} reaches "
                                     f"{chain[-1]} at import time via "
                                     + " -> ".join(chain))))
                        break
        return findings


class LazyFacadeRule(Rule):
    """LAYER002: lazy facades' own eager bodies must stay jax-free.

    ``repro.serving.__init__`` and ``repro.fl.__init__`` re-export
    jax-backed names through PEP 562 ``__getattr__``; the contract is
    that *importing the package* stays cheap — only attribute access
    pays.  This checks the facades' eager closure like LAYER001 does
    for protected namespaces.
    """

    id = "LAYER002"
    name = "lazy-facade"
    description = ("repro.serving and repro.fl package __init__ must "
                   "stay lazy: eager import closure jax-free")
    facades = LAZY_FACADES

    def check_project(self, project: Project) -> List[Finding]:
        graph = _ImportGraph(project)
        findings: List[Finding] = []
        for facade in self.facades:
            path = project.module_path(facade)
            if path is None or not path.endswith("__init__.py"):
                continue
            ctx = project.context(path)
            for root, line in graph.heavy.get(facade, ()):
                findings.append(Finding(
                    path=ctx.rel_path, line=line, rule=self.id,
                    message=f"lazy facade {facade} imports {root} "
                            f"eagerly"))
            for target, line in graph.edges.get(facade, ()):
                for hop in [target] + graph.ancestors(target):
                    chain = graph.heavy_chain(hop)
                    if chain is not None:
                        findings.append(Finding(
                            path=ctx.rel_path, line=line, rule=self.id,
                            message=(f"lazy facade {facade} reaches "
                                     f"{chain[-1]} eagerly via "
                                     + " -> ".join(chain))))
                        break
        return findings
