"""Vectorized request plane of the macro-event co-simulation.

The simulation is split into a sparse **control plane** — the event
heap in ``repro.sim.events``: round/epoch/aggregation windows,
failures, moves, stragglers, tenant load, drift, reconfigurations,
telemetry; a few thousand events per run — and a dense **request
plane**: the inference traffic, processed here in vectorized NumPy
batches covering the windows *between* consecutive control events.
Within such a window every routing input is constant by construction
(busy flags, capacities, interference stretch, penalty windows all
change only at control events), so per-request work collapses to array
arithmetic:

  * admission through each edge's leaky bucket is replayed *exactly*
    (:func:`bucket_admissions`) with a vectorized Lindley recursion on
    the bucket's token deficit — saturated stretches fall back to an
    O(#admissions) alternation of bulk-admit / bulk-reject runs, each
    found by ``searchsorted``, so cost never scales with the offered
    (rejected) load;
  * service times are per-(tier, node) constants — interference
    stretch times the latency model's base — broadcast over the batch;
    occupancy-sensitive calibrated models go through
    :func:`occupancy_replay`, which collapses every stretch of
    occupancy below the replica's slot count to the same closed-form
    broadcast (completion times are arrival + a constant, so occupancy
    is two ``searchsorted`` counts) and replays only genuinely
    oversubscribed stretches — where service and occupancy couple —
    with the exact scalar arithmetic, so cost scales with
    time-at-oversubscription, not offered load;
  * network RTTs are drawn in bulk from the same generator stream the
    heap path would have consumed request-by-request, so a batched
    co-simulation run is *bit-identical* to the heap ("parity") run.

Results land in a :class:`ColumnarLog` — preallocated, geometrically
grown float/int arrays, not Python object lists — whose
:meth:`~ColumnarLog.recent_percentile` is incremental (binary-searched
window start), so telemetry ticks cost O(log n + window) instead of
rescanning the whole request history.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from repro.routing.rules import EdgeState

#: rule-code table shared by the heap and batched engines; the columnar
#: log stores the int8 code, ``RequestLog`` materializes the string.
#: ``R4-failover`` is the fault-plane tier failover — a request whose
#: edge attempts were exhausted (down/dropped, retries timed out)
#: re-routed straight to the cloud replica.  The vectorized window path
#: never emits it (fault windows replay through the shared scalar
#: core), so it must stay *last*: ``_record_window``'s last-rule-gets-
#: the-remainder counting then assigns it an exact zero.
RULES = ("R1", "R1-flat", "R2-local", "R2-edge", "R2-cloud",
         "R3-overflow", "R4-failover")
RULE_CODE = {name: np.int8(k) for k, name in enumerate(RULES)}

TIER_DEVICE, TIER_EDGE, TIER_CLOUD = 0, 1, 2

# Lindley chunking: saturated buckets alternate short admit/reject runs,
# so scanning the whole remaining suffix per run would be quadratic —
# start small and grow geometrically while admissions stay clean.
_CHUNK0 = 64
_CHUNK_MAX = 1 << 20


class ColumnarLog:
    """Columnar request log: preallocated arrays grown geometrically.

    Both engines write here — the heap path appends one row per
    ``REQUEST_ARRIVAL`` event, the batched path extends whole windows —
    and rows are always in nondecreasing arrival-time order, which is
    what makes :meth:`recent_percentile` incremental."""

    __slots__ = ("n", "t", "device", "tier", "rule", "latency_ms",
                 "_win_cursor")

    def __init__(self, capacity: int = 1024):
        cap = max(int(capacity), 16)
        self.n = 0
        self.t = np.empty(cap, dtype=np.float64)
        self.device = np.empty(cap, dtype=np.int64)
        self.tier = np.empty(cap, dtype=np.int8)
        self.rule = np.empty(cap, dtype=np.int8)
        self.latency_ms = np.empty(cap, dtype=np.float64)
        self._win_cursor = 0

    def _grow(self, need: int) -> None:
        cap = self.t.size
        if self.n + need <= cap:
            return
        new = max(cap * 2, self.n + need)
        for name in ("t", "device", "tier", "rule", "latency_ms"):
            arr = getattr(self, name)
            out = np.empty(new, dtype=arr.dtype)
            out[: self.n] = arr[: self.n]
            setattr(self, name, out)

    def append(self, t: float, device: int, tier: int, rule: int,
               latency_ms: float) -> None:
        """One row (heap path)."""
        self._grow(1)
        k = self.n
        self.t[k] = t
        self.device[k] = device
        self.tier[k] = tier
        self.rule[k] = rule
        self.latency_ms[k] = latency_ms
        self.n = k + 1

    def extend(self, t: np.ndarray, device: np.ndarray, tier: np.ndarray,
               rule: np.ndarray, latency_ms: np.ndarray) -> None:
        """One window of rows (batched path)."""
        k = len(t)
        if k == 0:
            return
        self._grow(k)
        sl = slice(self.n, self.n + k)
        self.t[sl] = t
        self.device[sl] = device
        self.tier[sl] = tier
        self.rule[sl] = rule
        self.latency_ms[sl] = latency_ms
        self.n += k

    def recent_percentile(self, now: float, window_s: float, p: float,
                          min_requests: int = 1) -> Optional[float]:
        """p-th latency percentile over requests arriving in
        ``[now - window_s, now]``; None below ``min_requests``.

        Incremental: the window start index is found by binary search
        from a monotone cursor, so a telemetry tick costs
        O(log n + window size) — independent of total history.  The
        cursor resets itself if a caller moves ``now`` backward."""
        lo_t = now - window_s
        start = self._win_cursor
        if start > self.n or (start > 0 and self.t[start - 1] >= lo_t):
            start = 0                # window moved backward: full rescan
        lo = start + int(np.searchsorted(self.t[start:self.n], lo_t,
                                         side="left"))
        self._win_cursor = lo
        hi = lo + int(np.searchsorted(self.t[lo:self.n], now,
                                      side="right"))
        lat = self.latency_ms[lo:hi]
        if lat.size < min_requests:
            return None
        return float(np.percentile(lat, p))


def _bucket_replay(t: np.ndarray, admitted: np.ndarray, a: int, b: int,
                   rate: float, cap: float, tokens: float, last: float,
                   ) -> Tuple[float, float]:
    """Scalar replay of arrivals ``[a, b)`` with the verbatim
    ``EdgeState`` refill/admit arithmetic — the bit-exact fallback for
    chunks whose vectorized deficits graze the admission boundary."""
    for k in range(a, b):
        tokens = min(cap, tokens + rate * max(t[k] - last, 0.0))
        last = t[k]
        if tokens - 1.0 >= 0.0:
            tokens -= 1.0
            admitted[k] = True
    return tokens, last


def bucket_admissions(t: np.ndarray, st: EdgeState) -> np.ndarray:
    """Exact vectorized replay of :class:`EdgeState` leaky-bucket
    admission (priority class, rule R3) over a sorted arrival-time
    array.  Returns the heap path's admission mask and leaves
    ``st.tokens`` / ``st.last_t`` where the per-request heap path
    would, up to ULP-level rounding: compounded refills (one multiply
    over a skipped run, ``cumsum`` over a bulk chunk) associate floats
    differently than the heap's per-arrival arithmetic, so the carried
    token state can differ in the last bits.  A decision flips only if
    the true value sits within that ~1e-13 of the one-token admission
    threshold — measure-zero in practice (fuzzed against the scalar
    replay; the parity suite asserts bit-equality on fixed seeds) —
    and the bulk path additionally replays boundary-grazing chunks
    scalar.

    Three regimes, switched adaptively:

      * **bulk admission** — the all-admitted token *deficit*
        ``d_i = cap - tokens_i`` follows the Lindley recursion
        ``d_i = max(1, d_{i-1} + 1 - rate*dt_i)``, solved in closed
        form with ``cumsum`` + ``maximum.accumulate`` over
        geometrically growing chunks; the first index with
        ``d > cap`` is a rejection;
      * **saturation** — around a rejection the bucket hovers below
        one token: admissions are genuinely sequential, so they are
        replayed with the scalar ``EdgeState`` arithmetic (bit-exact
        by construction), and each *rejected run* in between — the
        bucket refills monotonically while nothing is admitted — is
        skipped with one ``searchsorted``.  Cost scales with the
        number of admissions at saturation (bounded by rate x window),
        never with the offered (rejected) load;
      * **boundary guard** — chunks whose vectorized deficits land
        within ``1e-6`` of the admission boundary, where ``cumsum``
        rounding could disagree with the heap's sequential ``min`` /
        ``max`` arithmetic, are replayed scalar as well."""
    n = t.size
    if not np.isfinite(st.capacity_rps):
        return np.ones(n, dtype=bool)          # infinite edge: admit all
    rate = float(st.capacity_rps)
    cap = rate * st.burst_s
    admitted = np.zeros(n, dtype=bool)
    tokens, last = float(st.tokens), float(st.last_t)
    starved = rate <= 0.0 or cap < 1.0         # can never refill to 1
    a, chunk = 0, _CHUNK0
    while a < n:
        if tokens - 1.0 < 0.0:
            # -- saturation: scalar admits + searchsorted run skips
            while a < n:
                tokens = min(cap, tokens + rate * max(t[a] - last, 0.0))
                last = t[a]
                if tokens - 1.0 >= 0.0:
                    tokens -= 1.0
                    admitted[a] = True
                    a += 1
                    if tokens - 1.0 >= 0.0:
                        break          # bucket recovered: back to bulk
                    continue
                if starved:            # reject the rest, but keep
                    # refilling toward cap like the heap does — a later
                    # CAPACITY_CHANGE may make these tokens admissible
                    tokens = min(cap, tokens
                                 + rate * max(t[n - 1] - last, 0.0))
                    last = t[n - 1]
                    a = n
                    break
                t_ok = last + (1.0 - tokens) / rate
                nxt = max(int(np.searchsorted(t, t_ok, side="left")),
                          a + 1)
                if nxt - 1 > a:        # roll refill through the run
                    tokens = min(cap, tokens + rate * (t[nxt - 1] - last))
                    last = t[nxt - 1]
                a = nxt
            chunk = _CHUNK0
            continue
        # -- bulk: closed-form Lindley over the next chunk
        b = min(a + chunk, n)
        dt = np.empty(b - a)
        dt[0] = t[a] - last
        np.subtract(t[a + 1:b], t[a:b - 1], out=dt[1:])
        g = 1.0 - rate * np.maximum(dt, 0.0, out=dt)
        s = np.cumsum(g)
        d = s + np.maximum(cap - tokens, np.maximum.accumulate(1.0 - s))
        if bool(np.any(np.abs(d - cap) < 1e-6)):
            tokens, last = _bucket_replay(t, admitted, a, b, rate, cap,
                                          tokens, last)
            a, chunk = b, _CHUNK0
            continue
        bad = d > cap
        v = int(np.argmax(bad)) if bad.any() else -1
        if v < 0:                              # whole chunk admitted
            admitted[a:b] = True
            tokens, last = cap - d[-1], t[b - 1]
            a = b
            chunk = min(chunk * 4, _CHUNK_MAX)
            continue
        admitted[a:a + v] = True               # admit the prefix ...
        if v > 0:
            tokens, last = cap - d[v - 1], t[a + v - 1]
        i = a + v                              # ... reject arrival i (its
        tokens = min(cap, tokens + rate * max(t[i] - last, 0.0))
        last = t[i]                            # refill still happens) and
        a, chunk = i + 1, _CHUNK0              # drop into saturation mode
    st.tokens, st.last_t = tokens, last
    return admitted


def _merge_pending(p: np.ndarray, c: np.ndarray, t_last: float,
                   ) -> np.ndarray:
    """In-flight completions surviving past the last processed arrival:
    the ``<= t_last`` prefix of either sorted array is exactly what the
    scalar replay's pops would have drained by then."""
    keep_p = p[np.searchsorted(p, t_last, side="right"):]
    keep_c = c[np.searchsorted(c, t_last, side="right"):]
    if keep_p.size == 0:
        return np.array(keep_c, dtype=np.float64)
    if keep_c.size == 0:
        return np.array(keep_p, dtype=np.float64)
    return np.sort(np.concatenate([keep_p, keep_c]))


def occupancy_replay(t: np.ndarray, pending: np.ndarray, base_ms: float,
                     slots: float,
                     service_ms_fn: Callable[[int], float],
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact vectorized replay of occupancy-coupled service on one
    edge's admitted arrivals ``t`` (sorted).  ``pending`` is the sorted
    array of in-flight completion times carried over from the previous
    window; ``base_ms`` the flat service (base x interference stretch)
    below ``slots`` occupancy (``LatencyModel.flat_service_slots``);
    ``service_ms_fn(occ)`` the exact scalar service at occupancy
    ``occ`` (used only while oversubscribed).  Returns the per-arrival
    service array and the new pending state.

    Mirrors the :func:`bucket_admissions` design — two regimes,
    switched adaptively over geometrically growing chunks:

      * **bulk (occupancy at most ``slots - 1``)** — every service is
        the same ``base_ms``, so completion times are
        ``t + base_ms/1000`` (the *identical* float add the scalar
        path performs) and the occupancy each arrival observes is two
        ``searchsorted`` counts: carried-over completions still in
        flight plus same-run predecessors not yet done.  The first
        arrival whose hypothesized occupancy reaches ``slots`` — where
        service departs from the base and the recursion genuinely
        couples — cuts the run; everything before it is exact;
      * **oversubscribed** — bulk-served in *runs of constant
        occupancy*: when arrival ``k`` observes occupancy ``L``, the
        replay hypothesizes that the whole next chunk stays at level
        ``L`` — every service is then the same ``service_ms_fn(L)``,
        completions are ``t + s/1000`` (the identical float add the
        scalar heap push performs), and each arrival's occupancy under
        the hypothesis is reconstructed exactly from two
        ``searchsorted`` counts (carried completions still in flight
        plus in-run predecessors not yet done).  The first arrival
        whose reconstructed occupancy differs from ``L`` cuts the run
        — everything before it is exact, and the occupancy *at* the
        cut is also exact, so the replay either drops to bulk
        (occupancy back under ``slots``) or re-buckets at the new
        level.  Cost scales with the number of occupancy-level
        *changes*, not with the number of oversubscribed arrivals.

    Bit-identical to the all-scalar replay by construction: both
    regimes perform the same float operations on the same operands
    (integer occupancy counts are exact), and every cut point is
    decided from exactly reconstructed occupancies."""
    n = t.size
    service = np.empty(n, dtype=np.float64)
    p = np.asarray(pending, dtype=np.float64)
    base_s = base_ms / 1000.0
    rel = np.arange(n, dtype=np.int64)       # chunk index template
    a, chunk = 0, _CHUNK0
    while a < n:
        # -- bulk: hypothesize flat service over the next chunk
        b = min(a + chunk, n)
        tc = t[a:b]
        c = tc + base_s                      # completion times if flat
        # same-run predecessors still in flight ...
        occ = rel[:b - a] - np.searchsorted(c, tc, side="right")
        np.maximum(occ, 0, out=occ)
        if p.size:                           # ... plus carried-over ones
            occ += p.size - np.searchsorted(p, tc, side="right")
        over = occ >= slots                  # service departs from base
        v = int(np.argmax(over)) if over.any() else -1
        if v < 0:                            # whole chunk stays flat
            service[a:b] = base_ms
            p = _merge_pending(p, c, float(tc[-1]))
            a = b
            chunk = min(chunk * 4, _CHUNK_MAX)
            continue
        service[a:a + v] = base_ms           # exact flat prefix ...
        if v > 0:
            p = _merge_pending(p, c[:v], float(tc[v - 1]))
        # ... then level-bucketed replay while oversubscribed: runs of
        # equal occupancy share one service value, so they commit in
        # bulk; the hypothesized occupancies are exact integer counts,
        # and the first level change cuts the run
        k = a + v
        lchunk = _CHUNK0
        while k < n:
            tk = t[k]
            p = p[np.searchsorted(p, tk, side="right"):]   # drain pops
            occ = p.size
            if occ < slots:                  # recovered: back to bulk
                break
            s_k = service_ms_fn(occ)
            sc = s_k / 1000.0
            e = min(k + lchunk, n)
            run_t = t[k:e]
            cr = run_t + sc                  # completions if level holds
            alive = p.size - np.searchsorted(p, run_t, side="right")
            done = np.minimum(np.searchsorted(cr, run_t, side="right"),
                              rel[:e - k])
            occ_run = alive + rel[:e - k] - done
            lvl_break = occ_run != occ       # occ_run[0] == occ always
            w = int(np.argmax(lvl_break)) if lvl_break.any() else e - k
            service[k:k + w] = s_k
            p = _merge_pending(p, cr[:w], float(run_t[w - 1]))
            lchunk = (min(lchunk * 4, _CHUNK_MAX) if w == e - k
                      else _CHUNK0)
            k += w
        a, chunk = k, _CHUNK0
    return service, p


def batched_rtt_draws(rng: np.random.Generator, lat,
                      first_tier: np.ndarray,
                      two_hop: np.ndarray) -> np.ndarray:
    """Network legs for one window, drawn from the *same* generator
    stream the heap path would consume: request k's draws occupy the
    same stream positions as its sequential ``lat.rtt(tier, rng)``
    calls would (``uniform(lo, hi)`` scales exactly one raw double), so
    batched and heap runs stay bit-identical when routing is
    deterministic.

    ``first_tier`` is the per-request tier of the first RTT leg
    (TIER_* code); ``two_hop`` marks requests that pay a second *edge*
    leg (R3 overflow / R2-cloud forwarding)."""
    n = first_tier.size
    if n == 0:
        return np.zeros(0)
    # per-tier (lo, width) gathered through one small LUT indexed by the
    # int8 tier code — one fancy-index pass instead of three masked
    # writes over the window
    lut = np.zeros((3, 2))
    for code, (rlo, rhi) in ((TIER_DEVICE, lat.device_rtt_ms),
                             (TIER_EDGE, lat.edge_rtt_ms),
                             (TIER_CLOUD, lat.cloud_rtt_ms)):
        lut[code, 0] = rlo
        lut[code, 1] = rhi - rlo
    bounds = lut[first_tier]
    any_two_hop = bool(two_hop.any())
    if not any_two_hop:
        # common case (no overflow forwarding in the window): one draw
        # per request, stream positions are just 0..n-1 — skip the
        # cumsum offset bookkeeping entirely
        raw = rng.random(n)
        return bounds[:, 0] + raw * bounds[:, 1]
    ndraw = 1 + two_hop.astype(np.int64)
    off = np.zeros(n, dtype=np.int64)
    np.cumsum(ndraw[:-1], out=off[1:])
    raw = rng.random(int(off[-1] + ndraw[-1]))
    net = bounds[:, 0] + raw[off] * bounds[:, 1]
    e_lo, e_hi = lat.edge_rtt_ms
    second = raw[off[two_hop] + 1]
    net[two_hop] += e_lo + second * (e_hi - e_lo)
    return net


# -- fault-plane retry policy -------------------------------------------


class RetryPolicy:
    """Per-request timeout + capped exponential backoff with jitter,
    shared by both engines' fault-mode scalar core.

    A failed attempt ``k`` (0-based) schedules a retry after
    ``min(backoff_cap_s, base_backoff_s * 2**k) * (1 + jitter * u)``
    with ``u`` one uniform draw from the shared generator stream — the
    only randomness retries consume (contract DET003).  A request
    fails over to the cloud replica (rule ``R4-failover``) once it has
    spent ``max_attempts`` tries or its next retry would land past
    ``timeout_s`` after the original arrival.  ``max_attempts <= 1``
    disables retries entirely (immediate failover); a huge
    ``max_attempts`` + ``timeout_s`` never fails over (requests back
    off until the fault clears) — the no-failover baseline of
    ``benchmarks/perf_faults.py``."""

    __slots__ = ("timeout_s", "base_backoff_s", "backoff_cap_s",
                 "max_attempts", "jitter")

    def __init__(self, timeout_s: float = 2.0,
                 base_backoff_s: float = 0.05,
                 backoff_cap_s: float = 0.8,
                 max_attempts: int = 4,
                 jitter: float = 0.5):
        self.timeout_s = float(timeout_s)
        self.base_backoff_s = float(base_backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.max_attempts = int(max_attempts)
        self.jitter = float(jitter)


def backoff_delay(policy: RetryPolicy, attempt: int, u: float) -> float:
    """Backoff before retry ``attempt + 1`` given one uniform draw
    ``u`` in [0, 1).  Pure float arithmetic — evaluated identically by
    the heap and batched engines."""
    base = min(policy.backoff_cap_s,
               policy.base_backoff_s * float(2 ** attempt))
    return base * (1.0 + policy.jitter * u)
