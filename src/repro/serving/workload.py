"""Inference workload generation: per-device Poisson streams (rate
lambda_i) aggregated into serving batches — the bridge between the
paper's request model and the TPU decode step."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple, Union

import numpy as np


@dataclass
class RequestEvent:
    t: float
    device: int


def poisson_requests(lam: np.ndarray, duration_s: float,
                     seed: Union[int, np.random.Generator] = 0,
                     ) -> List[RequestEvent]:
    """Per-device Poisson arrival streams.  ``seed`` may be an existing
    ``np.random.Generator`` so callers that draw more randomness after
    the arrivals (e.g. the event simulator's routing/RTT draws) share
    one deterministic stream."""
    rng = (seed if isinstance(seed, np.random.Generator)
           else np.random.default_rng(seed))
    events: List[RequestEvent] = []
    for i, rate in enumerate(np.asarray(lam)):
        if rate <= 0:
            continue
        t = 0.0
        while True:
            t += rng.exponential(1.0 / rate)
            if t > duration_s:
                break
            events.append(RequestEvent(t=t, device=i))
    events.sort(key=lambda e: e.t)
    return events


def batched_arrivals(events: List[RequestEvent], batch_size: int,
                     max_wait_s: float = 0.05
                     ) -> Iterator[Tuple[float, np.ndarray]]:
    """Continuous batching: emit a batch when it is full or the oldest
    request has waited ``max_wait_s``.

    A batch whose deadline (oldest arrival + ``max_wait_s``) passes is
    flushed *at that deadline*, before the next event joins — a late
    arrival must open a fresh batch, not ride along with (and further
    delay) one that should already have left."""
    cur: List[RequestEvent] = []
    for ev in events:
        if cur and ev.t - cur[0].t >= max_wait_s:
            yield cur[0].t + max_wait_s, np.asarray([e.device for e in cur])
            cur = []
        cur.append(ev)
        if len(cur) >= batch_size:
            yield ev.t, np.asarray([e.device for e in cur])
            cur = []
    if cur:
        yield cur[0].t + max_wait_s, np.asarray([e.device for e in cur])
