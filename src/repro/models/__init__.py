from repro.models.registry import ModelApi, make_model

__all__ = ["ModelApi", "make_model"]
