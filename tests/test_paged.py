"""Paged serving path: PagePool invariants, paged-vs-dense greedy token
parity across attention families, scheduler end-to-end over a shared page
pool, and the measured occupancy sweep feeding the calibrated latency
model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.routing import LatencyModel
from repro.serving import (ContinuousBatchingScheduler, EngineMeasurement,
                           PagedServeEngine, PagePool, PagesExhausted,
                           Request, ServeEngine)


def _fp32(cfg):
    model = dataclasses.replace(cfg.model, dtype="float32",
                                param_dtype="float32")
    if model.moe is not None:
        model = dataclasses.replace(model, moe=dataclasses.replace(
            model.moe, capacity_factor=float(model.moe.num_experts)))
    return dataclasses.replace(cfg, model=model)


def _cfg_params(arch):
    cfg = _fp32(get_config(arch).reduced())
    api = make_model(cfg)
    params, _ = api.init_params(jax.random.key(0))
    return cfg, params


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------

def test_page_pool_allocate_extend_release():
    pool = PagePool(num_pages=8, page_size=4)
    t0 = pool.allocate(0, 6)                 # 2 pages
    assert len(t0) == 2 and pool.free_pages == 6
    new = pool.extend(0, 9)                  # -> 3 pages (1 new)
    assert len(new) == 1
    assert pool.block_table(0) == t0 + new
    assert pool.length(0) == 9
    t1 = pool.allocate(1, 16)                # 4 pages
    assert pool.free_pages == 1
    assert set(t0 + new).isdisjoint(t1)
    assert not pool.can_allocate(8)          # needs 2, only 1 free
    with pytest.raises(PagesExhausted):
        pool.allocate(2, 8)
    assert pool.release(0) == 3
    assert pool.free_pages == 4
    pool.check_invariants()


def test_page_pool_misuse_raises():
    pool = PagePool(num_pages=4, page_size=4)
    pool.allocate(0, 8)
    with pytest.raises(ValueError):
        pool.allocate(0, 4)                  # seq already allocated
    with pytest.raises(ValueError):
        pool.extend(0, 4)                    # shrink
    with pytest.raises(KeyError):
        pool.release(7)                      # never allocated
    pool.release(0)
    with pytest.raises(KeyError):
        pool.release(0)                      # double release


def test_page_pool_snapshot_restore():
    pool = PagePool(num_pages=8, page_size=4)
    pool.allocate(0, 10)
    state = pool.snapshot()
    pool.allocate(1, 8)
    pool.extend(0, 14)
    pool.restore(state)
    assert pool.sequences == [0]
    assert pool.length(0) == 10
    assert pool.free_pages == 5
    pool.check_invariants()


def test_page_pool_property_churn():
    """Random admit/extend/release churn holds every pool invariant."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.given(st.lists(st.tuples(st.integers(0, 2),
                                         st.integers(0, 7),
                                         st.integers(1, 40)),
                               max_size=60))
    @hypothesis.settings(deadline=None, max_examples=50)
    def run(ops):
        pool = PagePool(num_pages=10, page_size=4)
        live = {}
        for op, seq, n in ops:
            if op == 0 and seq not in live:
                if pool.can_allocate(n):
                    pool.allocate(seq, n)
                    live[seq] = n
                else:
                    with pytest.raises(PagesExhausted):
                        pool.allocate(seq, n)
            elif op == 1 and seq in live and n >= live[seq]:
                try:
                    pool.extend(seq, n)
                    live[seq] = n
                except PagesExhausted:
                    pass
            elif op == 2 and seq in live:
                pool.release(seq)
                del live[seq]
            pool.check_invariants()
        assert pool.sequences == sorted(live)

    run()


# ---------------------------------------------------------------------------
# paged-vs-dense greedy parity (the tentpole's correctness bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["stablelm-1.6b", "h2o-danube-1.8b",
                                  "gemma3-1b", "deepseek-v2-lite-16b",
                                  "qwen2-moe-a2.7b"])
def test_paged_generate_matches_dense(arch):
    """Greedy decode through the paged cache must be token-identical to
    the dense slot engine on every supported attention family (GQA,
    sliding-window, mixed-window gemma3, MLA, MLA+MoE)."""
    cfg, params = _cfg_params(arch)
    dense = ServeEngine(cfg, params, batch_size=2, max_len=64)
    paged = PagedServeEngine(cfg, params, max_seqs=2, page_size=8,
                             max_len=64)
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.model.vocab_size, (2, 13)), jnp.int32)
    out_d = np.asarray(dense.generate(prompt, steps=6))
    out_p = np.asarray(paged.generate(prompt, steps=6))
    np.testing.assert_array_equal(out_p, out_d)


def test_paged_engine_requires_transformer():
    cfg, params = _cfg_params("xlstm-125m")
    with pytest.raises(ValueError, match="paged"):
        PagedServeEngine(cfg, params, max_seqs=2, page_size=8, max_len=64)


def test_double_evict_raises_both_engines():
    cfg, params = _cfg_params("stablelm-1.6b")
    for eng in (ServeEngine(cfg, params, batch_size=2, max_len=32),
                PagedServeEngine(cfg, params, max_seqs=2, page_size=8,
                                 max_len=32)):
        slot = eng.acquire_slot()
        eng.admit(np.arange(5), slot=slot)
        eng.evict(slot)
        with pytest.raises(ValueError, match="already free"):
            eng.evict(slot)


# ---------------------------------------------------------------------------
# scheduler end-to-end over a shared page pool
# ---------------------------------------------------------------------------

def test_scheduler_paged_oversubscribes_dense_rows():
    """The paged engine admits more concurrent sequences than the dense
    engine could hold in the same cache HBM, and the scheduler completes
    every request with the exact dense-engine tokens."""
    cfg, params = _cfg_params("stablelm-1.6b")
    max_len, ps = 32, 8
    # 8 pages = 64 cache tokens = TWO dense rows of max_len
    paged = PagedServeEngine(cfg, params, max_seqs=4, page_size=ps,
                             num_pages=8, max_len=max_len)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.model.vocab_size, (6, 5))
    reqs = [Request(id=k, arrival_s=0.0, prompt=prompts[k],
                    max_new_tokens=3) for k in range(6)]
    sched = ContinuousBatchingScheduler(paged)
    stats = sched.run([dataclasses.replace(r) for r in reqs])
    assert len(sched.completed) == 6
    # each request reserves 1 page: all 4 rows fill despite the pool
    # holding only 2 dense-row equivalents
    assert stats.peak_occupancy == 4
    dense = ServeEngine(cfg, params, batch_size=4, max_len=max_len)
    sched_d = ContinuousBatchingScheduler(dense)
    sched_d.run([dataclasses.replace(r) for r in reqs])
    tok_p = {r.id: r.tokens for r in sched.completed}
    tok_d = {r.id: r.tokens for r in sched_d.completed}
    assert tok_p == tok_d


def test_scheduler_rejects_impossible_request():
    cfg, params = _cfg_params("stablelm-1.6b")
    paged = PagedServeEngine(cfg, params, max_seqs=2, page_size=8,
                             num_pages=2, max_len=32)
    sched = ContinuousBatchingScheduler(paged)
    req = Request(id=0, arrival_s=0.0, prompt=np.arange(20),
                  max_new_tokens=12)           # 32 tokens > 16-token pool
    with pytest.raises(ValueError, match="never be admitted"):
        sched.run([req])


# ---------------------------------------------------------------------------
# measured occupancy sweep -> calibrated latency model
# ---------------------------------------------------------------------------

def test_measure_occupancy_sweep_paged_engine():
    cfg, params = _cfg_params("stablelm-1.6b")
    eng = PagedServeEngine(cfg, params, max_seqs=4, page_size=8,
                           max_len=64)
    m = eng.measure(prompt_len=8, decode_steps=2,
                    occupancy_levels=[1, 2, 4])
    levels = [lvl for lvl, _ in m.occupancy_ms]
    assert levels == [1, 2, 4]
    assert all(ms > 0.0 for _, ms in m.occupancy_ms)
    # the sweep must not leak state: the engine still serves correctly
    assert len(eng.free_slots) == 4
    assert eng.pool.free_pages == eng.pool.num_pages


def test_from_measurements_sweep_interpolation():
    """The calibrated model serves the measured curve: flat below the
    lowest swept level, interpolated between levels, time-shared beyond
    the highest."""
    m = EngineMeasurement(prefill_ms=10.0, decode_ms_per_token=1.0,
                          batch_size=4, prompt_len=8, decode_steps=4,
                          occupancy_ms=((1, 2.0), (4, 4.0)))
    lat = LatencyModel.from_measurements({"edge": m}, decode_tokens=10)
    assert lat.occupancy_dependent("edge")
    assert lat.flat_service_slots("edge") == 1.0
    # service at level c: prefill + 10 tokens * per-step ms
    assert lat.infer_ms("edge", occupancy=0.0) == pytest.approx(30.0)
    assert lat.infer_ms("edge", occupancy=3.0) == pytest.approx(50.0)
    # between levels: linear in concurrency c = occ + 1
    assert lat.infer_ms("edge", occupancy=1.0) == pytest.approx(
        30.0 + 20.0 / 3.0)
    # beyond the sweep: time-share the last measured rate
    assert lat.infer_ms("edge", occupancy=7.0) == pytest.approx(100.0)
    # scalar and array paths are bit-identical (occupancy_replay needs
    # base_service_ms == infer_ms at every occupancy below the boundary)
    occ = np.asarray([0.0, 1.0, 3.0, 7.0])
    arr = lat.infer_ms_array("edge", occ)
    for o, a in zip(occ, arr):
        assert lat.infer_ms("edge", occupancy=o) == a
    assert lat.base_service_ms("edge") == lat.infer_ms("edge", 0.0)
    # tiers without a sweep keep the closed-form stretch
    assert not lat.occupancy_dependent("cloud")


def test_from_measurements_without_sweep_unchanged():
    m = EngineMeasurement(prefill_ms=10.0, decode_ms_per_token=1.0,
                          batch_size=4, prompt_len=8, decode_steps=4)
    lat = LatencyModel.from_measurements({"edge": m}, decode_tokens=10)
    assert lat.tier_sweep == {}
    assert lat.flat_service_slots("edge") == 4.0
    assert lat.infer_ms("edge", occupancy=7.0) == pytest.approx(
        20.0 * 8.0 / 4.0)


# ---------------------------------------------------------------------------
# fault tolerance: admission-failure page release, crash drain + requeue
# ---------------------------------------------------------------------------

def test_admit_failure_releases_pages():
    """When allocate succeeds but prefill raises, the pages go back to
    the pool — repeated failed admissions must not bleed the pool dry."""
    cfg, params = _cfg_params("stablelm-1.6b")
    eng = PagedServeEngine(cfg, params, max_seqs=4, page_size=8,
                           max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 50, 12)
    good_prefill = eng._prefill

    def boom(*a, **k):
        raise RuntimeError("injected prefill failure")

    free0 = eng.pool.free_pages
    eng._prefill = boom
    for _ in range(10):                       # churn: fail, fail, ...
        slot = eng.acquire_slot()
        with pytest.raises(RuntimeError, match="injected"):
            eng.admit(prompt, slot=slot)
        eng.evict(slot)                       # row itself is still held
        assert eng.pool.free_pages == free0   # ... but no page leaked
        eng.pool.check_invariants()
    # pool is whole: a real admission still works at full capacity
    eng._prefill = good_prefill
    slot = eng.acquire_slot()
    eng.admit(prompt, slot=slot, reserve_tokens=4)
    eng.decode()
    eng.evict(slot)
    eng.pool.check_invariants()
    assert eng.pool.free_pages == eng.num_pages


def test_crash_drain_requeues_and_completes():
    """Mid-decode crash: drain releases every page, the scheduler
    requeues the in-flight requests from their prompts, and the finished
    token streams match an uninterrupted run (greedy decode is
    deterministic)."""
    cfg, params = _cfg_params("stablelm-1.6b")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 50, 10) for _ in range(3)]

    def make():
        eng = PagedServeEngine(cfg, params, max_seqs=4, page_size=8,
                               max_len=64)
        sched = ContinuousBatchingScheduler(eng)
        for k, p in enumerate(prompts):
            sched.submit(Request(id=k, arrival_s=0.0, prompt=p,
                                 max_new_tokens=6))
        return eng, sched

    def finish(sched, now):
        while sched.queue or sched.active:
            now = sched._admit_ready(now)
            if sched.active:
                now = sched._decode_once(now)
        return {r.id: list(r.tokens) for r in sched.completed}

    eng, sched = make()
    now = sched._admit_ready(0.0)
    now = sched._decode_once(now)             # two tokens in, then crash
    assert sched.active
    n = sched.requeue_active(now)
    assert n == 3 and not sched.active and sched.requeues == 3
    assert eng.pool.free_pages == eng.num_pages
    eng.pool.check_invariants()
    crashed = finish(sched, now)

    _, fresh = make()
    clean = finish(fresh, 0.0)
    assert crashed == clean
    assert eng.pool.free_pages == eng.num_pages
