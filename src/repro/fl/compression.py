"""Beyond-paper optimization: int8-quantized global aggregation with
error feedback.

The paper's global round ships full-precision models edge->cloud.  On the
TPU mapping the analogous traffic is the cross-pod ("pod"-axis) all-reduce
of parameters every l rounds — the dominant collective-roofline term of
HFL training.  Quantizing the *delta since the last sync* to int8 with a
per-tensor scale cuts those bytes 2x (bf16) to 4x (f32); the residual is
kept locally and re-added next round (error feedback), so the scheme is
unbiased in the long run."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class EFState(NamedTuple):
    anchor: PyTree                   # params at last global sync
    residual: PyTree                 # accumulated quantization error


def init_ef_state(stacked_params: PyTree) -> EFState:
    return EFState(
        anchor=jax.tree.map(lambda x: x.astype(jnp.float32), stacked_params),
        residual=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                              stacked_params),
    )


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_global_sync(stacked: PyTree, ef: EFState,
                           weights: Optional[jax.Array] = None
                           ) -> Tuple[PyTree, EFState]:
    """Global round with int8 delta exchange + error feedback.

    Each cluster quantizes (params - anchor + residual); the mean of the
    dequantized deltas (the only cross-pod communication, int8 payload)
    updates the anchor; every cluster adopts anchor+mean_delta."""
    n = None

    def one(x, a, r):
        delta = x.astype(jnp.float32) - a + r
        # per-cluster quantization (vmap over leading cluster dim)
        q, s = jax.vmap(quantize_int8)(delta)
        dq = jax.vmap(dequantize_int8)(q, s)
        new_r = delta - dq
        if weights is None:
            mean_delta = jnp.mean(dq, axis=0)
        else:
            w = (weights / jnp.sum(weights)).astype(jnp.float32)
            mean_delta = jnp.tensordot(w, dq, axes=(0, 0))
        new_a = a + jnp.broadcast_to(mean_delta[None], a.shape)
        new_x = new_a.astype(x.dtype)
        return new_x, new_a, new_r

    outs = jax.tree.map(one, stacked, ef.anchor, ef.residual)
    istuple = lambda t: isinstance(t, tuple)
    new_params = jax.tree.map(lambda t: t[0], outs, is_leaf=istuple)
    new_anchor = jax.tree.map(lambda t: t[1], outs, is_leaf=istuple)
    new_resid = jax.tree.map(lambda t: t[2], outs, is_leaf=istuple)
    return new_params, EFState(anchor=new_anchor, residual=new_resid)


def compressed_global_sync_shardmap(stacked: PyTree, ef: EFState, mesh,
                                    axis: str = "cluster",
                                    inner_specs: PyTree = None
                                    ) -> Tuple[PyTree, EFState]:
    """int8 global sync with the quantized payload ON THE WIRE.

    The pure-jnp version above dequantizes before the cross-cluster mean,
    so XLA communicates fp32 (measured: no byte reduction — EXPERIMENTS.md
    §Perf exp. 3 iteration 3, refuted).  Here the cluster axis is manual:
    each cluster quantizes its delta locally, ``all_gather``s the *int8*
    tensor (+ one f32 scale) across clusters, then dequantizes and means
    locally — cross-pod bytes drop to ~1 byte/param."""
    import jax.numpy as jnp

    from jax.sharding import NamedSharding, PartitionSpec as P

    def _constrain(t, spec):
        if spec is None:
            return t
        return jax.lax.with_sharding_constraint(
            t, NamedSharding(mesh, spec))

    def body(p, a, r, specs):
        def one(x, av, rv, spec):
            x0, a0, r0 = x[0], av[0], rv[0]
            delta = x0.astype(jnp.float32) - a0 + r0
            q, s = quantize_int8(delta)
            # keep the int8 payload sharded over the auto axes — without
            # this XLA may replicate it before the gather (measured:
            # EXPERIMENTS.md §Perf exp. 3 iteration 4, regression)
            q = _constrain(q, spec)
            qg = jax.lax.all_gather(q, axis)          # int8 over DCI
            sg = jax.lax.all_gather(s, axis)          # scalars
            dq = qg.astype(jnp.float32) * sg.reshape(
                (-1,) + (1,) * (q.ndim))
            mean_delta = jnp.mean(dq, axis=0)
            my = jax.lax.axis_index(axis)
            new_r = delta - dq[my]
            new_a = a0 + mean_delta
            return (new_a.astype(x0.dtype)[None], new_a[None], new_r[None])

        # manual flatten: PartitionSpec is a tuple subclass, so a specs
        # *tree* would be flattened as pytree structure
        leaves_p, treedef = jax.tree_util.tree_flatten(p)
        leaves_a = treedef.flatten_up_to(a)
        leaves_r = treedef.flatten_up_to(r)
        leaves_s = (specs if specs is not None
                    else [None] * len(leaves_p))
        outs = [one(x, av, rv, sp) for x, av, rv, sp in
                zip(leaves_p, leaves_a, leaves_r, leaves_s)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), unf(1), unf(2)

    new_p, new_a, new_r = jax.shard_map(
        lambda p, a, r: body(p, a, r, inner_specs),
        mesh=mesh, in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(axis)),
        axis_names={axis}, check_vma=False,
    )(stacked, ef.anchor, ef.residual)
    return new_p, EFState(anchor=new_a, residual=new_r)


def compressed_global_sync_manual(stacked: PyTree, ef: EFState, mesh,
                                  leaf_specs, axis: str = "cluster"
                                  ) -> Tuple[PyTree, EFState]:
    """Fully-manual int8 global sync: shard_map over EVERY mesh axis, so
    each device works on its true local shard and the cluster-axis
    ``all_gather`` ships exactly its int8 shard bytes over DCI.

    The per-tensor quantization scale is a ``pmax`` over the intra-pod
    axes (cheap ICI scalar reduction).  ``leaf_specs`` = full
    PartitionSpecs (including the leading cluster dim) for every leaf, in
    ``tree_flatten`` order."""
    from jax.sharding import PartitionSpec as P
    all_axes = set(mesh.shape.keys())
    intra = tuple(a for a in mesh.shape if a != axis)

    def body(p, a, r):
        leaves_p, treedef = jax.tree_util.tree_flatten(p)
        leaves_a = treedef.flatten_up_to(a)
        leaves_r = treedef.flatten_up_to(r)

        def one(x, av, rv):
            x0, a0, r0 = x[0], av[0], rv[0]        # local shard
            delta = x0.astype(jnp.float32) - a0 + r0
            local_max = jnp.max(jnp.abs(delta))
            gmax = jax.lax.pmax(local_max, intra)  # intra-pod scalar
            s = jnp.maximum(gmax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(delta / s), -127, 127).astype(jnp.int8)
            qg = jax.lax.all_gather(q, axis)       # int8 shard over DCI
            sg = jax.lax.all_gather(s, axis)
            dq = qg.astype(jnp.float32) * sg.reshape(
                (-1,) + (1,) * q.ndim)
            mean_delta = jnp.mean(dq, axis=0)
            my = jax.lax.axis_index(axis)
            new_r = delta - dq[my]
            new_a = a0 + mean_delta
            return (new_a.astype(x0.dtype)[None], new_a[None], new_r[None])

        outs = [one(x, av, rv) for x, av, rv in
                zip(leaves_p, leaves_a, leaves_r)]
        unf = lambda i: jax.tree_util.tree_unflatten(
            treedef, [o[i] for o in outs])
        return unf(0), unf(1), unf(2)

    specs = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(jax.tree.map(lambda _: 0, stacked)),
        list(leaf_specs))
    new_p, new_a, new_r = jax.shard_map(
        body, mesh=mesh, in_specs=(specs, specs, specs),
        out_specs=(specs, specs, specs),
        axis_names=all_axes, check_vma=False,
    )(stacked, ef.anchor, ef.residual)
    return new_p, EFState(anchor=new_a, residual=new_r)


def sync_bytes(stacked: PyTree, compressed: bool) -> int:
    """Cross-pod payload per global round (for the cost accounting)."""
    total = 0
    for x in jax.tree.leaves(stacked):
        per = x.size // x.shape[0]
        total += per * (1 if compressed else x.dtype.itemsize)
    return total
