"""Scenario engine on the co-simulation event core.

A :class:`Scenario` is a deterministic event-injection recipe — it
schedules typed perturbations (stragglers, device mobility, tenant
jobs, node failures, drift) onto a freshly built :class:`CoSim` and
nothing else, so the same scenario composes with any policy:

  static    no reactive loop — the initial deployment rides it out
  reactive  unconstrained reactive loop (PR 2 behavior)
  budgeted  reactive loop metered by a :class:`ReconfigBudget` —
            optional reclusterings are deferred once the modeled
            migration spend hits the cap

:func:`run_scenario` wires the standard hot-zone continuum (the Fig. 7
setup: 20 devices, 4 edges, one hot cluster) through inventory ->
controller -> reactive loop -> CoSim, injects the scenario, runs it,
and summarizes latency, training progress and budget spend.  Every
piece of randomness flows through generators seeded from the scenario
seed, so a (scenario, policy, seed) triple reproduces its event trace
bit-for-bit — asserted by :meth:`ScenarioResult.fingerprint` in the
tests and the ``perf_scenarios`` benchmark grid.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import ClusterTopology
from repro.routing.latency import LatencyModel
from repro.routing.simulator import RequestLog
from repro.fl.schedule import round_schedule
from repro.orchestration import Inventory, LearningController
from repro.orchestration.controller import Deployment
from repro.sim.budget import ReconfigBudget
from repro.sim.cosim import CoSim, CoSimConfig
from repro.sim.events import control_trace
from repro.sim.faults import (DomainOutagePlan, DropBurstPlan,
                              EdgeOutagePlan, FaultPlan, PartitionPlan)
from repro.sim.reactive import ReactiveLoop, ReactivePolicy

POLICIES = ("static", "reactive", "budgeted")


@dataclass(frozen=True)
class Scenario:
    """A named, deterministic perturbation recipe over a built CoSim."""
    name: str
    description: str
    inject: Callable[[CoSim], None]


@dataclass
class ScenarioResult:
    name: str
    policy: str
    seed: int
    p50: float
    p95: float
    p99: float
    mean_ms: float
    n_requests: int
    rounds_completed: int
    reclusters: int
    budget_total: float
    budget_spent: float
    budget_vetoes: int
    drops: int                       # straggler devices dropped from rounds
    moves: int                       # device handovers executed
    actions: List[Tuple[float, str]]
    trace: List[Tuple[float, str, int]]
    log: RequestLog                  # full request log (timeline plots)

    def fingerprint(self) -> str:
        """Digest of the full event trace + per-request latencies —
        two runs of the same (scenario, policy, seed) must match."""
        h = hashlib.sha256()
        for t, kind, node in self.trace:
            h.update(f"{t!r}|{kind}|{node};".encode())
        h.update(np.ascontiguousarray(self.log.latency_ms).tobytes())
        for t, a in self.actions:
            h.update(f"{t!r}|{a};".encode())
        return h.hexdigest()

    def control_fingerprint(self) -> str:
        """Digest of the *control-plane* trace (request arrivals /
        completions stripped) + per-request latencies + reactive
        actions.  The heap ("parity") engine and the batched engine
        must agree on this bit-for-bit for the same (scenario, policy,
        seed) — the batched engine never materializes request events,
        so the full trace is engine-specific but the control plane is
        not."""
        h = hashlib.sha256()
        for t, kind, node in control_trace(self.trace):
            h.update(f"{t!r}|{kind}|{node};".encode())
        h.update(np.ascontiguousarray(self.log.latency_ms).tobytes())
        for t, a in self.actions:
            h.update(f"{t!r}|{a};".encode())
        return h.hexdigest()


# ---------------------------------------------------------------------------
# the standard continuum the scenarios perturb
# ---------------------------------------------------------------------------

def hot_zone_topology(seed: int = 0, n: int = 20, m: int = 4,
                      hot: float = 3.0, slack: float = 1.35,
                      ) -> Tuple[ClusterTopology, np.ndarray, np.ndarray,
                                 np.ndarray]:
    """The Fig. 7 hot-zone continuum: location clusters with one zone's
    request load inflated by ``hot``x.  When ``m`` does not divide
    ``n``, the first zones absorb the remainder (contiguous zones
    either way; the divisible case matches the Fig. 7 draws exactly)."""
    rng = np.random.default_rng(seed)
    loc = np.repeat(np.arange(m), -(-n // m))[:n]
    lam = rng.uniform(2.0, 4.0, n)
    lam[loc == 0] *= hot
    r = np.full(m, lam.sum() / m * slack)
    topo = ClusterTopology(assign=loc.copy(), n_devices=n, n_edges=m,
                           lam=lam, r=r, l=2)
    return topo, loc, lam, r


def continuum_topology(seed: int = 0, n: int = 200, m: int = 8,
                       capacity_slack: float = 1.3, l: int = 2,
                       T: Optional[int] = None,
                       ) -> Tuple[ClusterTopology, np.ndarray, np.ndarray,
                                  np.ndarray]:
    """A paper-cost continuum whose initial deployment comes from the
    decomposed HFLOP solver instead of the hand-built zone assignment —
    the scenario grid perturbs a topology the solver actually produced,
    at any scale (the LAN instance never materializes an (n, m) cost
    matrix).  Same return shape as :func:`hot_zone_topology`:
    (topology, LAN edge per device, rates, capacities)."""
    from repro.core.partition import paper_cost_lan
    from repro.core.solvers import solve_decomposed
    inst = paper_cost_lan(n, m, seed=seed, l=l,
                          capacity_slack=capacity_slack)
    if T is not None:
        inst = type(inst)(free=inst.free, c_e=inst.c_e, lam=inst.lam,
                          r=inst.r, unit_cost=inst.unit_cost, l=inst.l,
                          T=T)
    sol = solve_decomposed(inst)
    topo = ClusterTopology(assign=np.asarray(sol.assign, int),
                           n_devices=n, n_edges=m, lam=inst.lam,
                           r=inst.r, l=inst.l)
    return topo, inst.free.copy(), inst.lam, inst.r


def continual_training(duration_s: float, l: int = 2,
                       ) -> Sequence:
    """Back-to-back HFL rounds covering the horizon (continual
    learning), the same shape the co-sim benchmarks use."""
    rounds = max(int(duration_s / 20.0), 1)
    return round_schedule(rounds=rounds, l=l, local_epochs=5, epoch_s=3.5,
                          upload_s=2.0, gap_s=2.0)


# ---------------------------------------------------------------------------
# scenario recipes
# ---------------------------------------------------------------------------

def baseline_scenario() -> Scenario:
    return Scenario("baseline", "training-inference interference only, "
                    "no extra perturbations", lambda cosim: None)


def straggler_scenario(times: Sequence[float] = (5.0, 27.0, 48.0),
                       devices: Sequence[int] = (0, 5, 1),
                       factor: float = 4.0) -> Scenario:
    """Devices slow down mid-round (thermal throttling / co-located
    jobs); the reactive drop policy enforces the round deadline."""
    def inject(cosim: CoSim) -> None:
        for t, i in zip(times, devices):
            if t < cosim.cfg.duration_s and i < cosim.proc.topo.n_devices:
                cosim.schedule_straggler(t, i, factor)
    return Scenario("straggler",
                    f"devices {tuple(devices)} slow {factor}x mid-round; "
                    "deadline-based drop", inject)


def mobility_scenario(moves: Sequence[Tuple[float, int, int]] = (
        (25.0, 7, 0), (55.0, 12, 0), (85.0, 17, 0)),
        ) -> Scenario:
    """Devices hand over between LAN edges mid-simulation — by default
    *into* the already-hot zone, compounding its overload — each paying
    the modeled handover cost; the reactive loop re-clusters around the
    new cost structure, budget permitting."""
    def inject(cosim: CoSim) -> None:
        m = cosim.proc.topo.n_edges
        for t, i, j in moves:
            if (t < cosim.cfg.duration_s
                    and i < cosim.proc.topo.n_devices and j < m):
                cosim.schedule_device_move(t, i, j)
    return Scenario("mobility",
                    f"{len(tuple(moves))} device handovers between LAN "
                    "edges (with handover cost)", inject)


def _edge_anchors(m: int) -> np.ndarray:
    """LAN edge anchor points: cell centers of the smallest square grid
    covering ``m`` edges in the unit square.  Deterministic in ``m``
    alone, so the spatial meaning of "edge j" is stable across seeds."""
    g = math.ceil(math.sqrt(m))
    centers = [((i % g + 0.5) / g, (i // g + 0.5) / g) for i in range(m)]
    return np.asarray(centers[:m], dtype=float)


def random_waypoint_moves(n: int, m: int, duration_s: float,
                          seed: int = 0,
                          speed: Tuple[float, float] = (0.005, 0.02),
                          pause_s: float = 5.0,
                          sample_dt: float = 1.0,
                          ) -> List[Tuple[float, int, int]]:
    """Random-waypoint mobility trace as a DEVICE_MOVE event list.

    Devices live in the unit square; each repeatedly picks a uniform
    waypoint and walks there at a uniform speed (fraction of the square
    per second), pausing ``pause_s`` between legs — the classic random
    waypoint model.  A device is associated with its nearest LAN edge
    anchor (:func:`_edge_anchors`); whenever the nearest edge changes
    at a ``sample_dt`` boundary, a ``(t, device, new_edge)`` handover
    is emitted, directly consumable by :func:`mobility_scenario`.

    All randomness comes from ``np.random.default_rng(seed)`` drawn in
    a fixed per-device order, so the trace is bit-reproducible
    (contract DET001): same arguments, same moves.
    """
    if n <= 0 or m <= 0 or duration_s <= 0:
        return []
    rng = np.random.default_rng(seed)
    anchors = _edge_anchors(m)

    def nearest(p: np.ndarray) -> int:
        d2 = ((anchors - p) ** 2).sum(axis=1)
        return int(np.argmin(d2))

    moves: List[Tuple[float, int, int]] = []
    for dev in range(n):
        pos = rng.uniform(0.0, 1.0, 2)
        edge = nearest(pos)
        t = 0.0
        next_sample = sample_dt
        while t < duration_s:
            target = rng.uniform(0.0, 1.0, 2)
            v = rng.uniform(speed[0], speed[1])
            leg = float(np.linalg.norm(target - pos))
            leg_end = t + leg / max(v, 1e-12)
            direction = (target - pos) / max(leg, 1e-12)
            # sample the walk at dt boundaries; handovers fire there
            while next_sample <= min(leg_end, duration_s):
                p = pos + direction * v * (next_sample - t)
                e = nearest(p)
                if e != edge:
                    moves.append((next_sample, dev, e))
                    edge = e
                next_sample += sample_dt
            pos = target
            t = leg_end + pause_s
            next_sample = max(next_sample,
                              math.floor(t / sample_dt) * sample_dt
                              + sample_dt)
    moves.sort()
    return moves


def multi_tenant_scenario(job_rate_per_edge: float = 1.0 / 25.0,
                          share: float = 0.45,
                          mean_duration_s: float = 8.0,
                          seed_offset: int = 7919) -> Scenario:
    """Co-located third-party workloads: each edge receives its own
    Poisson stream of tenant jobs, each claiming ``share`` of the edge's
    compute for an exponential duration — extra interference-model
    demand sources that serving (and aggregation) must time-share
    with.  Drawn from a child generator of the co-sim seed, so the
    stream is deterministic and does not perturb the co-sim's own
    draws."""
    def inject(cosim: CoSim) -> None:
        rng = np.random.default_rng(cosim.cfg.seed + seed_offset)
        horizon = cosim.cfg.duration_s
        tid = 0
        for j in sorted(cosim.proc.edges):
            t = 0.0
            while True:
                t += rng.exponential(1.0 / job_rate_per_edge)
                if t >= horizon:
                    break
                dur = rng.exponential(mean_duration_s)
                cosim.schedule_tenant_load(t, j, share, duration_s=dur,
                                           tenant=f"{j}.{tid}")
                tid += 1
    return Scenario("multi_tenant",
                    f"Poisson tenant jobs per edge ({share:.0%} share, "
                    f"~{mean_duration_s:.0f}s each)", inject)


def churn_scenario(drift_t: float = 30.0,
                   straggler: Tuple[float, int, float] = (22.0, 0, 4.0),
                   move: Tuple[float, int, int] = (50.0, 7, 2),
                   ) -> Scenario:
    """Everything at once — drift, a straggler and a handover on top of
    the tenant stream — the regime where an unmetered reactive loop
    overspends on migrations and the budget has to ration them."""
    tenants = multi_tenant_scenario()

    def inject(cosim: CoSim) -> None:
        tenants.inject(cosim)
        if drift_t < cosim.cfg.duration_s:
            cosim.schedule_drift(drift_t)
        t, i, f = straggler
        if t < cosim.cfg.duration_s:
            cosim.schedule_straggler(t, i, f)
        t, i, j = move
        if t < cosim.cfg.duration_s and j < cosim.proc.topo.n_edges:
            cosim.schedule_device_move(t, i, j)
    return Scenario("churn", "drift + straggler + handover + tenant "
                    "jobs (budget stress)", inject)


def outage_scenario(mttf_s: float = 18.0, mttr_s: float = 5.0,
                    edges: Tuple[int, ...] = (0,),
                    partition_edges: Tuple[int, ...] = (1,),
                    quorum: float = 0.5,
                    plan: Optional[FaultPlan] = None,
                    standby: bool = True) -> Scenario:
    """Edge/aggregator crash-and-recover chaos: ``edges`` cycle through
    exponential MTTF/MTTR *crash* outages — absorbed by warm-standby
    aggregator promotion, which re-homes their devices before any
    request can fail — while ``partition_edges`` cycle through
    *partition* outages the standby machinery cannot see (the host is
    up but unreachable), so their R1/R3 traffic exercises the retry +
    cloud-failover path.  The round machinery enforces the
    participation quorum throughout.  Pass ``plan`` to substitute any
    composed :class:`~repro.sim.faults.FaultPlan`."""
    def inject(cosim: CoSim) -> None:
        p = plan
        if p is None:
            p = EdgeOutagePlan(mttf_s=mttf_s, mttr_s=mttr_s,
                               edges=tuple(edges))
            if partition_edges:
                # anchored inside round *compute* spans, not horizon
                # fractions or a renewal draw: a partitioned edge only
                # strands traffic while its devices are busy training
                # (idle devices serve R2-local), so the retry/failover
                # path must be exercised where devices are computing —
                # and the schedule is a pure function of the horizon,
                # so this stays deterministic at any grid duration
                T = cosim.cfg.duration_s
                spans = [(w.start, min(w.compute_end, T))
                         for w in continual_training(
                             T, l=cosim.proc.topo.l)
                         if w.start < T]
                anchors = (spans[0],) if len(spans) == 1 else (
                    spans[0], spans[-1])
                wins = []
                for s0, s1 in anchors:
                    c = s1 - s0
                    wins.append((s0 + 0.25 * c, s0 + 0.60 * c))
                p = p + PartitionPlan(windows_s=tuple(wins),
                                      edges=tuple(partition_edges))
        cosim.schedule_faults(p, standby=standby, quorum=quorum)
    return Scenario("outage",
                    f"edge crash/recover cycles (MTTF {mttf_s:.0f}s, "
                    f"MTTR {mttr_s:.0f}s) with retry + cloud failover",
                    inject)


def domain_outage_scenario(mttf_s: float = 25.0, mttr_s: float = 6.0,
                           quorum: float = 0.5) -> Scenario:
    """Correlated failure domains (paired edges sharing an uplink) go
    dark together, composed with a request-drop burst stream — the
    regime that stresses quorum aggregation and standby promotion
    hardest."""
    def inject(cosim: CoSim) -> None:
        m = cosim.proc.topo.n_edges
        doms = tuple((j, j + 1) for j in range(0, m - 1, 2))
        if not doms:
            doms = ((0,),)
        # burst cadence scaled to the horizon so short grid cells still
        # see at least a couple of drop windows in expectation
        T = cosim.cfg.duration_s
        p = (DomainOutagePlan(domains=doms, mttf_s=mttf_s, mttr_s=mttr_s)
             + DropBurstPlan(p_drop=0.25, every_s=max(T / 5.0, 1.0),
                             burst_s=max(T / 10.0, 0.5)))
        cosim.schedule_faults(p, quorum=quorum)
    return Scenario("domain_outage",
                    "correlated LAN-domain outages + request-drop "
                    "bursts (quorum + standby stress)", inject)


SCENARIOS: Dict[str, Callable[[], Scenario]] = {
    "baseline": baseline_scenario,
    "straggler": straggler_scenario,
    "mobility": mobility_scenario,
    "multi_tenant": multi_tenant_scenario,
    "churn": churn_scenario,
    "outage": outage_scenario,
    "domain_outage": domain_outage_scenario,
}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def default_budget_total(m: int = 4, reconfigs: int = 2,
                         cfg: Optional[CoSimConfig] = None) -> float:
    """A budget worth ``reconfigs`` full-continuum migrations — the
    knob the benchmark grid sweeps."""
    cfg = cfg if cfg is not None else CoSimConfig()
    return cfg.reconfig_s * cfg.interference.migration_share * m * reconfigs


def run_scenario(scenario: Scenario, policy: str = "reactive",
                 seed: int = 0, duration_s: float = 120.0,
                 budget_total: Optional[float] = None,
                 n: int = 20, m: int = 4, hot: float = 3.0,
                 slack: float = 1.35, training: bool = True,
                 p95_threshold_ms: float = 20.0,
                 rx_policy: Optional[ReactivePolicy] = None,
                 engine: str = "batched",
                 latency: Optional[LatencyModel] = None,
                 fuse_windows: bool = True,
                 topology: Optional[Tuple[ClusterTopology, np.ndarray,
                                          np.ndarray, np.ndarray]] = None,
                 telemetry=None,
                 ) -> ScenarioResult:
    """One (scenario, policy, seed) cell of the grid.  ``engine``
    picks the request plane ("batched", default) or the per-request
    heap path ("heap") — the two produce bit-identical results here
    (``ScenarioResult.control_fingerprint``), heap just pays two heap
    events per request.  ``fuse_windows=False`` flushes the request
    plane at every control event (the pre-fusion behavior, same
    results); ``latency`` overrides the latency model (e.g. a
    ``CalibratedLatencyModel`` for occupancy-coupled serving);
    ``topology`` substitutes a pre-built continuum — e.g.
    :func:`continuum_topology`'s solver-produced deployment — for the
    default hot-zone draw (``n``/``m``/``hot``/``slack`` are then
    ignored); ``telemetry`` attaches a ``repro.telemetry.Telemetry``
    sink (metrics / control-plane spans / decision audit) — pure
    observation, the result and its fingerprints are bit-identical
    with or without it."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; pick from {POLICIES}")
    topo, loc, lam, r = (topology if topology is not None
                         else hot_zone_topology(seed=seed, n=n, m=m,
                                                hot=hot, slack=slack))
    cfg_kwargs = {} if latency is None else {"latency": latency}
    cfg = CoSimConfig(duration_s=duration_s, seed=seed, engine=engine,
                      fuse_windows=fuse_windows, telemetry=telemetry,
                      **cfg_kwargs)
    sched = continual_training(duration_s, l=topo.l) if training else None

    reactive, budget, ctl = None, None, None
    if policy != "static":
        ctl = LearningController(
            inventory=Inventory.from_arrays(lam, r, lan_edge=loc), l=topo.l)
        ctl.deployment = Deployment.from_topology(topo)
        reactive = ReactiveLoop(
            ctl, policy=rx_policy if rx_policy is not None
            else ReactivePolicy(p95_threshold_ms=p95_threshold_ms))
        if policy == "budgeted":
            budget = ReconfigBudget(
                total=budget_total if budget_total is not None
                else default_budget_total(m=m, cfg=cfg))

    cosim = CoSim(topo, cfg, schedule=sched, reactive=reactive,
                  budget=budget)
    scenario.inject(cosim)
    res = cosim.run()

    log = res.log
    return ScenarioResult(
        name=scenario.name, policy=policy, seed=seed,
        p50=log.percentile_latency(50), p95=log.percentile_latency(95),
        p99=log.percentile_latency(99), mean_ms=log.mean_latency(),
        n_requests=int(log.t.size),
        rounds_completed=res.rounds_completed,
        reclusters=ctl.recluster_count if ctl is not None else 0,
        budget_total=budget.total if budget is not None else math.inf,
        budget_spent=budget.spent if budget is not None else 0.0,
        budget_vetoes=budget.vetoes if budget is not None else 0,
        drops=len(res.drop_log), moves=len(res.move_log),
        actions=res.actions, trace=res.trace, log=log)


# ---------------------------------------------------------------------------
# parallel grid runner
# ---------------------------------------------------------------------------

def _grid_cell(item: Tuple[str, str, Dict, bool],
               ) -> Tuple[str, str, ScenarioResult, Optional[bool]]:
    """One picklable grid cell: scenarios are rebuilt by *name* inside
    the worker (their ``inject`` closures don't pickle), run, and
    optionally re-run for the determinism fingerprint check."""
    sc_name, policy, kwargs, check = item
    res = run_scenario(SCENARIOS[sc_name](), policy=policy, **kwargs)
    det: Optional[bool] = None
    if check:
        rerun = run_scenario(SCENARIOS[sc_name](), policy=policy, **kwargs)
        det = res.fingerprint() == rerun.fingerprint()
    return sc_name, policy, res, det


def run_grid(scenario_names: Sequence[str],
             policies: Sequence[str] = POLICIES, *,
             jobs: int = 1, check_determinism: bool = False,
             **kwargs) -> Dict[Tuple[str, str],
                               Tuple[ScenarioResult, Optional[bool]]]:
    """The scenario x policy grid, optionally over a process pool.

    Cells are independent by construction (every run seeds its own
    generators from the cell's seed), so ``jobs > 1`` fans them out
    with ``ProcessPoolExecutor`` — results come back in deterministic
    (scenario, policy) order either way, and ``check_determinism=True``
    re-runs each cell *inside its worker* and compares event-trace
    fingerprints.  Extra ``kwargs`` go to :func:`run_scenario`
    verbatim.  Returns ``{(scenario, policy): (result, det_ok)}`` with
    ``det_ok`` None when the check is off."""
    items = [(sc, pol, kwargs, check_determinism)
             for sc in scenario_names for pol in policies]
    if jobs <= 1 or len(items) <= 1:
        results = [_grid_cell(it) for it in items]
    else:
        from concurrent.futures import ProcessPoolExecutor
        with ProcessPoolExecutor(max_workers=min(jobs, len(items))) as ex:
            results = list(ex.map(_grid_cell, items))
    return {(sc, pol): (res, det) for sc, pol, res, det in results}
