"""Fused softmax + top-k MoE router Pallas kernel.

One VMEM pass per token block: softmax over experts then k iterative
argmax+mask rounds (k <= 8 for the assigned MoE archs), avoiding the
separate softmax materialization + sort of the XLA path."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(logits_ref, w_ref, i_ref, *, k: int):
    x = logits_ref[...].astype(jnp.float32)           # (bt, E)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    bt, E = probs.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    cur = probs
    for j in range(k):
        best = jnp.max(cur, axis=-1)
        arg = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        w_ref[:, j] = best.astype(w_ref.dtype)
        i_ref[:, j] = arg
        cur = jnp.where(cols == arg[:, None], -1.0, cur)


@functools.partial(jax.jit, static_argnames=("k", "bt", "interpret"))
def topk_router(logits: jax.Array, k: int, *, bt: int = 1024,
                interpret: bool = True):
    """logits (T,E) -> (weights (T,k) f32, idx (T,k) i32)."""
    T, E = logits.shape
    bt = min(bt, T)
    assert T % bt == 0
    kernel = functools.partial(_router_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(T // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
