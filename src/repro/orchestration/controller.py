"""The HFL-specific service orchestrator (paper §III, Fig. 1):

  learning controller  — solves HFLOP, produces a deployment, monitors the
                         pipeline and re-clusters on environment events
  inference controller — deploys an inference service + routing agent per
                         node, monitors serving accuracy, and triggers a
                         new HFL task when accuracy degrades
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, List, Optional, Sequence

import numpy as np

from repro.core.hflop import HFLOPInstance, HFLOPSolution, is_feasible
from repro.core.solvers import solve_bnb, solve_decomposed, solve_heuristic
from repro.core.topology import ClusterTopology
from repro.orchestration.gpo import Inventory
from repro.telemetry.tracer import wall_clock

if TYPE_CHECKING:   # deployments without serving tiers never import jax
    from repro.serving.replica import ReplicaPool, TierSpec


@dataclass
class Deployment:
    """The containerized deployment the GPO would realize: one aggregator
    service per open edge, one client + inference service + routing agent
    per participating device — plus the tiered serving replicas HFL
    leaves behind "for free" (one model copy per tier)."""
    topology: ClusterTopology
    aggregator_nodes: List[int]
    client_nodes: List[int]
    inference_services: List[str]
    replica_pool: Optional["ReplicaPool"] = None
    created_at: float = field(default_factory=wall_clock)

    @classmethod
    def from_topology(cls, topo: ClusterTopology,
                      serving_tiers: Optional[Sequence["TierSpec"]] = None,
                      ) -> "Deployment":
        aggs = [int(j) for j in topo.open_edges]
        clients = [int(i) for i in np.nonzero(topo.assign >= 0)[0]]
        services = ([f"aggregator/edge-{j}" for j in aggs]
                    + [f"inference/edge-{j}" for j in aggs]
                    + [f"client/device-{i}" for i in clients]
                    + [f"routing-agent/device-{i}" for i in clients]
                    + ["aggregator/global", "inference/global"])
        pool = None
        if serving_tiers is not None:
            from repro.serving.replica import ReplicaPool
            pool = ReplicaPool(serving_tiers)
            services += [f"replica/{t}" for t in pool.tiers]
        return cls(topology=topo, aggregator_nodes=aggs,
                   client_nodes=clients, inference_services=services,
                   replica_pool=pool)

    def calibrated_latency(self, decode_tokens: int = 0, **kwargs):
        """Measure this deployment's replicas and return a
        ``CalibratedLatencyModel`` for the routing simulator (the
        serving -> simulation bridge)."""
        from repro.routing.latency import LatencyModel
        if self.replica_pool is None:
            raise ValueError("deployment has no replica pool "
                             "(pass serving_tiers to deploy())")
        return LatencyModel.from_measurements(
            self.replica_pool.measure(), decode_tokens=decode_tokens,
            **kwargs)


@dataclass
class LearningController:
    inventory: Inventory
    l: int = 2
    T: Optional[int] = None
    exact: bool = False              # exact B&B vs heuristic clustering
    decompose_above: int = 5000      # inventories at/above this size go
    #                                  through the decomposed solver
    accuracy_threshold: float = 0.06 # MSE above this triggers retraining
    serving_tiers: Optional[Sequence["TierSpec"]] = None  # None -> no pool
    deployment: Optional[Deployment] = None
    solution: Optional[HFLOPSolution] = None
    recluster_count: int = 0

    def _solve(self, inst: HFLOPInstance) -> HFLOPSolution:
        if self.exact:
            return solve_bnb(inst)
        if inst.n >= self.decompose_above:
            return solve_decomposed(inst)
        return solve_heuristic(inst)

    def cluster(self) -> ClusterTopology:
        inst = self.inventory.to_instance(l=self.l, T=self.T)
        reliable = np.asarray([d.reliable for d in self.inventory.devices],
                              bool)
        if reliable.all():
            sol = self._solve(inst)
            if not is_feasible(inst, sol.assign):
                raise RuntimeError("clustering produced infeasible topology")
            self.solution = sol
            return ClusterTopology.from_solution(inst, sol)
        # solve over the reliable subset only: persistently
        # deadline-missing devices keep serving inference but no longer
        # gate training rounds (assign stays -1 -> the router treats
        # them like any non-participant)
        idx = np.nonzero(reliable)[0]
        sub = HFLOPInstance(inst.c_d[idx], inst.c_e, inst.lam[idx],
                            inst.r, l=inst.l,
                            T=(min(self.T, int(idx.size))
                               if self.T is not None else None))
        sub_sol = self._solve(sub)
        if not is_feasible(sub, sub_sol.assign):
            raise RuntimeError("clustering produced infeasible topology")
        assign = np.full(inst.n, -1, int)
        assign[idx] = sub_sol.assign
        self.solution = HFLOPSolution(
            assign, sub_sol.cost, optimal=sub_sol.optimal,
            solver=sub_sol.solver,
            meta=dict(sub_sol.meta, reliable_devices=int(idx.size)))
        return ClusterTopology(assign=assign, n_devices=inst.n,
                               n_edges=inst.m, lam=inst.lam, r=inst.r,
                               l=inst.l)

    def deploy(self) -> Deployment:
        topo = self.cluster()
        self.deployment = Deployment.from_topology(
            topo, serving_tiers=self.serving_tiers)
        return self.deployment

    # -- reactions to environment / service events (paper §III last para) --

    def drop_edge(self, edge_id: int) -> None:
        """Remove a dead edge from the inventory.  Edge ids above the
        removed one shift down by one, so device ``lan_edge`` references
        must be remapped the same way — only the dead edge's devices
        lose their LAN edge."""
        self.inventory.edges = [e for e in self.inventory.edges
                                if e.id != edge_id]
        for k, e in enumerate(self.inventory.edges):
            e.id = k
        for d in self.inventory.devices:
            if d.lan_edge is None:
                continue
            if d.lan_edge == edge_id:
                d.lan_edge = None
            elif d.lan_edge > edge_id:
                d.lan_edge -= 1

    def on_node_failure(self, edge_id: int,
                        redeploy: bool = True) -> Optional[Deployment]:
        """An edge host died: drop it from the inventory and re-cluster.
        ``redeploy=False`` records the loss without solving — the
        reactive loop uses it when a reconfiguration budget defers the
        re-deploy (the stale topology keeps serving meanwhile)."""
        self.drop_edge(edge_id)
        if not redeploy:
            return None
        self.recluster_count += 1
        return self.deploy()

    def on_capacity_change(self, edge_id: int, new_rps: float) -> Deployment:
        self.inventory.edges[edge_id].capacity_rps = new_rps
        self.recluster_count += 1
        return self.deploy()

    def on_device_move(self, device_id: int, new_edge: Optional[int],
                       redeploy: bool = True) -> Optional[Deployment]:
        """A device handed over to a different LAN edge (mobility):
        update its zero-cost association and, unless ``redeploy`` is
        False (budget-deferred or inside the recluster cooldown),
        re-solve HFLOP around the new cost structure."""
        self.inventory.devices[device_id].lan_edge = new_edge
        if not redeploy:
            return None
        self.recluster_count += 1
        return self.deploy()

    def on_unreliable_devices(self, device_ids: Sequence[int],
                              redeploy: bool = True
                              ) -> Optional[Deployment]:
        """Persistent stragglers: mark them unreliable so the next
        clustering excludes them from training, and (unless the budget
        defers it) re-solve HFLOP over the reliable subset right away."""
        for i in device_ids:
            self.inventory.devices[int(i)].reliable = False
        if not redeploy:
            return None
        self.recluster_count += 1
        return self.deploy()

    def on_accuracy_alarm(self, mse: float) -> bool:
        """Inference controller hook: True -> trigger a new HFL task."""
        return mse > self.accuracy_threshold
