"""Shared benchmark utilities: timing, CSV emission, and a JSON
results registry so CI can record the perf trajectory as an artifact
(``benchmarks/run.py --json BENCH_cosim.json``)."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional

#: every ``emit`` lands here too; ``write_json`` snapshots it.
RESULTS: List[Dict[str, object]] = []


def time_us(fn: Callable, *args, repeats: int = 5, warmup: int = 1,
            **kwargs) -> float:
    for _ in range(warmup):
        fn(*args, **kwargs)
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn(*args, **kwargs)
    return (time.perf_counter() - t0) / repeats * 1e6


def _derived_fields(derived: str) -> Dict[str, object]:
    """Parse the ``k=v;k=v`` derived string, keeping numeric values as
    numbers (so the JSON artifact is machine-comparable across runs)."""
    out: Dict[str, object] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    row: Dict[str, object] = {"name": name,
                              "us_per_call": float(us_per_call)}
    row.update(_derived_fields(derived))
    RESULTS.append(row)


def write_json(path: str) -> None:
    """Snapshot every emitted benchmark row to ``path`` as
    ``{name: {us_per_call, ...derived fields...}}`` — the perf record
    CI uploads (``requests_per_s`` rows carry the event-engine
    throughput the soft floor in ``scripts/ci.sh`` checks)."""
    payload = {}
    for row in RESULTS:
        payload[str(row["name"])] = {k: v for k, v in row.items()
                                     if k != "name"}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
