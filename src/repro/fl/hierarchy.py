"""Continual hierarchical FL runner — reproduces the paper's §V-B2
experiments (Fig. 6): 20 clients, 4 clusters, 5 local epochs per round,
2 local aggregations per global aggregation, sliding continual-learning
window; per-client validation MSE recorded right after the client
receives the (cluster/global) model."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.topology import ClusterTopology
from repro.data.traffic import (TrafficDataset, continual_split,
                                windows_for_sensor)
from repro.fl.aggregation import cluster_fedavg, fedavg, global_fedavg
from repro.fl.client import (ClientBatch, eval_clients, stack_clients,
                             train_clients_locally)
from repro.models import gru


def _even_indices(n: int, k: int) -> np.ndarray:
    """k indices spread evenly over [0, n) (all of them if n <= k)."""
    if n <= k:
        return np.arange(n)
    return np.linspace(0, n - 1, k).astype(int)


# ---------------------------------------------------------------------------
# round timeline — lives in the jax-free repro.fl.schedule (the co-sim
# imports it without pulling this module's jax stack); re-exported here
# so existing imports keep working
# ---------------------------------------------------------------------------

from repro.fl.schedule import RoundWindow, round_schedule  # noqa: E402


@dataclass
class HFLRunConfig:
    rounds: int = 100
    local_epochs: int = 5
    batch_size: int = 16
    lr: float = 1e-4
    history: int = 12
    train_days: int = 21
    val_days: int = 7
    shift_steps: int = 36
    max_batches: int = 40            # subsample batches/epoch for speed
    max_val_windows: int = 512
    seed: int = 0


@dataclass
class HFLResult:
    mse: np.ndarray                  # (rounds, clients) val MSE per round
    train_loss: np.ndarray           # (rounds, clients)
    mode: str

    def converged_round(self, tol: float = 1.05) -> int:
        """First round whose mean MSE is within tol x of the min."""
        means = self.mse.mean(axis=1)
        target = means.min() * tol
        idx = np.nonzero(means <= target)[0]
        return int(idx[0]) if idx.size else len(means) - 1


class ContinualHFL:
    """mode: 'flat' (centralized FedAvg every round),
             'hier' (cluster aggregation each round, global every l)."""

    def __init__(self, cfg: ArchConfig, ds: TrafficDataset,
                 sensors: np.ndarray, topo: ClusterTopology,
                 run: HFLRunConfig, mode: str = "hier"):
        assert mode in ("flat", "hier")
        self.cfg, self.ds, self.run, self.mode = cfg, ds, run, mode
        self.sensors = np.asarray(sensors)
        self.topo = topo
        # cluster ids compacted to 0..k-1 for segment ops
        assign = topo.assign[:len(self.sensors)] \
            if topo.assign.shape[0] >= len(self.sensors) else topo.assign
        uniq = {int(j): k for k, j in enumerate(np.unique(assign))}
        self.cluster_ids = np.asarray([uniq[int(j)] for j in assign])
        rng = jax.random.key(run.seed)
        params0, _ = gru.init_params(rng, cfg.model)
        self.params = stack_clients([params0] * len(self.sensors))
        self.weights = np.ones(len(self.sensors))

    def round_schedule(self, rounds: Optional[int] = None,
                       epoch_s: float = 6.0, upload_s: float = 2.0,
                       **kwargs) -> List[RoundWindow]:
        """Timeline of this run's rounds for the co-simulation."""
        return round_schedule(rounds or self.run.rounds, l=self.topo.l,
                              local_epochs=self.run.local_epochs,
                              epoch_s=epoch_s, upload_s=upload_s, **kwargs)

    def _round_data(self, round_idx: int):
        r = self.run
        tr, va = continual_split(self.ds, round_idx, r.train_days,
                                 r.val_days, r.shift_steps)
        Xs, ys, Xv, yv = [], [], [], []
        for s in self.sensors:
            X, y = windows_for_sensor(self.ds, int(s), tr.start, tr.stop,
                                      r.history)
            Xs.append(X)
            ys.append(y)
            X2, y2 = windows_for_sensor(self.ds, int(s), va.start, va.stop,
                                        r.history)
            # subsample the val week EVENLY: max_val_windows contiguous
            # windows cover only ~max_val_windows*5min, so a truncated
            # prefix slides through the daily cycle as rounds shift and
            # the metric tracks time-of-day, not learning
            idx = _even_indices(len(X2), r.max_val_windows)
            Xv.append(X2[idx])
            yv.append(y2[idx])
        train = ClientBatch(X=jnp.asarray(np.stack(Xs)),
                            y=jnp.asarray(np.stack(ys)))
        val = ClientBatch(X=jnp.asarray(np.stack(Xv)),
                          y=jnp.asarray(np.stack(yv)))
        return train, val

    def run_rounds(self, rounds: Optional[int] = None,
                   progress: bool = False) -> HFLResult:
        r = self.run
        rounds = rounds or r.rounds
        mse_hist, loss_hist = [], []
        rng = jax.random.key(r.seed + 1)
        for t in range(rounds):
            train, val = self._round_data(t)
            rng, sub = jax.random.split(rng)
            self.params, losses = train_clients_locally(
                self.params, train, sub, cfg=self.cfg,
                epochs=r.local_epochs, batch_size=r.batch_size, lr=r.lr,
                max_batches=r.max_batches)
            if self.mode == "flat":
                glob = fedavg(self.params, jnp.asarray(self.weights))
                self.params = jax.tree.map(
                    lambda g: jnp.broadcast_to(g, (len(self.sensors),)
                                               + g.shape), glob)
            else:
                if (t + 1) % self.topo.l == 0:      # global round
                    self.params = global_fedavg(self.params,
                                                self.cluster_ids,
                                                self.weights)
                else:                                # local round
                    self.params = cluster_fedavg(self.params,
                                                 self.cluster_ids,
                                                 self.weights)
            val_mse = eval_clients(self.params, val, cfg=self.cfg)
            mse_hist.append(np.asarray(val_mse))
            loss_hist.append(np.asarray(losses))
            if progress and (t % 10 == 0 or t == rounds - 1):
                print(f"  round {t:3d}: mean val MSE "
                      f"{float(np.mean(val_mse)):.5f}")
        return HFLResult(mse=np.stack(mse_hist),
                         train_loss=np.stack(loss_hist), mode=self.mode)


def continuous_vs_static(cfg: ArchConfig, ds: TrafficDataset, sensor: int,
                         run: HFLRunConfig, rounds: int = 20
                         ) -> Dict[str, float]:
    """Paper §V-B1: a single continuously-retrained model vs a one-shot
    model, evaluated on the final validation week."""
    rng = jax.random.key(run.seed)
    params0, _ = gru.init_params(rng, cfg.model)
    stacked = stack_clients([params0])

    def data(round_idx):
        tr, va = continual_split(ds, round_idx, run.train_days,
                                 run.val_days, run.shift_steps)
        X, y = windows_for_sensor(ds, sensor, tr.start, tr.stop, run.history)
        Xv, yv = windows_for_sensor(ds, sensor, va.start, va.stop,
                                    run.history)
        idx = _even_indices(len(Xv), run.max_val_windows)
        return (ClientBatch(jnp.asarray(X[None]), jnp.asarray(y[None])),
                ClientBatch(jnp.asarray(Xv[idx][None]),
                            jnp.asarray(yv[idx][None])))

    # static: train once on round-0 window
    tr0, _ = data(0)
    static = stacked
    for _ in range(4):               # a few extra passes, like 20 epochs
        static, _ = train_clients_locally(
            static, tr0, rng, cfg=cfg, epochs=run.local_epochs,
            batch_size=run.batch_size, lr=run.lr,
            max_batches=run.max_batches)
    # continual: retrain on each shifted window
    cont = stacked
    for t in range(rounds):
        trt, _ = data(t)
        cont, _ = train_clients_locally(
            cont, trt, rng, cfg=cfg, epochs=run.local_epochs,
            batch_size=run.batch_size, lr=run.lr,
            max_batches=run.max_batches)
    _, va_last = data(rounds - 1)
    mse_static = float(eval_clients(static, va_last, cfg=cfg)[0])
    mse_cont = float(eval_clients(cont, va_last, cfg=cfg)[0])
    return {"static_mse": mse_static, "continual_mse": mse_cont}
