"""End-to-end behaviour: the full paper pipeline — inventory -> HFLOP
clustering -> deployment -> continual HFL training -> inference routing ->
communication-cost accounting — produces a coherent, paper-consistent
result on a small instance."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import hfl_cost, flat_fl_cost, is_feasible
from repro.core.topology import ClusterTopology
from repro.data.traffic import generate, select_fl_sensors
from repro.fl.hierarchy import ContinualHFL, HFLRunConfig
from repro.orchestration import (DeviceNode, EdgeNode, Inventory,
                                 LearningController)
from repro.routing import SimConfig, compare_methods


@pytest.fixture(scope="module")
def pipeline():
    # 21d train + 7d val + 4 rounds x 36-step shift needs >28 days
    ds = generate(num_days=31, n_sensors=40, seed=0)
    sensors = select_fl_sensors(ds, per_cluster=2, seed=0)   # 8 clients
    n, m = len(sensors), 4
    rng = np.random.default_rng(0)
    lam = rng.uniform(2.0, 6.0, n)
    devices = [DeviceNode(i, lam=float(lam[i]),
                          lan_edge=int(ds.cluster_of[sensors[i]]))
               for i in range(n)]
    edges = [EdgeNode(j, capacity_rps=float(lam.sum() / m * 1.4))
             for j in range(m)]
    inv = Inventory(devices, edges)
    ctl = LearningController(inventory=inv, l=2)
    dep = ctl.deploy()
    return ds, sensors, inv, ctl, dep


def test_clustering_feasible(pipeline):
    ds, sensors, inv, ctl, dep = pipeline
    inst = inv.to_instance(l=2)
    assert is_feasible(inst, dep.topology.assign)
    assert dep.topology.participant_count() == len(sensors)


def test_continual_training_converges(pipeline):
    ds, sensors, inv, ctl, dep = pipeline
    cfg = get_config("gru-traffic")
    run = HFLRunConfig(rounds=4, max_batches=12, max_val_windows=128,
                       local_epochs=3)
    hfl = ContinualHFL(cfg, ds, sensors, dep.topology, run, mode="hier")
    res = hfl.run_rounds()
    means = res.mse.mean(axis=1)
    assert np.isfinite(means).all()
    assert means[-1] < means[0]          # learning happened
    assert res.mse.shape == (4, len(sensors))


def test_inference_latency_ordering(pipeline):
    ds, sensors, inv, ctl, dep = pipeline
    inst = inv.to_instance(l=2)
    logs = compare_methods(
        inst, {"flat": None, "hflop": dep.topology.assign},
        SimConfig(duration_s=60, seed=0))
    # paper Fig. 7: flat ~79 ms, HFLOP ~10 ms
    assert logs["flat"].mean_latency() > 50
    assert logs["hflop"].mean_latency() < 25
    assert logs["hflop"].std_latency() < logs["flat"].std_latency() + 20


def test_cost_accounting_ordering(pipeline):
    ds, sensors, inv, ctl, dep = pipeline
    inst = inv.to_instance(l=2)
    flat = flat_fl_cost(inst.n, 100)
    hier = hfl_cost(inst, dep.topology.assign, 100)
    assert hier.metered_bytes < flat.metered_bytes
    assert hier.n_global_rounds == 50
